"""Max-Share heuristic — paper Algorithm 1.

Prefer binding a task onto ACTIVE deployments of its backbone (best-fit order:
smallest spare capacity that still absorbs the task — leaves minimal unused
capacity); only when no feasible plan exists over live backbones, provision a
new backbone on a best-fit server. Supports replication: if one deployment
cannot absorb the demand, the plan spreads it across several (routing
fractions), matching the paper's "task replication across servers".
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.controller.state import ClusterState, Deployment, Server, TaskSpec


@dataclasses.dataclass
class Plan:
    task: TaskSpec
    assignment: dict[str, float]              # dep_id -> demand fraction
    new_deployments: list[tuple[str, str]]    # (server_id, backbone) provisioned


def _feasible_assignment(task: TaskSpec, candidates: list[Deployment]
                         ) -> Optional[dict[str, float]]:
    """Greedy fill over the candidate set (paper's plan())."""
    remaining = task.demand_rps
    assignment: dict[str, float] = {}
    for dep in candidates:
        if not dep.meets_slo(task.slo_s):
            continue
        absorb = min(max(dep.spare_rps(), 0.0), remaining)
        if absorb <= 0:
            continue
        assignment[dep.dep_id] = absorb / task.demand_rps if task.demand_rps else 1.0
        remaining -= absorb
        if remaining <= 1e-9:
            return assignment
    return None


class MaxShare:
    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def best_fit_order(self, deps: list[Deployment], task: TaskSpec
                       ) -> list[Deployment]:
        """Rank by how snugly they absorb the task (minimal leftover spare)."""
        def key(d):
            spare_after = d.spare_rps() - task.demand_rps
            return (0 if spare_after >= 0 else 1,
                    spare_after if spare_after >= 0 else -spare_after)
        return sorted(deps, key=key)

    def best_fit_servers(self, task: TaskSpec) -> list[Server]:
        prof = self.cluster.profiles[task.backbone]
        need = prof.memory_bytes + prof.task_memory_bytes
        fits = [s for s in self.cluster.servers.values()
                if s.alive and s.mem_free() >= need]
        # fewest co-resident deployments first (a new instance halves the
        # partition of everything already on the server), then snuggest memory
        return sorted(fits, key=lambda s: (len(s.deployments),
                                           s.mem_free() - need))

    def place(self, task: TaskSpec) -> Optional[Plan]:
        """Algorithm 1. Returns a committed Plan or None (⊥)."""
        cand: list[Deployment] = []
        # phase 1: prefer existing backbones
        active = self.cluster.active_deployments(task.backbone)
        for dep in self.best_fit_order(active, task):
            cand.append(dep)
            assignment = _feasible_assignment(task, cand)
            if assignment is not None:
                self.cluster.bind(task, assignment)
                return Plan(task, assignment, [])
        # phase 2: provision only as needed
        new_deps: list[tuple[str, str]] = []
        for server in self.best_fit_servers(task):
            # Algorithm 1 feasible(): a new instance shrinks the spatial
            # partition of co-resident deployments — reject the server if that
            # would push any EXISTING deployment over its admitted load.
            n_after = len(server.deployments) + 1
            if any(d.load_rps() > 0.8 * (d.profile.b_max /
                                         d.profile.l(d.profile.b_max)) / n_after
                   for d in server.deployments):
                continue
            dep = self.cluster.new_deployment(server, task.backbone)
            new_deps.append((server.server_id, task.backbone))
            cand.append(dep)
            assignment = _feasible_assignment(task, cand)
            if assignment is not None:
                self.cluster.bind(task, assignment)
                return Plan(task, assignment, new_deps)
        # infeasible: roll back provisioned deployments
        for server_id, _ in new_deps:
            server = self.cluster.servers[server_id]
            dep = server.deployments.pop()
            self.cluster.deployments.pop(dep.dep_id, None)
        return None
