"""Cluster deployment state maintained by FMplex-Controller (paper §5).

Backend-agnostic: the same state drives the discrete-event simulator and the
real in-process servers. Compute feasibility uses the backbone profile's
amortized throughput at the batching knee; memory feasibility uses backbone +
per-task extension residency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core.profile import FMProfile

_dep_ids = itertools.count()

UTILIZATION_TARGET = 0.8     # keep headroom for bursts when admitting load


@dataclasses.dataclass
class TaskSpec:
    task_id: str
    backbone: str
    demand_rps: float = 1.0
    weight: float = 1.0
    slo_s: Optional[float] = None
    adapter_id: Optional[str] = None


@dataclasses.dataclass
class Deployment:
    """One physical FM instance on one server."""
    dep_id: str
    server_id: str
    backbone: str
    profile: FMProfile
    tasks: dict[str, float] = dataclasses.field(default_factory=dict)  # task->rps
    routing: dict[str, float] = dataclasses.field(default_factory=dict)  # task->frac
    partitions: int = 1   # FM instances sharing this accelerator (spatial split)

    def capacity_rps(self) -> float:
        """Sustainable request rate at the batching knee, scaled by the
        accelerator partition this instance owns (paper §6: co-located FM
        instances get disjoint TPC subsets)."""
        b = self.profile.b_max
        return b / self.profile.l(b) / max(self.partitions, 1)

    def load_rps(self) -> float:
        return sum(self.tasks.values())

    def spare_rps(self) -> float:
        return UTILIZATION_TARGET * self.capacity_rps() - self.load_rps()

    def memory(self) -> float:
        return self.profile.memory_bytes + self.profile.instance_overhead_bytes \
            + len(self.tasks) * self.profile.task_memory_bytes

    def meets_slo(self, slo_s: Optional[float]) -> bool:
        if slo_s is None:
            return True
        return self.profile.l(self.profile.b_max) <= slo_s


@dataclasses.dataclass
class Server:
    server_id: str
    mem_bytes: float = 16e9
    alive: bool = True
    deployments: list[Deployment] = dataclasses.field(default_factory=list)

    def mem_used(self) -> float:
        return sum(d.memory() for d in self.deployments)

    def mem_free(self) -> float:
        return self.mem_bytes - self.mem_used()


class ClusterState:
    def __init__(self, servers: list[Server],
                 profiles: dict[str, FMProfile]):
        self.servers = {s.server_id: s for s in servers}
        self.profiles = profiles                      # backbone -> profile
        self.deployments: dict[str, Deployment] = {}
        self.task_bindings: dict[str, list[str]] = {}  # task -> [dep_id]

    def active_deployments(self, backbone: str) -> list[Deployment]:
        return [d for d in self.deployments.values() if d.backbone == backbone]

    def new_deployment(self, server: Server, backbone: str) -> Deployment:
        dep = Deployment(f"dep{next(_dep_ids)}", server.server_id, backbone,
                         self.profiles[backbone])
        self.deployments[dep.dep_id] = dep
        server.deployments.append(dep)
        for d in server.deployments:          # spatial partition rebalance
            d.partitions = len(server.deployments)
        return dep

    def bind(self, task: TaskSpec, assignment: dict[str, float]):
        """assignment: dep_id -> fraction of the task's demand routed there."""
        self.task_bindings[task.task_id] = list(assignment)
        for dep_id, frac in assignment.items():
            dep = self.deployments[dep_id]
            dep.tasks[task.task_id] = task.demand_rps * frac
            dep.routing[task.task_id] = frac

    def unbind(self, task_id: str):
        for dep_id in self.task_bindings.pop(task_id, []):
            dep = self.deployments.get(dep_id)
            if dep:
                dep.tasks.pop(task_id, None)
                dep.routing.pop(task_id, None)

    def total_tasks(self) -> int:
        return len(self.task_bindings)
