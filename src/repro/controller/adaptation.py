"""Elastic adaptation (paper §5.3, Fig. 16) + fault/straggler response.

When demand surges or a server degrades, the Controller first tries the CHEAP
path enabled by vFM decoupling: update the affected task's binding/routing to
a compatible backbone that is already resident (move only task-local state —
queue metadata, decoder/adapter refs, scheduler weights; ~task-load
timescale). Only if no compatible backbone has spare capacity does it fall
back to provisioning a new backbone (backbone-load timescale).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.controller.maxshare import MaxShare
from repro.controller.state import ClusterState, TaskSpec


@dataclasses.dataclass
class AdaptResult:
    path: str                 # "rebind" | "provision" | "infeasible"
    ready_s: float            # time until the new capacity serves traffic
    assignment: dict


class ElasticAdapter:
    def __init__(self, cluster: ClusterState):
        self.cluster = cluster
        self.placer = MaxShare(cluster)

    def on_surge(self, task: TaskSpec, new_demand_rps: float) -> AdaptResult:
        """Demand change for an existing task: rebind vs provision."""
        task = dataclasses.replace(task, demand_rps=new_demand_rps)
        self.cluster.unbind(task.task_id)
        before = set(self.cluster.deployments)
        plan = self.placer.place(task)
        if plan is None:
            return AdaptResult("infeasible", float("inf"), {})
        if set(self.cluster.deployments) == before:
            # only task-local state moved: queue metadata + extensions
            prof = self.cluster.profiles[task.backbone]
            return AdaptResult("rebind", prof.task_load_s, plan.assignment)
        prof = self.cluster.profiles[task.backbone]
        return AdaptResult("provision", prof.load_time_s + prof.task_load_s,
                           plan.assignment)

    def on_server_failure(self, server_id: str) -> list[AdaptResult]:
        """Rebind every task of a dead/straggling server elsewhere."""
        server = self.cluster.servers[server_id]
        server.alive = False
        moved = []
        dead = list(server.deployments)
        server.deployments.clear()
        agg: dict[str, TaskSpec] = {}
        for dep in dead:
            self.cluster.deployments.pop(dep.dep_id, None)
            for tid, rps in dep.tasks.items():
                if tid in agg:
                    agg[tid].demand_rps += rps
                else:
                    agg[tid] = TaskSpec(tid, dep.backbone, demand_rps=rps)
        victims = list(agg.values())
        # also clear stale bindings before replacement
        for t in victims:
            self.cluster.task_bindings.pop(t.task_id, None)
        for t in victims:
            moved.append(self.on_surge(t, t.demand_rps))
        return moved
