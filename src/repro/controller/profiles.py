"""Paper-calibrated backbone profiles (Table 3 + Fig. 12 saturation points).

Derivation notes:
  * l(1) from Table 3 mean per-request backbone latency;
  * beta chosen so saturated shared-backbone throughput matches Fig. 12
    (FMplex sustains ~84 RPS on MOMENT-Large where S-STFQ caps at 1/l(1)≈38);
  * memory/load times straight from Table 3 (backbone vs task split).
"""
from __future__ import annotations

from repro.core.profile import FMProfile

MB = 1 << 20

PAPER_PROFILES: dict[str, FMProfile] = {
    # Time series
    "moment-large": FMProfile("moment-large", alpha=16.8e-3, beta=11.2e-3,
                              b_max=16, memory_bytes=1462 * MB,
                              load_time_s=5.737, adapter_alpha=2e-3,
                              adapter_beta=4e-4, task_memory_bytes=int(0.52 * MB),
                              task_load_s=0.025),
    "papagei": FMProfile("papagei", alpha=11e-3, beta=4.8e-3, b_max=16,
                         memory_bytes=int(23.24 * MB), load_time_s=0.162,
                         adapter_alpha=1e-3, adapter_beta=2e-4,
                         task_memory_bytes=int(0.26 * MB), task_load_s=0.005),
    # Vision
    "dinov2-base": FMProfile("dinov2-base", alpha=13e-3, beta=5.8e-3, b_max=16,
                             memory_bytes=347 * MB, load_time_s=0.817,
                             adapter_alpha=1.5e-3, adapter_beta=3e-4,
                             task_memory_bytes=int(0.03 * MB), task_load_s=0.001),
    "swin-large": FMProfile("swin-large", alpha=21e-3, beta=9.9e-3, b_max=16,
                            memory_bytes=347 * MB, load_time_s=1.001,
                            adapter_alpha=1.5e-3, adapter_beta=3e-4,
                            task_memory_bytes=int(0.04 * MB), task_load_s=0.001),
    # LLM / VLM (token-based; service time charged per request-equivalent)
    "qwen2.5-3b": FMProfile("qwen2.5-3b", alpha=120e-3, beta=190e-3, b_max=4,
                            memory_bytes=6285 * MB, load_time_s=3.095,
                            adapter_alpha=4e-3, adapter_beta=1e-3,
                            task_memory_bytes=8 * MB, task_load_s=0.18),
    "mistral-7b": FMProfile("mistral-7b", alpha=220e-3, beta=384e-3, b_max=4,
                            memory_bytes=14496 * MB, load_time_s=5.927,
                            adapter_alpha=4e-3, adapter_beta=1e-3,
                            task_memory_bytes=8 * MB, task_load_s=0.2),
    "qwen2-vl-2b": FMProfile("qwen2-vl-2b", alpha=60e-3, beta=74e-3, b_max=8,
                             memory_bytes=4420 * MB, load_time_s=4.492,
                             adapter_alpha=4e-3, adapter_beta=1e-3,
                             task_memory_bytes=int(8.76 * MB), task_load_s=0.176),
}


def get_profile(name: str) -> FMProfile:
    return PAPER_PROFILES[name]
