from repro.controller.adaptation import AdaptResult, ElasticAdapter
from repro.controller.maxshare import MaxShare, Plan
from repro.controller.profiles import PAPER_PROFILES, get_profile
from repro.controller.state import ClusterState, Deployment, Server, TaskSpec
