"""Continuous-batching decode engine: segmented-LoRA token serving over a
persistent int8 KV-cache pool.

Autoregressive serving is where FMplex's co-location wins compound: every
decode step re-uses the shared backbone across all co-resident tasks, so the
per-step cost of multi-task isolation must be ~zero. The engine owns:

  * a **slot pool** — a fixed, bucketed number of decode slots backed by one
    persistent KV cache allocated ONCE (``lm.init_cache(kv_quant=True)``):
    self-attention K/V live as int8 with per-(slot, kv-head) scales fixed at
    prefill admission (``kernels.decode_attention_int8.quantize_kv``), halving
    cache traffic; every decode step streams int8 only;
  * **admission prefill** — a joining request's prompt runs a single jitted
    prefill (LoRA applied, K/V quantized in-graph) and is scattered into its
    slot with one ``dynamic_update_slice`` per cache leaf. Admission is
    **variable-length**: prompts are right-padded to the smallest of 2-3
    *prompt-length buckets* (a static jit-cache key), while the TRUE length
    rides along as a traced operand — pad keys are masked out of attention
    (``lm.prefill(seq_lens=...)``), the cache ``len`` is per-row exact, and
    the first token comes from the last REAL prompt position. Any prompt
    length within the largest bucket therefore reuses one of at most
    ``len(prompt_buckets)`` compiled executables;
  * **chunked decode** — ``step_chunk`` advances ALL occupied slots ``chunk``
    tokens under one jitted ``lax.scan`` (device-resident sampling: one
    dispatch and one host sync per chunk, not per token). Sampling is greedy
    by default; ``temperature > 0`` switches to temperature/top-k sampling
    with **per-slot PRNG key state threaded through the scan carry**, so
    streams stay reproducible and independent across slot churn;
  * **cached SGMV metadata** — segment metadata for the S=1 token co-batch is
    built once per batch *composition* (slot occupancy + adapter assignment)
    and reused every step; steady-state decode performs zero host-side sorts
    (``PhysicalFM.seg_meta_cache`` memoizes, this class caches the
    device-uploaded arrays) and zero recompiles (jit keyed on
    (slot bucket, adapter slot bucket, chunk), like ``run_batch``).

Requests join and leave slots between chunks without recompilation: all
traced shapes depend only on the bucketed quantities above. Free slots keep
stepping (static shapes) — their rows are per-slot isolated garbage that the
next admission's prefill overwrites.

int8 KV scale drift: the per-(slot, kv-head) quantization scales are fixed
ONCE at prefill admission. Decode-era K/V whose magnitude outgrows the
prompt-era range are clipped to ±127·scale — the engine never rescales a
live slot (that would re-quantize the whole row mid-stream). The divergence
this introduces is bounded and grows slowly with decode length: empirically
(``tests/test_decode_engine.py::test_int8_scale_drift_bounded``) a decode
tail 3× longer than the prompt whose K/V magnitude drifts to 3× the
admission-scale range keeps attention-output relative divergence under ~0.8
(vs ~0.06 with no drift), and at the model level a decode 4× the prompt
length keeps logit relative divergence under 0.5
(``test_int8_long_decode_divergence_bounded``). Decodes far beyond a
``max_new`` of a few hundred tokens, or adapters that systematically grow
activation magnitude, should either re-admit (prefill on the generated
prefix refreshes scales) or allocate the pool with ``kv_quant=False``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.physical import PAD_SENTINEL, PhysicalFM, bucket_for
from repro.models import lm

FREE = PAD_SENTINEL   # free-slot adapter sentinel (same as run_batch padding)


def default_prompt_buckets(prompt_len: int) -> tuple[int, ...]:
    """2-3 admission buckets: quarter, half and full ``prompt_len`` (deduped,
    ascending). Small enough that every bucket's prefill executable warms
    quickly; coarse enough that steady state never recompiles."""
    return tuple(sorted({max(1, prompt_len // 4),
                         max(1, prompt_len // 2), prompt_len}))


def make_sampler(temperature: float, top_k: int):
    """Token sampler used inside the jitted prefill/decode graphs.

    ``sample(logits (B, V), keys (B, 2) uint32) -> (tokens (B,), keys')``.
    Greedy when ``temperature <= 0`` (keys pass through untouched); otherwise
    temperature-scaled categorical over the top-k logits, one PRNG key per
    row so co-batched streams sample independently."""
    if temperature <= 0:
        def sample(logits, keys):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
        return sample

    def sample(logits, keys):
        split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
        next_keys, use_keys = split[:, 0], split[:, 1]
        l = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jax.lax.top_k(l, top_k)[0][:, -1]
            l = jnp.where(l >= kth[:, None], l, -1e30)
        toks = jax.vmap(jax.random.categorical)(use_keys, l)
        return toks.astype(jnp.int32), next_keys
    return sample


@dataclasses.dataclass
class DecodeSlot:
    """One occupied decode stream."""
    rid: int
    task_id: str
    adapter_slot: int
    max_new: int
    eos_id: Optional[int]
    tokens: list          # generated token ids (first one from prefill)
    t_join: float
    t_first: float        # wall time of the first generated token (TTFT end)
    prompt_tokens: int = 0   # TRUE (post-truncation) admitted prompt length
    done: bool = False


class DecodeEngine:
    """Slot-based continuous-batching token server bound to one PhysicalFM."""

    def __init__(self, fm: PhysicalFM, *, num_slots: int = 8,
                 prompt_len: Optional[int] = None, max_new: int = 32,
                 chunk: int = 4, kv_quant: bool = True,
                 eos_id: Optional[int] = None,
                 prompt_buckets: Optional[tuple] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0):
        cfg = fm.cfg
        assert cfg.vocab_size > 0 and not cfg.is_representation, \
            "DecodeEngine serves generative decoder LMs (vocab head required)"
        assert not cfg.is_encoder_decoder, \
            "enc-dec decode needs per-slot encoder state (not supported yet)"
        self.fm = fm
        self.cfg = cfg
        self.num_slots = bucket_for(num_slots)
        self.prompt_len = prompt_len or fm.input_len
        # variable-length admission masks pads out of ATTENTION; recurrent
        # blocks (mamba/xLSTM) would still scan right-pad tokens into their
        # state, so hybrid stacks keep the single full-length bucket with
        # the legacy left-pad (pads attended, positionally before the prompt)
        from repro.configs.base import ATTN
        self.var_len = all(b == ATTN for b in cfg.blocks)
        if prompt_buckets is None:
            prompt_buckets = default_prompt_buckets(self.prompt_len) \
                if self.var_len else (self.prompt_len,)
        self.prompt_buckets = tuple(sorted(set(int(b) for b in prompt_buckets)))
        self.prompt_len = self.prompt_buckets[-1]   # largest bucket is the cap
        self.max_new = max_new
        self.chunk = chunk
        self.kv_quant = kv_quant
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample = make_sampler(self.temperature, self.top_k)
        # per-slot PRNG key state; threaded through the decode scan carry
        self._keys = jax.random.split(jax.random.PRNGKey(sample_seed),
                                      self.num_slots)
        self.s_max = self.prompt_len + max_new + 1
        # the persistent pool: allocated once, updated in place (donated)
        self.pool = lm.init_cache(cfg, self.num_slots, self.s_max,
                                  kv_quant=kv_quant)
        self._tokens = jnp.zeros((self.num_slots,), jnp.int32)  # last token/slot
        self.slots: list[Optional[DecodeSlot]] = [None] * self.num_slots
        self._slot_adapters = np.full((self.num_slots,), FREE, np.int32)
        self._jit_prefill: dict[tuple, Callable] = {}
        self._jit_decode: dict[tuple, Callable] = {}
        self._jit_write: Optional[Callable] = None
        self._seg_key = None        # composition signature of cached metadata
        self._seg_dev = None        # device-uploaded (perm, inv, blocks)
        self.steps = 0              # decode steps executed (all slots)
        self.last_chunk_s = 0.0

    # ---- occupancy ----
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def compile_count(self) -> int:
        """Total jitted executables (prefill + decode + pool writes); steady
        state across request join/leave churn must not grow this."""
        fns = list(self._jit_prefill.values()) + list(self._jit_decode.values())
        if self._jit_write is not None:
            fns.append(self._jit_write)
        return sum(f._cache_size() if hasattr(f, "_cache_size") else 1
                   for f in fns)

    # ---- jitted planes ----
    @staticmethod
    def _donate(*argnums):
        return argnums if jax.default_backend() != "cpu" else ()

    def _prefill_fn(self, cap: int, plen: int):
        """Admission prefill for one prompt-length bucket. The bucket length
        is a static jit key; the TRUE prompt length is a traced operand, so
        every length within the bucket reuses the executable."""
        key = (cap, plen)
        if key not in self._jit_prefill:
            cfg, impl, bt = self.cfg, self.fm.lora_impl, self.fm.seg_block_t
            s_max, kvq, sample = self.s_max, self.kv_quant, self._sample

            @jax.jit
            def run(params, tokens, true_len, rng_key, lora_stack,
                    adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                cache = lm.init_cache(cfg, 1, s_max, kv_quant=kvq)
                logits, cache = lm.prefill(
                    params, cfg, tokens=tokens, cache=cache, lora=lora_stack,
                    adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg,
                    seq_lens=true_len)
                first, rng_key = sample(logits, rng_key)
                return first, rng_key, cache

            self._jit_prefill[key] = run
        return self._jit_prefill[key]

    def _write_fn(self):
        if self._jit_write is None:
            donate = self._donate(0)

            def write(pool, cache, slot):
                # every cache leaf is (nper, batch, ...): scatter the one-row
                # prefill cache into the pool's slot along the batch axis
                return jax.tree.map(
                    lambda p, c: jax.lax.dynamic_update_slice_in_dim(
                        p, c.astype(p.dtype), slot, axis=1), pool, cache)

            self._jit_write = jax.jit(write, donate_argnums=donate)
        return self._jit_write

    def _decode_fn(self, cap: int, chunk: int):
        key = (self.num_slots, cap, chunk)
        if key not in self._jit_decode:
            cfg, impl, bt = self.cfg, self.fm.lora_impl, self.fm.seg_block_t
            donate = self._donate(1)

            sample = self._sample

            def run(params, pool, tokens, keys, lora_stack, adapter_idx,
                    perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}

                def body(carry, _):
                    pool, tok, keys = carry
                    logits, pool = lm.decode_step(
                        params, cfg, tokens=tok, cache=pool, lora=lora_stack,
                        adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg)
                    nxt, keys = sample(logits, keys)
                    return (pool, nxt, keys), nxt

                (pool, tok, keys), out = jax.lax.scan(
                    body, (pool, tokens, keys), None, length=chunk)
                return pool, tok, keys, out.T                # (slots, chunk)

            self._jit_decode[key] = jax.jit(run, donate_argnums=donate)
        return self._jit_decode[key]

    # ---- segment metadata (per composition, not per token) ----
    def _segments(self, cap: int):
        key = (self._slot_adapters.tobytes(), cap)
        if key != self._seg_key:
            perm, inv, blocks = self.fm.segment_meta(self._slot_adapters, cap, 1)
            self._seg_dev = (jnp.asarray(perm), jnp.asarray(inv),
                             jnp.asarray(blocks))
            self._seg_key = key
        return self._seg_dev

    def _prefill_segments(self, adapter_slot: int, cap: int, plen: int):
        ids = np.full((plen,), adapter_slot, np.int32)
        perm, inv, blocks = self.fm.segment_meta(ids, cap, 1)
        return jnp.asarray(perm), jnp.asarray(inv), jnp.asarray(blocks)

    def bucket_for_prompt(self, n: int) -> int:
        """Smallest admission bucket holding an n-token prompt."""
        for b in self.prompt_buckets:
            if n <= b:
                return b
        return self.prompt_buckets[-1]

    # ---- serving surface ----
    def join(self, task_id: str, prompt: np.ndarray, *,
             adapter_id: Optional[str] = None, max_new_tokens: int = 8,
             rid: int = -1, eos_id: Optional[int] = None) -> int:
        """Admit one request: prefill its prompt (LoRA applied, K/V int8-
        quantized in-graph), scatter it into a free slot, produce the first
        token. Returns the slot index; raises if the pool is full.

        Admission is variable-length: the prompt is right-padded to the
        smallest prompt-length bucket that holds it (a static jit key —
        at most ``len(prompt_buckets)`` prefill executables ever compile)
        while the true length is a traced operand masking the pads out of
        attention and the KV cache. Prompts longer than the largest bucket
        keep their LAST ``prompt_len`` tokens (causal LM: the suffix
        matters) — that loses context, so it WARNS; the decode budget clamps
        to the pool's ``max_new`` capacity."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free decode slots; step_chunk() first")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.prompt_len:
            warnings.warn(
                f"prompt of {len(prompt)} tokens exceeds the engine's largest "
                f"admission bucket ({self.prompt_len}); left-truncating to "
                f"the last {self.prompt_len} tokens (context is lost — size "
                f"prompt_buckets to the workload)", RuntimeWarning,
                stacklevel=2)
            prompt = prompt[-self.prompt_len:]     # causal LM: suffix matters
        if self.var_len:
            true_len = max(1, len(prompt))
            plen = self.bucket_for_prompt(true_len)
            if len(prompt) < plen:                 # right-pad to the bucket
                prompt = np.concatenate(
                    [prompt, np.zeros(plen - len(prompt), np.int32)])
        else:                                      # hybrid stack: legacy pad
            plen = true_len = self.prompt_len
            if len(prompt) < plen:
                prompt = np.concatenate(
                    [np.zeros(plen - len(prompt), np.int32), prompt])
        max_new_tokens = max(1, min(max_new_tokens, self.max_new))
        slot = free[0]
        cap = self.fm.adapters.capacity()
        aslot = self.fm.adapters.index(adapter_id)
        perm, inv, blocks = self._prefill_segments(aslot, cap, plen)
        first, key, cache = self._prefill_fn(cap, plen)(
            self.fm.params, jnp.asarray(prompt[None]),
            jnp.full((1,), true_len, jnp.int32), self._keys[slot][None],
            self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
            perm, inv, blocks)
        self._keys = self._keys.at[slot].set(key[0])
        self.pool = self._write_fn()(self.pool, cache, slot)
        self._tokens = self._tokens.at[slot].set(first[0])
        now = time.perf_counter()
        tok0 = int(first[0])
        eos = self.eos_id if eos_id is None else eos_id
        self.slots[slot] = DecodeSlot(
            rid=rid, task_id=task_id, adapter_slot=aslot,
            max_new=max_new_tokens, eos_id=eos,
            tokens=[tok0], t_join=now, t_first=now, prompt_tokens=true_len,
            done=(max_new_tokens == 1 or (eos is not None and tok0 == eos)))
        self._slot_adapters[slot] = aslot
        self._seg_key = None                    # composition changed
        return slot

    def leave(self, slot: int) -> DecodeSlot:
        """Retire a slot (finished or cancelled) and free it for admission."""
        s = self.slots[slot]
        assert s is not None, slot
        self.slots[slot] = None
        self._slot_adapters[slot] = FREE
        self._seg_key = None                    # composition changed
        # keep the freed slot's cache length bounded while it idles
        for sub in self.pool:
            if isinstance(sub, dict) and "len" in sub:
                sub["len"] = sub["len"].at[:, slot].set(0)
        return s

    def step_chunk(self) -> list[DecodeSlot]:
        """Advance every occupied slot by up to ``chunk`` greedy tokens under
        one jitted scan; retire and return the slots that finished."""
        t0 = time.perf_counter()
        finished = [i for i, s in enumerate(self.slots)
                    if s is not None and s.done]
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]
        if live:
            cap = self.fm.adapters.capacity()
            perm, inv, blocks = self._segments(cap)
            self.pool, self._tokens, self._keys, out = \
                self._decode_fn(cap, self.chunk)(
                    self.fm.params, self.pool, self._tokens, self._keys,
                    self.fm.adapters.stacked(),
                    jnp.asarray(self._slot_adapters), perm, inv, blocks)
            out = np.asarray(out)               # one host sync per chunk
            self.steps += self.chunk
            now = time.perf_counter()
            for i in live:
                s = self.slots[i]
                take = min(self.chunk, s.max_new - len(s.tokens))
                for t in out[i, :take]:
                    s.tokens.append(int(t))
                    if s.eos_id is not None and int(t) == s.eos_id:
                        break
                if len(s.tokens) >= s.max_new or (
                        s.eos_id is not None and s.tokens[-1] == s.eos_id):
                    s.done = True
                    finished.append(i)
        retired = [self.leave(i) for i in finished]
        self.last_chunk_s = time.perf_counter() - t0
        return retired

    def drain(self) -> list[DecodeSlot]:
        """Step until every occupied slot retires."""
        out = []
        while self.active_count():
            out += self.step_chunk()
        return out
