"""Continuous-batching decode engine: segmented-LoRA token serving over a
persistent int8 KV pool — dense slot-contiguous or block-paged.

Autoregressive serving is where FMplex's co-location wins compound: every
decode step re-uses the shared backbone across all co-resident tasks, so the
per-step cost of multi-task isolation must be ~zero. The engine owns:

  * a **slot pool** — a fixed, bucketed number of decode slots backed by one
    persistent KV cache allocated ONCE. Two layouts:

      - *dense* (``paged=False``): ``lm.init_cache(kv_quant=True)`` — one
        contiguous ``(num_slots, s_max)`` int8 region per slot with
        per-(slot, kv-head) scales fixed at prefill admission
        (``kernels.decode_attention_int8``). Every stream RESERVES its
        worst-case length, so the slot count — not memory — caps colocation.
      - *paged* (``paged=True``): one global arena of ``total_pages``
        fixed-size pages (int8 K/V + per-(page, kv-head) scales,
        ``page_size`` tokens each) shared by every slot, addressed through a
        device-resident per-slot page table. Attention gathers K/V through
        the page table inside the Pallas kernel grid
        (``kernels.paged_decode_attention``; jnp gather oracle on CPU).
        Page 0 is the reserved trash page: free slots keep stepping (static
        shapes) and their garbage writes land there, never in a live
        stream's pages.

    **Cache-manager plane (``core.cache_manager``) — the cache contract is
    per-SUBLAYER, not per-engine.** ``CachePlan.for_config`` walks the
    stack's period layout and declares, for every sublayer, where its
    serving state lives:

      - *attention*: growing K/V — the paged int8 arena (or the dense int8
        region) described above;
      - *recurrent* (mamba conv+SSM, mLSTM/sLSTM state): FIXED-SIZE per-slot
        state tensors riding in the same pool list (batch axis == slot),
        written at admission by the same scatter and advanced in place by
        the decode scan — nothing grows, nothing pages;
      - *encoder-decoder cross-attention*: per-slot encoder-output K/V
        sidecars (``ck``/``cv``, ``enc_len`` frames each), computed once at
        admission from the join's ``enc_feats`` and read-only thereafter.

    One lifecycle composes them: admit / decode / retire / preempt / cancel
    / quarantine / snapshot all route through the same slot machinery, with
    a ``StateSlotPool`` tracking the fixed-size side (strict alloc at
    admission, free on every exit path, occupancy + deferral gauges beside
    the page gauges; ``can_admit`` counts state slots for hybrid stacks,
    not just pages). Admission prefill is variable-length for EVERY stack:
    the recurrent scans are length-aware (``dt`` zeroed / state carried
    through right-pad positions), so hybrids share the bucketed right-pad
    path and its zero-recompile guarantees.

    Capability negotiation is explicit — planes whose mechanics are
    attention-only DEMOTE cleanly instead of crashing mid-serve:

      - prefix sharing + chunked prefill: shared pages capture attention KV
        only; recurrent state at the prefix boundary is stream-private, so
        hybrid joins admit plain (per-stream pages, full prefill);
      - speculative decode (``spec_k``): rollback is a length/tracker reset
        on paged KV; recurrent state cannot rewind past rejected drafts —
        ``spec_k > 0`` demotes to plain decode with a warning;
      - spill-resume: the stream spill captures pages + trackers only, so
        hybrid preemption uses the lossless fold-and-re-prefill path (which
        recomputes recurrent state exactly); snapshot/restore instead
        captures the dense state wholesale (``capture_dense_state``).

    **Paged page lifecycle — refcounted ownership + copy-on-write prefix
    sharing.** Every usable page carries a reference count; a page is owned
    by the free list exactly when its refcount is zero, and by one or more
    page-table mappings otherwise. The lifecycle:

      * *allocate* (``_take_pages``): pop from the free list, refcount 1.
      * *share* (``_share_pages``): a joining stream whose prompt starts
        with a prefix another stream already admitted MAPS that stream's
        pages into its own page table instead of copying them — the prefix
        registry (indexed by a chained sha256 digest over the adapter
        identity and the leading token bytes, one entry per full page of a
        registered prompt) resolves the
        longest page-aligned shared prefix, and each mapped page's refcount
        increments. Only pages wholly covered by prompt tokens are ever
        registered, and decode writes only ever land at positions at or
        beyond the stream's true prompt length — so shared pages are
        IMMUTABLE and the read path (the paged attention kernel) needs no
        change. The first divergent or partial page is the copy-on-write
        boundary: shared positions before it are mapped, everything from it
        on (including the partial boundary page itself, recomputed into a
        PRIVATE copy) lands in freshly allocated pages.
      * *release* (``_release_pages``; retire / preempt / bucket-trim all
        route through it): decrement, and only a refcount that reaches zero
        returns the page to the free list (and drops its registry entry).
        Preempting or retiring one sharer therefore never invalidates
        another sharer's mapped pages.

    Admission quantizes the prompt's K/V **per (page, kv-head)**: a page's
    scale is a pure function of the tokens it covers, so a shared page's
    int8 codes and scales are bit-identical to what the joining stream's
    own prefill would have written — sharing is exact, not approximate, and
    a sharer's token stream matches the unshared engine token for token.
    One exception keeps decode sane: the prompt/decode BOUNDARY page (the
    partial page decode keeps appending into — never shared, sharing stops
    at the last full page) is stamped at the slot-wide admission scale, so
    a few small-magnitude prompt tokens in it cannot clip the stream's
    normal-range decode K/V. Decode appends quantize into the slot's
    admission-era running scale for the first token of each fresh page
    (stamping it as the page scale) and into the page's stamped scale
    thereafter, so a recycled page's stale scale can never leak into a new
    owner.

    **Chunked shared-prefix admission (two-phase: map, then tail-compute).**
    A prefix hit saves COMPUTE as well as memory: when ``chunked_prefill``
    is on (the default) an admission whose prompt maps >= 1 registered or
    spill-restorable page runs the prefill ONLY over its private tail. The
    *map phase* increments the shared pages' refcounts (restoring spilled
    ones H2D first); the *tail-compute phase* feeds the tail tokens through
    ``_tail_prefill_fn`` with the mapped pages' int8 content dequantized
    per page (``kernels.ops.gather_prefix_kv``) riding in front of the
    tail's own fresh K/V inside every attention sublayer — absolute RoPE
    positions, causality and pad masking all offset by the prefix length.
    Tail lengths bucket separately (powers of two of the page size, a
    static jit key) so sharer churn with any mix of tail lengths stays
    zero-recompile; the prefix page vector, prefix length and true tail
    length are traced operands. The tail-page scatter quantizes the tail
    from its FLOAT cache exactly like the full path, and folds the mapped
    pages' stamped scales into the slot-wide running scale — bit-identical
    to the slot scale a full prefill would have computed. A quarantined
    tail rolls the map phase back (refcounts drop, nothing registered, the
    spill entries survive). The full prefill remains the fallback whenever
    nothing is shareable or free pages cannot cover restores + tail bucket,
    and is always correct. ``tail_tokens_computed``/``prefill_tokens_saved``
    count the split; ``admitted_log`` carries per-admission tail tokens so
    fair-share schedulers charge the work actually done.

    Admission prefill scatters the prompt's private tail into freshly
    allocated pages, decode appends a page on demand (the host allocator
    tops slots up to ``len + chunk`` tokens before each chunk), and retire
    releases — so concurrency is bounded by TOTAL *deduplicated* TOKENS IN
    FLIGHT: co-resident streams carrying the same system prompt pay for it
    once, not once per stream; and prefix-hit TTFT drops with the tail
    fraction (see ``BENCH_serving.json#prefix.ttft``).

  * **admission prefill** — a joining request's prompt runs a single jitted
    prefill (LoRA applied, K/V quantized in-graph) and is scattered into its
    slot (dense: one ``dynamic_update_slice`` per cache leaf; paged: a page
    scatter into the allocated page ids, shared positions pointed at the
    trash page). Admission is **variable-length**: prompts are right-padded
    to the smallest of 2-3 *prompt-length buckets* (a static jit-cache
    key), while the TRUE length rides along as a traced operand — pad keys
    are masked out of attention, the cache ``len`` is per-row exact, and
    the first token comes from the last REAL prompt position. On a full
    pool, a paged ``join`` **defers** (pending queue drained as slots and
    pages free up) instead of raising — a burst of admissions beyond
    capacity queues and drains across chunks; the dense layout keeps the
    historical raise. The pending queue drains mostly-FIFO with a bounded
    lookahead (``pending_lookahead``): a small prompt may admit past a
    large head that free pages cannot yet cover, but only
    ``hol_skip_cap`` times in a row — then the head regains strict
    priority, so skip-ahead cannot starve it.

  * **chunked decode** — ``step_chunk`` advances ALL occupied slots ``chunk``
    tokens under one jitted ``lax.scan`` (device-resident sampling: one
    dispatch and one host sync per chunk, not per token), greedy by default
    with per-slot PRNG key state for temperature/top-k sampling. If the free
    list cannot cover a live stream's next chunk, the youngest live stream is
    **preempted**: its pages return to the pool and it re-queues with its
    generated prefix folded into the prompt (re-admission also refreshes its
    int8 scales). Memory-aware loop admission (``ServeLoop``) keeps a chunk
    of decode headroom per admit precisely so this path stays rare.

  * **cached SGMV metadata** — segment metadata for the S=1 token co-batch is
    built once per batch *composition* and reused every step; steady-state
    decode performs zero host-side sorts and zero recompiles: jits stay keyed
    on (slot bucket, adapter slot bucket, chunk) and
    (adapter slot bucket, prompt bucket) — page tables, true lengths and page
    ids are all TRACED operands, so join/leave churn and page allocation
    never retrace. The LoRA path per jit key follows
    ``PhysicalFM.resolve_lora_impl`` (gather vs segmented crossover;
    ``lora_impl="auto"`` is the server default).

int8 KV scale drift: dense-pool quantization scales are fixed ONCE at prefill
admission; decode-era K/V whose magnitude outgrows the prompt-era range are
clipped to ±127·scale and the dense engine never rescales a live slot. The
divergence this introduces is bounded and grows slowly with decode length:
empirically (``tests/test_decode_engine.py::test_int8_scale_drift_bounded``)
a decode tail 3× longer than the prompt whose K/V magnitude drifts to 3× the
admission-scale range keeps attention-output relative divergence under ~0.8
(vs ~0.06 with no drift), and at the model level a decode 4× the prompt
length keeps logit relative divergence under 0.5. The PAGED pool refreshes
**proactively**: the decode step tracks each slot's running decode-era
|K|/|V| maxima in the pool (``k_max``/``v_max``, traced — no extra
compiles), and when a slot's observed maximum exceeds
``scale_refresh`` × its admission range the engine re-quantizes the slot's
current tail page in place (codes rescaled old→new scale, both per-page and
slot running scales bumped, ``scale_refreshes`` counted) so SUBSEQUENT
tokens quantize into the drifted range instead of clipping against the
prompt-era one. The refresh bounds the FUTURE, not the past: codes clipped
before the drift first crossed the threshold stay clipped (int8 cannot be
un-clipped), so a drifting stream converges to the refreshed-layout bound
(~no-drift tolerance, see ``test_int8_scale_drift_bounded``) rather than
holding it from the first drifted token. Shared prefix pages are never
refresh targets (the tail page is always private). Dense decodes far beyond a ``max_new`` of a few
hundred tokens should either re-admit (prefill on the generated prefix
refreshes scales — the paged preemption path does exactly this) or use
``kv_quant=False``.

**Failure semantics.** Every stream leaves the engine through ``leave`` (the
single retire path: slot freed, pages refcount-released, registry entries
dropped when their last reference goes) with a terminal ``DecodeSlot.status``
(``core.request`` statuses); deferred joins that never reach a slot leave
through the ``rejected`` list instead. The exit paths:

  * ``ok`` — budget reached or EOS. Pages freed at retire; nothing refunded
    (the stream's chunks were real device work).
  * ``quarantined`` — the in-graph per-slot finite-logits flag (AND-reduced
    across the chunk inside the decode ``lax.scan``, synced with the chunk's
    tokens: zero extra D2H round trips, no new jit keys — the same pattern
    as the scale-drift flag) came back False, or the admission prefill's
    logits were non-finite. A poisoned stream (NaN'd adapter, Inf
    activations) retires at the END of its chunk; co-batched rows are
    per-slot independent (attention, LoRA and sampling are all row-local),
    so their token streams are bit-identical to a fault-free run. A
    quarantined ADMISSION never allocates pages, never writes the pool and
    never registers its prefix — NaN K/V cannot enter the COW registry.
  * ``deadline_cancelled`` — a live slot (or a preempted resume entry) ran
    past ``DecodeSlot.deadline``; marked done on chunk entry and retired
    through the normal sweep, partial tokens preserved.
  * ``deadline_shed`` — a deferred join expired in the pending queue before
    ever being admitted; no pages were held, nothing to free.
  * ``rejected_stranded`` — a stranded deferred join (its shared-prefix
    discount was released and it can never fit, see ``_viable_pending``)
    past its deadline, or force-shed by the serve loop's wedge recovery.
    Stranded entries WITHOUT a deadline still idle (a later re-registration
    can unstrand them); only a fully wedged engine raises.
  * ``cancelled`` — ``cancel(rid)`` unwound the stream wherever it lived:
    live slot (retired via ``leave``, pages freed), pending entry (popped,
    nothing held), or preempted resume (popped, pages already freed at
    preemption).

Admissions are recorded in ``admitted_log`` (drained by
``ServeLoop.take_admitted``-style callers) so schedulers can charge prompt
tokens when the prefill ACTUALLY runs — a request cancelled or shed while
deferred was never charged and cannot distort fair shares.

**Durability plane** (paged pool with ``spill_bytes > 0``): device-state
loss is allowed to cost time, never tokens. Three paths move KV state
across the device boundary, all digest-guarded:

  * *spill on eviction* — a preemption victim's pages, per-page scales,
    running drift trackers, last token and PRNG key are captured D2H into
    the bounded host arena (``core.spill.HostSpillArena``, LRU by bytes)
    before release; a registered prefix whose LAST sharer releases spills
    its pages the same way (keyed by the chained prefix digest) instead of
    evaporating. Over-budget entries are SKIPPED, not force-fit — the spill
    tier is an accelerator, losing it only costs a re-prefill.
  * *restore on re-entry* — a deferred resume whose spill entry survived
    restores by H2D page write-back (no re-prefill, exact token AND
    sampling parity: ``spill_resumes``/``resume_costs``); a joining prompt
    whose prefix chain lives only in the spill arena restores those pages
    and re-registers them (``spill_prefix_hits``). The pending gate sizes
    a spill-backed resume by its TRUE restored page count (spill-entry
    meta), not its admission bucket, so the restore and its re-prefill
    fallback are both viable at admission time.
  * *snapshot/restore* — ``snapshot()`` captures the engine's full logical
    state (used pages D2H, slots, pending, registry, counters, PRNG keys)
    with a sha256 digest per page; ``restore()`` rebuilds a FRESH arena
    from it, verifying every page digest. ``reuse_jits_from`` shares the
    dead engine's jit caches (executables are code, not device state) so
    an in-process device reset is recompile-free; ``checkpoint.ckpt``
    round-trips the snapshot through disk for cross-process restores.

The digest contract on every path: bytes re-enter the arena only after
their sha256 matches what was stamped at capture. A mismatch increments
``digest_failures``, drops the entry (spill) or the page's registry entry
plus the mapping streams via requeue (snapshot), and the affected stream
falls back to lossless re-prefill from host-side tokens — corrupted
durable state can never surface as wrong tokens.

**Speculative plane** (``spec_k > 0``, paged pool only): each chunk scan
step commits UP TO ``spec_k + 1`` tokens per slot instead of exactly one,
with exact greedy parity. The moving parts:

  * *drafter* — prompt-lookup n-gram matching over the slot's OWN history
    (prompt + generated tokens), held in a device-resident buffer that the
    scan carry appends committed tokens to, so later steps in the same
    chunk draft from tokens committed moments earlier. No draft model, no
    host round-trip mid-scan. A slot with no bigram match (or a free slot)
    proposes the out-of-vocab sentinel ``FILL = vocab_size``, which can
    never match — that step degrades to exactly today's one-token step.
  * *verify* — ONE batched forward (``lm.verify_step``, T = k + 1
    positions) through the existing paged cache scores the pending token
    plus all drafts; ``models.attention.self_attention_verify`` replicates
    the sequential per-token quantize/stamp walk bit-exactly (same scale
    selection a one-token step would make at each position) and stacks a
    positionwise running-max so rollback can gather any commit point.
  * *acceptance* — per-slot, inside the scan carry: the longest draft
    prefix matching the backbone's own (argmax) output is committed plus
    one corrected token (``m`` in [1, k+1]); a mixed co-batch never
    serializes on its slowest stream. Greedy output is BIT-IDENTICAL to
    the non-speculative engine (keys untouched); sampled mode commits
    exact per-step conditionals but advances the PRNG stream faster —
    documented approximate, not bit-reproducible against sequential.
  * *rollback* — speculative KV writes past the reject point are undone by
    resetting ``len`` and the drift trackers to the commit point's running
    values (pages past true_len are decode-private — never freed); the
    allocator provisions ``chunk * (k + 1)`` tokens of page headroom per
    live stream (``_headroom_tokens``) so the in-flight window always has
    pages.
  * *adaptivity* — ``_spec_dispatch_now`` demotes to the plain decode fn
    when the accept EMA drops below ``spec_disable_below`` tokens per
    slot-step (``spec_fallbacks``) and re-probes after
    ``spec_probe_every`` plain chunks. Probes are single-step (the
    chunk-1 spec executable from the warmed ladder — about one extra
    plain dispatch per probe instead of a verify-width chunk) and dry
    probes back the interval off exponentially (capped at 16x), so a
    zero-overlap adversarial trace pays a vanishing probe tax while a
    workload turning self-similar is still re-detected.
    ``warm_speculative`` precompiles the spec fn over the chunk ladder,
    so mode flips and deadline clamps never recompile in steady state.
    Jit keys: ("spec", slots, adapter capacity, chunk, k).
  * *parity discipline* — the device page table every non-speculative
    plane sees keeps the spec_k=0 width; the speculative headroom
    columns ride separately (``_spec_cols``) and are concatenated back
    in-graph only inside the spec executable. XLA specializes on input
    shapes, so this is what keeps every plain-plane executable — and
    therefore every committed int8 KV code — bit-identical to a
    spec_k=0 engine's.
  * *accounting* — ``draft_proposed`` / ``draft_accepted`` /
    ``spec_dispatches`` / ``spec_commits`` count the plane;
    ``take_decode_charges`` drains per-task COMMITTED token counts so
    fair-share scheduling bills real throughput, and
    ``spec_task_accept_rates`` exposes per-task accept-rate gauges.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_manager import (CachePlan, StateSlotPool,
                                      capture_dense_state,
                                      restore_dense_state)
from repro.core.physical import PAD_SENTINEL, PhysicalFM, bucket_for
from repro.core.spill import EngineSnapshot, HostSpillArena
from repro.kernels import ops
from repro.models import lm

FREE = PAD_SENTINEL   # free-slot adapter sentinel (same as run_batch padding)
TRASH_PAGE = 0        # arena page absorbing free-slot garbage writes


def default_prompt_buckets(prompt_len: int) -> tuple[int, ...]:
    """2-3 admission buckets: quarter, half and full ``prompt_len`` (deduped,
    ascending). Small enough that every bucket's prefill executable warms
    quickly; coarse enough that steady state never recompiles."""
    return tuple(sorted({max(1, prompt_len // 4),
                         max(1, prompt_len // 2), prompt_len}))


def make_sampler(temperature: float, top_k: int):
    """Token sampler used inside the jitted prefill/decode graphs.

    ``sample(logits (B, V), keys (B, 2) uint32) -> (tokens (B,), keys')``.
    Greedy when ``temperature <= 0`` (keys pass through untouched); otherwise
    temperature-scaled categorical over the top-k logits, one PRNG key per
    row so co-batched streams sample independently."""
    if temperature <= 0:
        def sample(logits, keys):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
        return sample

    def sample(logits, keys):
        split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
        next_keys, use_keys = split[:, 0], split[:, 1]
        l = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jax.lax.top_k(l, top_k)[0][:, -1]
            l = jnp.where(l >= kth[:, None], l, -1e30)
        toks = jax.vmap(jax.random.categorical)(use_keys, l)
        return toks.astype(jnp.int32), next_keys
    return sample


@dataclasses.dataclass
class DecodeSlot:
    """One occupied decode stream."""
    rid: int
    task_id: str
    adapter_slot: int
    max_new: int
    eos_id: Optional[int]
    tokens: list          # generated token ids (first one from prefill)
    t_join: float
    t_first: float        # wall time of the first generated token (TTFT end)
    prompt_tokens: int = 0   # TRUE (post-truncation) admitted prompt length
    done: bool = False
    prompt: Optional[np.ndarray] = None   # admitted prompt (paged: requeue)
    adapter_id: Optional[str] = None
    deadline: float = float("inf")        # wall-clock cancel point (inf: none)
    status: str = "ok"                    # terminal status (core.request)
    enc_feats: Optional[np.ndarray] = None   # enc-dec: encoder input frames


@dataclasses.dataclass
class _PendingJoin:
    """A deferred admission (paged pool full) waiting in the FIFO queue."""
    task_id: str
    prompt: np.ndarray
    adapter_id: Optional[str]
    max_new_tokens: int
    rid: int
    eos_id: Optional[int]
    resume: Optional[DecodeSlot] = None   # preempted stream being re-admitted
    deadline: float = float("inf")
    status: str = "ok"                    # stamped when rejected terminally
    enc_feats: Optional[np.ndarray] = None   # enc-dec: encoder input frames


class DecodeEngine:
    """Slot-based continuous-batching token server bound to one PhysicalFM."""

    def __init__(self, fm: PhysicalFM, *, num_slots: int = 8,
                 prompt_len: Optional[int] = None, max_new: int = 32,
                 chunk: int = 4, kv_quant: bool = True,
                 eos_id: Optional[int] = None,
                 prompt_buckets: Optional[tuple] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, paged: bool = False,
                 page_size: int = 16, total_pages: Optional[int] = None,
                 prefix_sharing: bool = True, scale_refresh: float = 2.0,
                 pending_lookahead: int = 4, hol_skip_cap: int = 4,
                 spill_bytes: int = 0,
                 spill_arena: Optional[HostSpillArena] = None,
                 deadline_clamp: bool = True,
                 chunked_prefill: bool = True,
                 spec_k: int = 0, spec_force_fill: bool = False,
                 spec_disable_below: float = 1.25,
                 spec_probe_every: int = 16,
                 enc_len: Optional[int] = None):
        cfg = fm.cfg
        assert cfg.vocab_size > 0 and not cfg.is_representation, \
            "DecodeEngine serves generative decoder LMs (vocab head required)"
        self.fm = fm
        self.cfg = cfg
        self.num_slots = bucket_for(num_slots)
        self.prompt_len = prompt_len or fm.input_len
        # per-sublayer cache plan (core.cache_manager): which sublayers page
        # into the shared int8 arena, which carry fixed-size per-slot state
        # (recurrent conv/SSM/LSTM state, encoder-output cross K/V), and
        # which serving planes the stack supports. Capabilities negotiate —
        # unsupported planes demote cleanly instead of crashing mid-serve.
        self.plan = CachePlan.for_config(cfg, paged)
        if paged and not self.plan.paged:
            warnings.warn(
                "paged=True on a stack with no attention sublayers: the "
                "whole serving state is fixed-size per-slot state, nothing "
                "to page — running the dense slot pool", RuntimeWarning,
                stacklevel=2)
            paged = False
        # admission is variable-length for EVERY stack: attention masks pads
        # out of its K/V, and the recurrent scans are length-aware (dt zeroed
        # / state carried through right-pad positions — models.mamba,
        # models.xlstm), so hybrids share the bucketed prefill path
        self.var_len = True
        # enc-dec: per-slot encoder-output cross K/V rides in the pool as
        # fixed-size state (enc_len frames per slot, written at admission)
        self.enc_len = int(enc_len) if enc_len is not None else \
            (self.prompt_len if cfg.is_encoder_decoder else 0)
        if prompt_buckets is None:
            prompt_buckets = default_prompt_buckets(self.prompt_len)
        self.prompt_buckets = tuple(sorted(set(int(b) for b in prompt_buckets)))
        self.prompt_len = self.prompt_buckets[-1]   # largest bucket is the cap
        self.max_new = max_new
        self.chunk = chunk
        self.kv_quant = kv_quant
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample = make_sampler(self.temperature, self.top_k)
        # per-slot PRNG key state; threaded through the decode scan carry
        self._keys = jax.random.split(jax.random.PRNGKey(sample_seed),
                                      self.num_slots)
        self.s_max = self.prompt_len + max_new + 1
        self.spec_k = int(spec_k)
        if self.spec_k > 0 and not self.plan.speculative_ok and \
                (self.plan.has_recurrent or self.plan.has_encoder):
            warnings.warn(
                "spec_k > 0 demoted to plain decode: speculative rollback is "
                "a length/tracker reset on paged attention KV only — "
                "recurrent state cannot rewind past rejected drafts, and the "
                "verify forward has no encoder-decoder mode", RuntimeWarning,
                stacklevel=2)
            self.spec_k = 0
        if self.spec_k > 0 and not paged:
            raise ValueError("speculative decoding (spec_k > 0) requires "
                             "paged=True: speculative KV rollback relies on "
                             "decode-private pages past true_len")
        # speculative in-flight window: the device length can run up to one
        # chunk of (k+1)-token steps past a slot's nominal maximum before
        # the host-side done check truncates, so the per-slot page table
        # (and the arena sizing derived from it) must cover those targets
        spec_room = self.chunk * (self.spec_k + 1) if self.spec_k > 0 else 0
        self.paged = paged
        if paged:
            assert kv_quant, "the paged arena is int8-only (kv_quant=True)"
            self.page_size = page_size
            self.pages_per_slot = -(-(self.s_max + spec_room) // page_size)
            if total_pages is None:        # dense-equivalent memory + trash
                total_pages = 1 + self.num_slots * self.pages_per_slot
            assert total_pages >= 2, "need at least one usable page"
            self.total_pages = total_pages
            self.pool = lm.init_cache(cfg, self.num_slots,
                                      self.s_max + spec_room,
                                      kv_quant=True, paged=True,
                                      page_size=page_size,
                                      num_pages=total_pages,
                                      enc_len=self.enc_len or None)
            # bit-exact parity contract: the DEVICE page table every
            # non-speculative plane sees keeps the spec_k=0 width. XLA
            # specializes executables on input shapes, so a table widened
            # by the speculative headroom would recompile the plain
            # decode/prefill/tail planes into reduction orders that differ
            # from a spec_k=0 engine's — float drift that occasionally
            # flips an int8 quantization tie and, many dispatches later, a
            # greedy argmax. The headroom columns ride separately in
            # ``_spec_cols`` and only the speculative dispatch (its own
            # executable regardless) concatenates them back in-graph.
            self._plain_pages = -(-self.s_max // page_size)
            for sub in self.pool:
                if isinstance(sub, dict) and "page_table" in sub:
                    sub["page_table"] = \
                        sub["page_table"][..., :self._plain_pages]
            self._spec_cols: list = []
            # host-side allocator state; the device page table is synced
            # from _ptab before any decode dispatch that follows a change
            self._free_pages = list(range(total_pages - 1, TRASH_PAGE, -1))
            self._ptab = np.zeros((self.num_slots, self.pages_per_slot),
                                  np.int32)
            self._held = np.zeros((self.num_slots,), np.int64)
            self._lens = np.zeros((self.num_slots,), np.int64)
            self._ptab_dirty = True
            self.pending: collections.deque[_PendingJoin] = collections.deque()
            self.deferrals = 0
            self.preemptions = 0
            # refcounted ownership + COW prefix sharing (module docstring).
            # Capability-gated: shared pages capture attention KV only, and
            # a recurrent sublayer's state at the shared-prefix boundary is
            # stream-private — on hybrid / enc-dec stacks sharing demotes
            # silently to plain (per-stream) admission.
            self.prefix_sharing = bool(prefix_sharing) and \
                self.plan.prefix_sharing_ok
            self._page_refs = np.zeros((total_pages,), np.int32)
            self._prefix_registry: dict[tuple, int] = {}   # key -> page id
            self._page_key: dict[int, tuple] = {}          # page id -> key
            self.prefix_hits = 0            # joins that mapped >= 1 page
            self.shared_pages_mapped = 0    # cumulative pages mapped, not copied
            # chunked shared-prefix prefill (module docstring): a join whose
            # prompt maps >= 1 registered (or spilled) page prefills ONLY its
            # private tail. Tail lengths bucket separately from prompt
            # lengths (powers of two of the page size) so sharer churn stays
            # zero-recompile. Registered prefix pages keep a host-side FLOAT
            # sidecar (the float prefill K/V the page was quantized from) so
            # the tail attends the SAME values a full prefill would have —
            # exact token parity; a page whose sidecar is gone (post-reset
            # restore, spill-resume re-registration) is attended dequantized
            # from its int8 arena content instead, trading ~0.4% K/V error
            # for keeping the TTFT win.
            self.chunked_prefill = bool(chunked_prefill) and self.prefix_sharing
            self._page_float: dict[int, list] = {}   # page id -> float K/V
            # assembled float-prefix operands memoized per mapped page-id
            # tuple: sharers of one prefix reuse ONE host assembly + H2D
            # upload; entries die with any constituent page (_release_pages)
            self._prefix_fp_cache: dict[tuple, list] = {}
            self._prefix_width = self._pages_for(self.prompt_len)
            tb = {min(page_size, self.prompt_len)}
            b = page_size
            while b < self.prompt_len:
                b *= 2
                tb.add(min(b, self.prompt_len))
            self.tail_buckets = tuple(sorted(tb))
            # proactive int8 scale refresh (module docstring, drift section)
            self.scale_refresh = float(scale_refresh)
            self.scale_refreshes = 0
            self._jit_rescale = None
            # bounded pending-queue lookahead (head-of-line fix)
            self.pending_lookahead = max(1, int(pending_lookahead))
            self.hol_skip_cap = max(1, int(hol_skip_cap))
            self._hol_skips = 0
            self.hol_bypasses = 0
            # host-RAM spill tier (module docstring, durability section):
            # preemption victims and last-sharer prefix evictions spill D2H
            # instead of being destroyed; resume/re-join restore by H2D copy
            self.spill = spill_arena if spill_arena is not None else (
                HostSpillArena(spill_bytes) if spill_bytes > 0 else None)
            if self.spill is not None and not self.plan.spill_resume_ok:
                warnings.warn(
                    "spill tier demoted: the stream spill captures pages + "
                    "quantization trackers only, not per-slot dense state "
                    "(recurrent / encoder) — preemption falls back to the "
                    "lossless fold-and-re-prefill path", RuntimeWarning,
                    stacklevel=2)
                self.spill = None
        else:
            self.spill = None
            self.chunked_prefill = False    # needs the paged arena
            # the persistent pool: allocated once, updated in place (donated)
            self.pool = lm.init_cache(cfg, self.num_slots, self.s_max,
                                      kv_quant=kv_quant,
                                      enc_len=self.enc_len or None)
            self.pending = collections.deque()
        # fixed-size per-slot state lifecycle (core.cache_manager): one state
        # slot per live stream, allocated at admission, freed on every exit
        # path (retire / preempt / cancel / quarantine). The tensors live in
        # self.pool (batch axis == slot); this tracks lifecycle + gauges and
        # feeds the hybrid admission gate.
        self.state_pool = StateSlotPool(self.num_slots) \
            if self.plan.needs_state_slots else None
        self._tokens = jnp.zeros((self.num_slots,), jnp.int32)  # last token/slot
        self.slots: list[Optional[DecodeSlot]] = [None] * self.num_slots
        self._slot_adapters = np.full((self.num_slots,), FREE, np.int32)
        self._jit_prefill: dict[tuple, Callable] = {}
        self._jit_decode: dict[tuple, Callable] = {}
        self._jit_write: dict = {}      # dense: {None: fn}; paged: {npages: fn}
        self._seg_key = None        # composition signature of cached metadata
        self._seg_dev = None        # device-uploaded (perm, inv, blocks)
        self.steps = 0              # decode steps executed (all slots)
        self.last_chunk_s = 0.0
        # failure-semantics state (module docstring, failure section)
        self.rejected: list[_PendingJoin] = []   # terminally rejected joins
        # (rid, task, true_prompt_len, tail_tokens): tail_tokens is what the
        # prefill ACTUALLY computed — schedulers charge it, not true_len
        self.admitted_log: list[tuple[int, str, int, int]] = []
        self.admissions = 0          # streams admitted into slots (ever)
        self.tail_tokens_computed = 0   # prompt tokens actually prefilled
        self.prefill_tokens_saved = 0   # prompt tokens skipped (prefix mapped)
        self.quarantines = 0         # streams retired on non-finite logits
        self.deadline_cancels = 0    # mid-flight (slot/resume) expirations
        self.deadline_sheds = 0      # pending entries expired unadmitted
        self.stranded_rejections = 0  # stranded entries terminally rejected
        self.cancels = 0             # client cancel() unwinds
        # durability-layer state (spill tier + snapshot/restore)
        self.spilled_pages = 0       # pages captured D2H into the host arena
        self.restored_pages = 0      # pages restored H2D from the host arena
        self.digest_failures = 0     # corrupted spill/snapshot pages detected
        self.spill_resumes = 0       # preempted streams resumed without prefill
        self.spill_prefix_hits = 0   # joins that restored >= 1 spilled prefix page
        self.resume_costs: list[tuple[str, float]] = []  # ("spill"|"reprefill", s)
        self._jit_gather = None       # padded fixed-width D2H page capture
        self._jit_page_restore = None  # padded H2D page write-back
        self._jit_slot_restore = None  # per-slot scale/len write-back
        # deadline overrun clamp: EMA of per-token decode seconds, used to
        # shrink the next chunk to a ladder size when a live deadline is
        # nearer than a full chunk (satellite; see step_chunk)
        self.deadline_clamp = bool(deadline_clamp)
        self._step_ema = 0.0
        self.deadline_clamps = 0     # chunks shortened by the clamp
        # self-speculative decode plane (module docstring, speculation
        # section): device-resident n-gram drafter + one batched verify
        # forward per scan step; paged-only (rollback is a length/tracker
        # reset over decode-private pages). spec_k itself parses above,
        # before the arena sizing it feeds.
        self.spec_force_fill = bool(spec_force_fill)
        self.spec_disable_below = float(spec_disable_below)
        self.spec_probe_every = max(1, int(spec_probe_every))
        # history buffer bound: prompt + generated tokens never exceed
        # s_max, plus one dispatch's worst-case in-flight growth (chunk
        # scan steps x up to k+1 commits each)
        self._spec_hist_len = self.s_max + self.chunk * (self.spec_k + 1)
        self._spec_seg_key = None    # composition signature (spec metadata)
        self._spec_seg_dev = None
        self._spec_accept_ema = 0.0  # committed tokens per slot-step (EMA)
        self._spec_cool = 0          # plain dispatches since the last probe
        # re-probe cadence with exponential backoff: a dry probe (nothing
        # accepted) doubles the interval up to 16x the base, so a
        # sustained zero-overlap workload pays an asymptotically vanishing
        # probe tax while a workload that turns self-similar again is
        # still re-detected within a bounded number of dispatches
        self._spec_probe_interval = self.spec_probe_every
        self._spec_probe = False     # current spec dispatch is a probe
        self._spec_task_stats: dict = {}   # task -> [proposed, accepted]
        self._decode_charges: collections.Counter = collections.Counter()
        self.draft_proposed = 0      # draft tokens sent to verification
        self.draft_accepted = 0      # draft tokens committed
        self.spec_dispatches = 0     # chunk dispatches through the spec fn
        self.spec_commits = 0        # tokens committed by spec dispatches
        self.spec_fallbacks = 0      # dispatches demoted to the plain fn

    # ---- occupancy ----
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def pending_count(self) -> int:
        return len(self.pending)

    def pending_rids(self) -> list[int]:
        return [p.rid for p in self.pending]

    def pending_task_ids(self) -> list[str]:
        return [p.task_id for p in self.pending]

    def compile_count(self) -> int:
        """Total jitted executables (prefill + decode + pool writes); steady
        state across request join/leave churn must not grow this."""
        fns = (list(self._jit_prefill.values()) +
               list(self._jit_decode.values()) +
               list(self._jit_write.values()))
        for name in ("_jit_rescale", "_jit_gather", "_jit_page_restore",
                     "_jit_slot_restore"):
            if getattr(self, name, None) is not None:
                fns.append(getattr(self, name))
        return sum(f._cache_size() if hasattr(f, "_cache_size") else 1
                   for f in fns)

    # ---- page accounting (paged layout) ----
    def free_page_count(self) -> int:
        return len(self._free_pages) if self.paged else 0

    def used_page_count(self) -> int:
        if not self.paged:
            return 0
        return (self.total_pages - 1) - len(self._free_pages)

    def page_occupancy(self) -> float:
        """Fraction of usable (non-trash) pages held by streams."""
        if not self.paged:
            return 0.0
        return self.used_page_count() / max(self.total_pages - 1, 1)

    def _pages_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.page_size)

    def _headroom_tokens(self) -> int:
        """Decode headroom the allocator provisions per live stream per
        chunk: ``chunk`` tokens, or ``chunk * (spec_k + 1)`` when the
        speculative plane is configured — each scan step may commit up to
        ``k + 1`` tokens, so page topping / admission gates budget the
        worst case.  Static on ``spec_k`` (never the adaptive spec/plain
        demotion state): dispatch mode can flip between chunks, and the
        provisioning must hold either way."""
        return self.chunk * (self.spec_k + 1 if self.spec_k > 0 else 1)

    def shared_page_count(self) -> int:
        """Physical pages currently mapped by more than one stream."""
        return int((self._page_refs > 1).sum()) if self.paged else 0

    def dedup_saved_pages(self) -> int:
        """Pages prefix sharing is saving RIGHT NOW: logical mappings minus
        physical pages (Σ max(refcount - 1, 0))."""
        if not self.paged:
            return 0
        return int(np.maximum(self._page_refs - 1, 0).sum())

    def logical_page_count(self) -> int:
        """Total page-table mappings across live slots — what the streams
        would hold physically without prefix sharing."""
        return int(self._held.sum()) if self.paged else 0

    def _imminent_page_need(self) -> int:
        """Pages the LIVE streams will allocate for their next chunk — the
        watermark an admission must clear on top of its own need, so letting
        one more stream in doesn't immediately preempt a running one."""
        need = 0
        for i, s in enumerate(self.slots):
            if s is not None and not s.done:
                need += max(0, self._pages_for(self._lens[i]
                                               + self._headroom_tokens())
                            - self._held[i])
        return need

    def _admission_need(self, prompt_tokens: int, prompt=None,
                        adapter_id: Optional[str] = None) -> int:
        """Free pages an admission must find: the prompt's bucket worth of
        pages MINUS the pages its prefix would share (known only when the
        prompt content is provided), plus a chunk of decode headroom for the
        new stream and for every live one."""
        plen = self.bucket_for_prompt(min(max(prompt_tokens, 1),
                                          self.prompt_len))
        shared = len(self._match_prefix(adapter_id, prompt)) \
            if prompt is not None else 0
        return (self._pages_for(self._adm_s_max(plen)) - shared
                + self._pages_for(self._headroom_tokens())
                + self._imminent_page_need())

    def can_admit(self, prompt_tokens: Optional[int] = None, *,
                  prompt=None, adapter_id: Optional[str] = None) -> bool:
        """Would an admission of a ``prompt_tokens``-token prompt proceed
        right now? Dense: a free slot. Paged: a free slot, nothing already
        deferred ahead of it (FIFO), and free pages covering the prompt's
        admission bucket PLUS a chunk of decode headroom for this stream AND
        for every live one — the memory-aware gate ``ServeLoop`` consults
        before dispatching a prefill. Deliberately conservative by one chunk
        per live stream: over-admitting converts into preemptions, which
        redo prefill work and can truncate long streams.

        The paged gate REQUIRES the prompt length (or the prompt itself) —
        a silent 1-token default once let callers consult the memory gate
        with a wildly low estimate and over-admit. Passing ``prompt``
        (token ids) additionally lets the gate DISCOUNT the pages a shared
        prefix would map instead of allocate; ``adapter_id`` keys the
        prefix registry lookup (LoRA'd V differs per adapter)."""
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32).reshape(-1)
            if prompt_tokens is None:
                prompt_tokens = len(prompt)
        if self.paged and prompt_tokens is None:
            raise TypeError(
                "can_admit on a paged pool requires prompt_tokens (or "
                "prompt=): the memory gate cannot size an admission from "
                "a default 1-token estimate")
        if not self.free_slots():
            return False
        if self.state_pool is not None and self.state_pool.available() <= 0:
            # hybrid/enc-dec gate: admission needs a fixed-size state slot
            # alongside the decode slot (1:1 today, but counted separately
            # so the invariant — and the deferral gauge — is explicit)
            self.state_pool.note_deferral()
            return False
        if not self.paged:
            return True
        if self.pending:
            return False
        return len(self._free_pages) >= self._admission_need(
            prompt_tokens, prompt=prompt, adapter_id=adapter_id)

    # ---- refcounted page allocator + prefix registry (paged layout) ----
    def _take_pages(self, n: int) -> np.ndarray:
        assert len(self._free_pages) >= n
        pages = np.array([self._free_pages.pop() for _ in range(n)], np.int32)
        self._page_refs[pages] = 1
        return pages

    def _share_pages(self, pages):
        for p in pages:
            self._page_refs[int(p)] += 1

    def _release_pages(self, pages):
        """Drop one reference per page; pages whose refcount reaches zero
        return to the free list and fall out of the prefix registry. With a
        spill arena attached, registered pages losing their LAST sharer are
        captured D2H (keyed by their chained digest) before the id is
        recycled — the prefix survives the idle gap in host RAM. The
        capture happens before any later allocation can rewrite the page;
        within this call the device content is still intact."""
        spillable = []
        freed = set()
        for p in pages:
            p = int(p)
            r = self._page_refs[p] = self._page_refs[p] - 1
            assert r >= 0, f"double free of page {p}"
            if r == 0:
                self._free_pages.append(p)
                freed.add(p)
                key = self._page_key.pop(p, None)
                if key is not None and self._prefix_registry.get(key) == p:
                    del self._prefix_registry[key]
                    if self.spill is not None:
                        # the float sidecar rides into the spill blob
                        spillable.append((p, key))
                        continue
                self._page_float.pop(p, None)
        if freed and self._prefix_fp_cache:
            # a freed id may be recycled with new content: drop every
            # assembled-prefix operand that referenced it
            self._prefix_fp_cache = {
                k: v for k, v in self._prefix_fp_cache.items()
                if not freed.intersection(k)}
        if spillable:
            self._spill_prefix_pages(spillable)

    def _release_slot_pages(self, slot: int):
        self._release_pages(self._ptab[slot, :self._held[slot]])
        self._ptab[slot] = TRASH_PAGE
        self._held[slot] = 0
        self._lens[slot] = 0
        self._ptab_dirty = True

    def _prefix_keys(self, adapter_id: Optional[str],
                     prompt: np.ndarray) -> list[bytes]:
        """One registry key per full page of ``prompt``: a CHAINED sha256
        digest (key_j = H(key_{j-1} || page_j bytes), seeded with the
        adapter identity), so key material and hashing stay O(prompt
        bytes) total — not O(pages × prefix) — while a digest still
        commits to the ENTIRE prefix up to its page. 256-bit collisions
        are negligible against page-mapping corruption."""
        import hashlib
        h = hashlib.sha256(b"\x00" if adapter_id is None
                           else b"\x01" + adapter_id.encode())
        ps = self.page_size
        keys = []
        for j in range(len(prompt) // ps):
            h.update(prompt[j * ps:(j + 1) * ps].tobytes())
            keys.append(h.digest())
        return keys

    def _match_prefix(self, adapter_id: Optional[str], prompt) -> list[int]:
        """Arena page ids of the longest registered page-aligned prefix of
        ``prompt`` under ``adapter_id`` (LoRA changes V, so prefixes only
        match within one adapter). Empty when sharing is off."""
        if not (self.paged and self.prefix_sharing) or prompt is None:
            return []
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.prompt_len:       # join() left-truncates
            prompt = prompt[-self.prompt_len:]
        shared = []
        for key in self._prefix_keys(adapter_id, prompt):
            p = self._prefix_registry.get(key)
            if p is None:
                break
            shared.append(p)
        return shared

    def _register_prefix(self, adapter_id: Optional[str], prompt: np.ndarray,
                         slot: int, true_len: int, cache=None,
                         cache_page0: int = 0):
        """Publish the slot's FULL prompt pages (the only immutable ones —
        decode never writes below ``true_len``) for future joins to map.
        An existing registration for the same prefix wins (first writer);
        the duplicate page stays private to this slot.

        ``cache`` (chunked prefill only): the admission's FLOAT prefill
        cache, whose page ``j - cache_page0`` holds the exact pre-quantized
        K/V of registered page ``j``. Winning registrations stash that slice
        host-side (``_page_float``) so future sharers' tails can attend the
        SAME float values a full prefill would have seen — exact token
        parity instead of the int8 arena's ~0.4% dequantization error."""
        if not self.prefix_sharing:
            return
        keys = self._prefix_keys(adapter_id, prompt[:true_len])
        stash = []
        for j, key in enumerate(keys):
            page = int(self._ptab[slot, j])
            if self._prefix_registry.setdefault(key, page) == page:
                self._page_key[page] = key
                if (self.chunked_prefill and cache is not None
                        and j >= cache_page0
                        and page not in self._page_float):
                    stash.append((j - cache_page0, page))
        if stash:
            # one D2H pull of the whole admission cache, then numpy page
            # slices: per-page device reads would sync once per page
            ps = self.page_size
            host = [{"k": np.asarray(csub["k"][:, 0]),
                     "v": np.asarray(csub["v"][:, 0])}
                    for csub, psub in zip(cache, self.pool)
                    if isinstance(psub, dict) and "page_table" in psub]
            for rel, page in stash:
                self._page_float[page] = [
                    {"k": sub["k"][:, rel * ps:(rel + 1) * ps].copy(),
                     "v": sub["v"][:, rel * ps:(rel + 1) * ps].copy()}
                    for sub in host]

    def _sync_page_table(self):
        """Push the host page table to every attention sublayer's device
        leaf. Values-only: the (num_slots, pages_per_slot) shape is static,
        so syncing never retraces."""
        if not self._ptab_dirty:
            return
        self._spec_cols = []
        for sub in self.pool:
            if isinstance(sub, dict) and "page_table" in sub:
                nper = sub["page_table"].shape[0]
                full = np.broadcast_to(self._ptab[None],
                                       (nper,) + self._ptab.shape)
                sub["page_table"] = jnp.asarray(
                    full[..., :self._plain_pages])
                # speculative headroom columns (empty at spec_k=0); the
                # spec dispatch concatenates these behind the plain-width
                # table in-graph — see the ctor's parity note
                self._spec_cols.append(
                    jnp.asarray(full[..., self._plain_pages:]))
        self._ptab_dirty = False

    # ---- host-RAM spill tier (paged layout) ----
    def _paged_subs(self):
        return [sub for sub in self.pool
                if isinstance(sub, dict) and "page_table" in sub]

    def _gather_fn(self):
        """D2H capture of up to ``pages_per_slot`` pages plus one slot's
        running scales/drift trackers in ONE dispatch. The page-id vector is
        padded to the fixed width with the trash page, so the gather
        compiles exactly once — spill traffic never retraces."""
        if self._jit_gather is None:
            def gather(pool, page_idx, slot):
                out = []
                for sub in pool:
                    if not (isinstance(sub, dict) and "page_table" in sub):
                        continue
                    out.append({
                        "k": sub["k"][:, page_idx],
                        "v": sub["v"][:, page_idx],
                        "k_scale": sub["k_scale"][:, page_idx],
                        "v_scale": sub["v_scale"][:, page_idx],
                        "slot_k_scale": sub["slot_k_scale"][:, slot],
                        "slot_v_scale": sub["slot_v_scale"][:, slot],
                        "k_max": sub["k_max"][:, slot],
                        "v_max": sub["v_max"][:, slot],
                    })
                return out
            self._jit_gather = jax.jit(gather)
        return self._jit_gather

    def _page_restore_fn(self):
        """H2D write-back of up to ``pages_per_slot`` pages' int8 codes and
        per-page scales. Padded page ids point at the trash page (whose
        content is garbage by contract), so duplicate trash writes from the
        padding are harmless and the write compiles exactly once."""
        if self._jit_page_restore is None:
            donate = self._donate(0)

            def write(pool, data, page_idx):
                out, i = [], 0
                for sub in pool:
                    if not (isinstance(sub, dict) and "page_table" in sub):
                        out.append(sub)
                        continue
                    d_, i = data[i], i + 1
                    d = dict(sub)
                    d["k"] = sub["k"].at[:, page_idx].set(d_["k"])
                    d["v"] = sub["v"].at[:, page_idx].set(d_["v"])
                    d["k_scale"] = sub["k_scale"].at[:, page_idx].set(
                        d_["k_scale"])
                    d["v_scale"] = sub["v_scale"].at[:, page_idx].set(
                        d_["v_scale"])
                    out.append(d)
                return out

            self._jit_page_restore = jax.jit(write, donate_argnums=donate)
        return self._jit_page_restore

    def _slot_restore_fn(self):
        """H2D write-back of one slot's running scales, drift trackers and
        true length — the second half of a spill resume."""
        if self._jit_slot_restore is None:
            donate = self._donate(0)

            def write(pool, state, slot, true_len):
                out, i = [], 0
                for sub in pool:
                    if not (isinstance(sub, dict) and "page_table" in sub):
                        out.append(sub)
                        continue
                    st, i = state[i], i + 1
                    d = dict(sub)
                    d["slot_k_scale"] = sub["slot_k_scale"].at[:, slot].set(
                        st["slot_k_scale"])
                    d["slot_v_scale"] = sub["slot_v_scale"].at[:, slot].set(
                        st["slot_v_scale"])
                    d["k_max"] = sub["k_max"].at[:, slot].set(st["k_max"])
                    d["v_max"] = sub["v_max"].at[:, slot].set(st["v_max"])
                    d["len"] = sub["len"].at[:, slot].set(true_len)
                    out.append(d)
                return out

            self._jit_slot_restore = jax.jit(write, donate_argnums=donate)
        return self._jit_slot_restore

    def _capture_pages(self, pages: np.ndarray, slot: int) -> list:
        """Pull ``pages`` (and ``slot``'s running state) to host arrays:
        one padded gather dispatch, one host sync."""
        n = len(pages)
        idx = np.full((self.pages_per_slot,), TRASH_PAGE, np.int32)
        idx[:n] = pages
        dev = self._gather_fn()(self.pool, jnp.asarray(idx), jnp.int32(slot))
        host = []
        for sub in jax.device_get(dev):      # one transfer for the whole blob
            host.append({
                "k": np.asarray(sub["k"][:, :n]),
                "v": np.asarray(sub["v"][:, :n]),
                "k_scale": np.asarray(sub["k_scale"][:, :n]),
                "v_scale": np.asarray(sub["v_scale"][:, :n]),
                "slot_k_scale": np.asarray(sub["slot_k_scale"]),
                "slot_v_scale": np.asarray(sub["slot_v_scale"]),
                "k_max": np.asarray(sub["k_max"]),
                "v_max": np.asarray(sub["v_max"]),
            })
        return host

    def _restore_pages(self, blob: list, pages: np.ndarray):
        """Write captured page content back into arena pages ``pages``
        (freshly allocated — possibly different ids than at capture)."""
        n = len(pages)
        W = self.pages_per_slot
        idx = np.full((W,), TRASH_PAGE, np.int32)
        idx[:n] = pages
        data = []
        for sub in blob:
            d = {}
            for k in ("k", "v", "k_scale", "v_scale"):
                a = np.asarray(sub[k])
                pad = np.zeros((a.shape[0], W) + a.shape[2:], a.dtype)
                pad[:, :n] = a[:, :n]
                d[k] = pad
            data.append(d)
        self.pool = self._page_restore_fn()(self.pool, data,
                                            jnp.asarray(idx))

    def _spill_stream(self, slot: int, s: DecodeSlot):
        """Capture a preemption victim's full KV state D2H before its pages
        are released: pages + scales + drift trackers + last token + PRNG
        key. Resume restores all of it — no re-prefill, no re-quantization,
        exact token AND sampling parity with a never-preempted run."""
        n = int(self._held[slot])
        if n == 0:
            return
        pages = self._ptab[slot, :n]
        blob = self._capture_pages(pages, slot)
        meta = {
            "n_pages": n,
            "true_len": int(self._lens[slot]),
            "last_token": int(np.asarray(self._tokens[slot])),
            "key": np.asarray(self._keys[slot]),
        }
        if self.spill.put(("stream", s.rid), blob, meta):
            self.spilled_pages += n

    def _drop_stream_spill(self, rid: int):
        if self.spill is not None:
            self.spill.pop(("stream", rid))

    def _try_spill_resume(self, req: _PendingJoin) -> Optional[int]:
        """Resume a preempted stream from its host-RAM spill: allocate fresh
        pages, H2D-restore its int8 codes/scales/trackers/PRNG key, rebuild
        the page table and re-register its prefix — skipping the re-prefill
        entirely. Returns the slot, or None to fall back to re-prefill
        (spill missing/evicted, digest mismatch, or not enough free pages
        for the exact restored length)."""
        entry = self.spill.get(("stream", req.rid))
        if entry is None:
            return None
        if not entry.verify():
            self.spill.pop(("stream", req.rid))
            self.digest_failures += 1
            return None
        n = int(entry.meta["n_pages"])
        if len(self._free_pages) < n or not self.free_slots():
            return None
        t0 = time.perf_counter()
        self.spill.pop(("stream", req.rid))
        s = req.resume
        slot = self.free_slots()[0]
        pages = self._take_pages(n)
        true_len = int(entry.meta["true_len"])
        self._restore_pages(entry.blob, pages)
        state = [{k: sub[k] for k in ("slot_k_scale", "slot_v_scale",
                                      "k_max", "v_max")}
                 for sub in entry.blob]
        self.pool = self._slot_restore_fn()(self.pool, state,
                                            jnp.int32(slot),
                                            jnp.int32(true_len))
        self._ptab[slot, :n] = pages
        self._held[slot] = n
        self._lens[slot] = true_len
        self._ptab_dirty = True
        self._tokens = self._tokens.at[slot].set(
            jnp.int32(int(entry.meta["last_token"])))
        self._keys = self._keys.at[slot].set(
            jnp.asarray(entry.meta["key"]))
        self.slots[slot] = s
        aslot = self.fm.adapters.index(req.adapter_id)
        self._slot_adapters[slot] = aslot
        self._seg_key = None
        # restored prompt pages sit at their original page-table positions,
        # so re-registration republishes the prefix for future sharers
        if s.prompt is not None:
            self._register_prefix(s.adapter_id, np.asarray(s.prompt),
                                  slot, s.prompt_tokens)
        self.admissions += 1      # progress signal (watchdog); not re-logged
        self.spill_resumes += 1
        self.restored_pages += n
        self.resume_costs.append(("spill", time.perf_counter() - t0))
        return slot

    def _spill_prefix_pages(self, pairs: list):
        """Capture last-sharer prefix pages D2H as they leave the registry,
        keyed by their chained digest — a later join whose prompt chain
        reaches the digest restores them by DMA instead of recompute."""
        pages = np.array([p for p, _ in pairs], np.int32)
        blob = self._capture_pages(pages, 0)    # slot state ignored
        for j, (p, key) in enumerate(pairs):
            per_page = [{k: sub[k][:, j:j + 1]
                         for k in ("k", "v", "k_scale", "v_scale")}
                        for sub in blob]
            # the float sidecar spills WITH the page, so a restored prefix
            # keeps serving exact-parity chunked tails ("kf"/"vf" keys are
            # ignored by _restore_pages, which only writes the arena keys)
            fp = self._page_float.pop(p, None)
            if fp is not None:
                for sub, f in zip(per_page, fp):
                    sub["kf"] = f["k"]
                    sub["vf"] = f["v"]
            if self.spill.put(("prefix", key), per_page, {}):
                self.spilled_pages += 1

    def _match_spilled_prefix(self, adapter_id: Optional[str], prompt,
                              skip: int) -> list[tuple[bytes, object]]:
        """Continue a prompt's digest chain past the live registry into the
        spill arena: (key, entry) pairs for consecutive spilled full pages
        starting at page index ``skip``. A digest mismatch ends the chain
        (the corrupt entry is dropped and counted)."""
        if self.spill is None or not (self.paged and self.prefix_sharing) \
                or prompt is None:
            return []
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) > self.prompt_len:
            prompt = prompt[-self.prompt_len:]
        out = []
        for j, key in enumerate(self._prefix_keys(adapter_id, prompt)):
            if j < skip:
                continue
            entry = self.spill.get(("prefix", key))
            if entry is None:
                break
            if not entry.verify():
                self.spill.pop(("prefix", key))
                self.digest_failures += 1
                break
            out.append((key, entry))
        return out

    # ---- jitted planes ----
    @staticmethod
    def _donate(*argnums):
        return argnums if jax.default_backend() != "cpu" else ()

    def _impl(self, rows: int, cap: int) -> str:
        """LoRA path for a ``rows``-row co-batch. Resolved from the slot
        bucket (not the live adapter count) so the choice is stable within
        each compiled (rows, cap) jit key."""
        return self.fm.resolve_lora_impl(rows, num_adapters=cap)

    def _adm_s_max(self, plen: int) -> int:
        """Admission-prefill cache length for one prompt bucket: the paged
        scatter needs a whole number of pages; dense scatters into s_max."""
        if self.paged:
            return self._pages_for(plen) * self.page_size
        return self.s_max

    def _prefill_fn(self, cap: int, plen: int):
        """Admission prefill for one prompt-length bucket. The bucket length
        is a static jit key; the TRUE prompt length is a traced operand, so
        every length within the bucket reuses the executable."""
        key = (cap, plen)
        if key not in self._jit_prefill:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(1, cap)
            # paged admission keeps the prefill K/V in float: the page
            # scatter quantizes PER PAGE (a page's scale depends only on
            # the tokens it covers, so shared prefix pages are bit-exact
            # across streams); the dense scatter stores the in-graph
            # per-row quantization unchanged
            s_max, kvq, sample = self._adm_s_max(plen), \
                self.kv_quant and not self.paged, self._sample
            enc_len = self.enc_len

            @jax.jit
            def run(params, tokens, true_len, enc_embeds, rng_key, lora_stack,
                    adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                cache = lm.init_cache(cfg, 1, s_max, kv_quant=kvq,
                                      enc_len=enc_len or None)
                logits, cache = lm.prefill(
                    params, cfg, tokens=tokens, cache=cache, lora=lora_stack,
                    adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg,
                    seq_lens=true_len, enc_embeds=enc_embeds)
                first, rng_key = sample(logits, rng_key)
                # numeric-health flag rides the admission's existing host
                # sync: a non-finite prefill quarantines at admission, before
                # any page allocation or prefix registration
                return first, lm.finite_logits(logits), rng_key, cache

            self._jit_prefill[key] = run
        return self._jit_prefill[key]

    def _tail_prefill_fn(self, cap: int, tlen: int, mode: str = "float"):
        """Chunked shared-prefix admission prefill for one TAIL bucket: run
        the model over only the prompt's private tail, with the tail's
        queries attending the already-mapped prefix pages in front of the
        tail's own fresh K/V. Two prefix sources, same attention plumbing:

          * ``mode="float"`` — the prefix K/V arrive as an explicit operand
            assembled host-side from the pages' float sidecars
            (``_page_float`` / spilled ``kf``/``vf``). These are the EXACT
            pre-quantization values a full prefill would have computed, so
            the tail's logits (and cache) are bit-identical to the full
            path's — exact token parity for sharer joins.
          * ``mode="pages"`` — the prefix is gathered from the int8 arena
            through the prefix page vector and dequantized per page
            (``ops.gather_prefix_kv``). Fallback for pages whose sidecar is
            gone (engine restored from a device-reset snapshot, prefix
            re-registered by a spill resume): keeps the TTFT win at ~0.4%
            K/V error.

        True prefix length and tail length are traced operands — which
        pages a sharer maps never retraces; only the tail BUCKET (and the
        mode) is a jit key."""
        key = ("tail", cap, tlen, mode)
        if key not in self._jit_prefill:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(1, cap)
            # like the full paged admission, the tail cache stays FLOAT: the
            # tail-page scatter quantizes per page afterwards
            s_max = self._pages_for(tlen) * self.page_size
            sample = self._sample
            # which pool entries are paged attention sublayers is static —
            # the float variant takes its prefix operand without the pool
            paged_mask = [isinstance(sub, dict) and "page_table" in sub
                          for sub in self.pool]

            def body(params, prefix, tokens, tail_len, prefix_len, rng_key,
                     lora_stack, adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                cache = lm.init_cache(cfg, 1, s_max, kv_quant=False)
                # absolute positions: RoPE must see the tail at its true
                # offset behind the prefix
                pos = prefix_len[:, None] + jnp.arange(tokens.shape[1])[None]
                logits, cache = lm.prefill(
                    params, cfg, tokens=tokens, cache=cache, lora=lora_stack,
                    adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg,
                    seq_lens=tail_len, pos=pos, prefix=prefix,
                    prefix_len=prefix_len)
                first, rng_key = sample(logits, rng_key)
                return first, lm.finite_logits(logits), rng_key, cache

            if mode == "float":
                @jax.jit
                def run(params, prefix_fp, tokens, tail_len, prefix_len,
                        rng_key, lora_stack, adapter_idx, perm, inv, blocks):
                    it = iter(prefix_fp)
                    prefix = [next(it) if paged else None
                              for paged in paged_mask]
                    return body(params, prefix, tokens, tail_len, prefix_len,
                                rng_key, lora_stack, adapter_idx, perm, inv,
                                blocks)
            else:
                @jax.jit
                def run(params, pool, tokens, tail_len, prefix_pages,
                        prefix_len, rng_key, lora_stack, adapter_idx, perm,
                        inv, blocks):
                    # dequantized prefix K/V per attention sublayer, gathered
                    # from the arena through the explicit prefix page vector
                    # (positions past prefix_len point at the trash page and
                    # are masked out of attention by the validity mask)
                    prefix = []
                    for sub in pool:
                        if isinstance(sub, dict) and "page_table" in sub:
                            gk, gv = jax.vmap(
                                lambda kp, vp, ks, vs: ops.gather_prefix_kv(
                                    kp, vp, ks, vs, prefix_pages[None]))(
                                sub["k"], sub["v"],
                                sub["k_scale"], sub["v_scale"])
                            prefix.append({"k": gk, "v": gv})
                        else:
                            prefix.append(None)
                    return body(params, prefix, tokens, tail_len, prefix_len,
                                rng_key, lora_stack, adapter_idx, perm, inv,
                                blocks)

            self._jit_prefill[key] = run
        return self._jit_prefill[key]

    def _write_fn(self):
        """Dense admission scatter: one dynamic_update_slice per cache leaf
        along the slot (batch) axis."""
        if None not in self._jit_write:
            donate = self._donate(0)

            def write(pool, cache, slot):
                # every cache leaf is (nper, batch, ...): scatter the one-row
                # prefill cache into the pool's slot along the batch axis
                return jax.tree.map(
                    lambda p, c: jax.lax.dynamic_update_slice_in_dim(
                        p, c.astype(p.dtype), slot, axis=1), pool, cache)

            self._jit_write[None] = jax.jit(write, donate_argnums=donate)
        return self._jit_write[None]

    def _paged_write_fn(self, npages: int):
        """Paged admission scatter for one prompt bucket (``npages`` pages):
        the one-row FLOAT prefill cache reshapes into pages, each page is
        quantized with its own per-(page, kv-head) scale (a pure function of
        the tokens the page covers — the property that makes shared prefix
        pages bit-exact across streams), and pages scatter into the arena at
        the allocated page ids. Shared prefix positions arrive pointed at
        the trash page, so their (identical) content is simply discarded.
        The slot's running scales are set to the prompt-wide maximum (the
        admission range decode appends quantize into), the drift trackers
        reset, and ``len`` is set to the TRUE prompt length. Page ids, slot
        and length are traced operands — allocation and sharing churn never
        retrace."""
        if npages not in self._jit_write:
            donate = self._donate(0)
            ps = self.page_size

            def write(pool, cache, slot, page_idx, true_len):
                out = []
                for psub, csub in zip(pool, cache):
                    if not (isinstance(psub, dict) and "page_table" in psub):
                        # fixed-size per-slot state (recurrent sublayers):
                        # the one-row prefill state scatters into the slot
                        # along the batch axis, same contract as the dense
                        # pool's _write_fn — no paging, no quantization
                        if isinstance(psub, dict):
                            out.append({
                                kk: jax.lax.dynamic_update_slice_in_dim(
                                    psub[kk], csub[kk].astype(psub[kk].dtype),
                                    slot, axis=1)
                                for kk in psub})
                        else:
                            out.append(psub)
                        continue
                    kf = csub["k"][:, 0].astype(jnp.float32)  # (nper,S,kv,hd)
                    nper, _, kv, hd = kf.shape
                    kf = kf.reshape(nper, npages, ps, kv, hd)
                    vf = csub["v"][:, 0].astype(jnp.float32).reshape(
                        nper, npages, ps, kv, hd)
                    kmax = jnp.max(jnp.abs(kf), axis=(2, 4))  # (nper,np,kv)
                    vmax = jnp.max(jnp.abs(vf), axis=(2, 4))
                    ks = kmax / 127.0       # 0 for empty (pad-only) pages
                    vs = vmax / 127.0
                    # slot scales = prompt-wide max: identical to the dense
                    # per-row quantization range (kernels.quantize_kv)
                    slot_ks = jnp.maximum(jnp.max(kmax, axis=1), 1e-8) / 127.0
                    slot_vs = jnp.maximum(jnp.max(vmax, axis=1), 1e-8) / 127.0
                    # the prompt/decode BOUNDARY page (the partial page
                    # decode will keep appending into) is stamped at the
                    # slot-wide scale, not its prompt-local one: a partial
                    # page holding a few small-magnitude prompt tokens must
                    # not clip the stream's normal-range decode K/V. Still a
                    # pure function of the prompt (slot scale is), and never
                    # a shared page (sharing stops at the last FULL page).
                    sel = (jnp.arange(npages) == true_len // ps)[None, :,
                                                                 None]
                    ks = jnp.where(sel, slot_ks[:, None, :], ks)
                    vs = jnp.where(sel, slot_vs[:, None, :], vs)
                    kq = jnp.clip(jnp.round(
                        kf / jnp.maximum(ks, 1e-12)[:, :, None, :, None]),
                        -127, 127).astype(psub["k"].dtype)
                    vq = jnp.clip(jnp.round(
                        vf / jnp.maximum(vs, 1e-12)[:, :, None, :, None]),
                        -127, 127).astype(psub["v"].dtype)
                    d = dict(psub)
                    d["k"] = psub["k"].at[:, page_idx].set(kq)
                    d["v"] = psub["v"].at[:, page_idx].set(vq)
                    d["k_scale"] = psub["k_scale"].at[:, page_idx].set(ks)
                    d["v_scale"] = psub["v_scale"].at[:, page_idx].set(vs)
                    d["slot_k_scale"] = psub["slot_k_scale"].at[:, slot].set(
                        slot_ks)
                    d["slot_v_scale"] = psub["slot_v_scale"].at[:, slot].set(
                        slot_vs)
                    d["k_max"] = psub["k_max"].at[:, slot].set(0.0)
                    d["v_max"] = psub["v_max"].at[:, slot].set(0.0)
                    d["len"] = psub["len"].at[:, slot].set(true_len)
                    for cc in ("ck", "cv"):
                        # enc-dec: fixed-size encoder-output K/V sidecars
                        # ride beside the paged arena, one row per slot
                        if cc in psub:
                            d[cc] = jax.lax.dynamic_update_slice_in_dim(
                                psub[cc], csub[cc].astype(psub[cc].dtype),
                                slot, axis=1)
                    out.append(d)
                return out

            self._jit_write[npages] = jax.jit(write, donate_argnums=donate)
        return self._jit_write[npages]

    def _paged_tail_write_fn(self, npages: int):
        """Page scatter for a chunked (tail-only) admission: quantize the
        tail's float cache per page exactly like ``_paged_write_fn``, but

          * the prompt/decode boundary page index is a TRACED operand
            (``boundary = true_len // page_size - skip``, relative to the
            tail's first page — out of range when the prompt is
            page-aligned, exactly like the full path's), and
          * the slot-wide running scales fold in the mapped prefix pages'
            stamped scales. A registered full page's scale IS its own
            |K|max/127 (it is never the boundary page), and max-then-divide
            equals divide-then-max for a positive constant, so the combined
            slot scale is bit-identical to what a full prefill over the
            whole prompt would have computed.
        """
        key = ("tail", npages)
        if key not in self._jit_write:
            donate = self._donate(0)
            ps = self.page_size

            def write(pool, cache, slot, page_idx, true_len, boundary,
                      prefix_pages, prefix_np):
                out = []
                W = prefix_pages.shape[0]
                pmask = (jnp.arange(W) < prefix_np)[None, :, None]
                for psub, csub in zip(pool, cache):
                    kf = csub["k"][:, 0].astype(jnp.float32)  # (nper,S,kv,hd)
                    nper, _, kv, hd = kf.shape
                    kf = kf.reshape(nper, npages, ps, kv, hd)
                    vf = csub["v"][:, 0].astype(jnp.float32).reshape(
                        nper, npages, ps, kv, hd)
                    kmax = jnp.max(jnp.abs(kf), axis=(2, 4))  # (nper,np,kv)
                    vmax = jnp.max(jnp.abs(vf), axis=(2, 4))
                    ks = kmax / 127.0
                    vs = vmax / 127.0
                    # prefix page scales (trash-padded entries masked to 0)
                    pks = jnp.where(pmask, psub["k_scale"][:, prefix_pages],
                                    0.0)
                    pvs = jnp.where(pmask, psub["v_scale"][:, prefix_pages],
                                    0.0)
                    slot_ks = jnp.maximum(
                        jnp.maximum(jnp.max(kmax, axis=1), 1e-8) / 127.0,
                        jnp.max(pks, axis=1))
                    slot_vs = jnp.maximum(
                        jnp.maximum(jnp.max(vmax, axis=1), 1e-8) / 127.0,
                        jnp.max(pvs, axis=1))
                    sel = (jnp.arange(npages) == boundary)[None, :, None]
                    ks = jnp.where(sel, slot_ks[:, None, :], ks)
                    vs = jnp.where(sel, slot_vs[:, None, :], vs)
                    kq = jnp.clip(jnp.round(
                        kf / jnp.maximum(ks, 1e-12)[:, :, None, :, None]),
                        -127, 127).astype(psub["k"].dtype)
                    vq = jnp.clip(jnp.round(
                        vf / jnp.maximum(vs, 1e-12)[:, :, None, :, None]),
                        -127, 127).astype(psub["v"].dtype)
                    d = dict(psub)
                    d["k"] = psub["k"].at[:, page_idx].set(kq)
                    d["v"] = psub["v"].at[:, page_idx].set(vq)
                    d["k_scale"] = psub["k_scale"].at[:, page_idx].set(ks)
                    d["v_scale"] = psub["v_scale"].at[:, page_idx].set(vs)
                    d["slot_k_scale"] = psub["slot_k_scale"].at[:, slot].set(
                        slot_ks)
                    d["slot_v_scale"] = psub["slot_v_scale"].at[:, slot].set(
                        slot_vs)
                    d["k_max"] = psub["k_max"].at[:, slot].set(0.0)
                    d["v_max"] = psub["v_max"].at[:, slot].set(0.0)
                    d["len"] = psub["len"].at[:, slot].set(true_len)
                    out.append(d)
                return out

            self._jit_write[key] = jax.jit(write, donate_argnums=donate)
        return self._jit_write[key]

    def _rescale_fn(self):
        """Proactive per-page scale refresh for ONE (slot, tail page): bump
        the page and slot scales to cover the slot's observed decode-era
        |K|/|V| maxima (with 10% headroom) and rewrite the page's int8 codes
        from the old scale into the new one. Slot and page are traced — the
        refresh compiles once, ever."""
        if self._jit_rescale is None:
            donate = self._donate(0)
            margin = 1.1 / 127.0

            def rescale(pool, slot, page):
                out = []
                for sub in pool:
                    if not (isinstance(sub, dict) and "k_max" in sub):
                        out.append(sub)     # fixed-size state: no scales
                        continue
                    km = sub["k_max"][:, slot] * margin       # (nper, kv)
                    vm = sub["v_max"][:, slot] * margin
                    old_ks = sub["k_scale"][:, page]
                    old_vs = sub["v_scale"][:, page]
                    new_ks = jnp.maximum(old_ks, km)
                    new_vs = jnp.maximum(old_vs, vm)
                    rk = jnp.where(new_ks > 0,
                                   old_ks / jnp.maximum(new_ks, 1e-12), 1.0)
                    rv = jnp.where(new_vs > 0,
                                   old_vs / jnp.maximum(new_vs, 1e-12), 1.0)
                    kp = jnp.round(sub["k"][:, page].astype(jnp.float32)
                                   * rk[:, None, :, None])
                    vp = jnp.round(sub["v"][:, page].astype(jnp.float32)
                                   * rv[:, None, :, None])
                    d = dict(sub)
                    d["k"] = sub["k"].at[:, page].set(
                        kp.astype(sub["k"].dtype))
                    d["v"] = sub["v"].at[:, page].set(
                        vp.astype(sub["v"].dtype))
                    d["k_scale"] = sub["k_scale"].at[:, page].set(new_ks)
                    d["v_scale"] = sub["v_scale"].at[:, page].set(new_vs)
                    d["slot_k_scale"] = sub["slot_k_scale"].at[:, slot].set(
                        jnp.maximum(sub["slot_k_scale"][:, slot], km))
                    d["slot_v_scale"] = sub["slot_v_scale"].at[:, slot].set(
                        jnp.maximum(sub["slot_v_scale"][:, slot], vm))
                    out.append(d)
                return out

            self._jit_rescale = jax.jit(rescale, donate_argnums=donate)
        return self._jit_rescale

    def _maybe_refresh_scales(self, over):
        """Refresh the (always private) tail page of every slot the chunk's
        in-graph drift check flagged: its decode-era |K|/|V| maxima exceeded
        ``scale_refresh`` × the admission range."""
        if not self.paged or self.scale_refresh <= 0 or not over.any():
            return
        for i in np.nonzero(over)[0]:
            s = self.slots[i]
            # only decode-era tokens drift, and only a slot that has decoded
            # past its prompt has a PRIVATE tail page to rewrite — shared
            # prefix pages are never refresh targets
            if s is None or s.done or self._lens[i] <= s.prompt_tokens:
                continue
            page = int(self._ptab[i, (self._lens[i] - 1) // self.page_size])
            self.pool = self._rescale_fn()(self.pool, jnp.int32(int(i)),
                                           jnp.int32(page))
            self.scale_refreshes += 1

    def _decode_fn(self, cap: int, chunk: int):
        key = (self.num_slots, cap, chunk)
        if key not in self._jit_decode:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(self.num_slots, cap)
            donate = self._donate(1)
            # drift detection rides the chunk: the over-threshold flag is
            # computed in-graph from the post-chunk trackers and synced
            # with the tokens — the steady-state path never does extra
            # host round-trips just to learn nothing drifted
            refresh_thr = self.scale_refresh * 127.0 \
                if self.paged and self.scale_refresh > 0 else None
            nslots = self.num_slots

            sample = self._sample

            def run(params, pool, tokens, keys, lora_stack, adapter_idx,
                    perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}

                def body(carry, _):
                    # the per-slot finite flag AND-accumulates through the
                    # scan carry (logits are per-step values — unlike the
                    # drift trackers they cannot be read back post-scan), so
                    # a single NaN step anywhere in the chunk quarantines the
                    # stream; it is a traced OUTPUT synced with the chunk's
                    # tokens — no extra D2H syncs, no new jit keys
                    pool, tok, keys, fin = carry
                    logits, pool = lm.decode_step(
                        params, cfg, tokens=tok, cache=pool, lora=lora_stack,
                        adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg)
                    fin = fin & lm.finite_logits(logits)
                    nxt, keys = sample(logits, keys)
                    return (pool, nxt, keys, fin), nxt

                fin0 = jnp.ones((nslots,), jnp.bool_)
                (pool, tok, keys, fin), out = jax.lax.scan(
                    body, (pool, tokens, keys, fin0), None, length=chunk)
                drift = jnp.zeros((nslots,), jnp.bool_)
                if refresh_thr is not None:
                    for sub in pool:
                        if isinstance(sub, dict) and "k_max" in sub:
                            o = (sub["k_max"] > refresh_thr * jnp.maximum(
                                    sub["slot_k_scale"], 1e-8)) | \
                                (sub["v_max"] > refresh_thr * jnp.maximum(
                                    sub["slot_v_scale"], 1e-8))
                            drift = drift | jnp.any(o, axis=(0, 2))
                return pool, tok, keys, out.T, drift, fin    # (slots, chunk)

            self._jit_decode[key] = jax.jit(run, donate_argnums=donate)
        return self._jit_decode[key]

    def _spec_decode_fn(self, cap: int, chunk: int):
        """Self-speculative chunk dispatch (module docstring, speculation
        section): ``chunk`` draft -> verify -> accept steps under ONE jitted
        ``lax.scan``.  Each step drafts up to ``spec_k`` tokens per slot
        from that slot's own device-resident history (prompt-lookup bigram
        match — no draft model), scores all ``k + 1`` window positions in a
        single batched ``lm.verify_step`` forward through the paged cache,
        accepts the longest draft prefix that matches what the backbone
        itself emits, commits the accepted run plus one corrected token,
        and rolls the speculative KV writes past the reject point back by
        resetting ``len`` and the drift trackers (pages past true_len are
        decode-private — never freed, simply overwritten next step).

        Keyed ``("spec", num_slots, cap, chunk, k)`` in the same executable
        cache as the plain decode fns, so restore/compile_count cover it
        for free.  Per-slot acceptance lives INSIDE the scan carry: a mixed
        co-batch never serializes on its slowest stream, and a zero-accept
        slot degrades to exactly today's one-token step."""
        k = self.spec_k
        key = ("spec", self.num_slots, cap, chunk, k)
        if key not in self._jit_decode:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(self.num_slots, cap)
            donate = self._donate(1)
            refresh_thr = self.scale_refresh * 127.0 \
                if self.paged and self.scale_refresh > 0 else None
            nslots = self.num_slots
            T = k + 1
            # draft sentinel: one past the vocab.  The embed gather clips it
            # to a valid row (harmless garbage compute) and neither argmax
            # nor sampling can ever RETURN it, so a filled position never
            # matches and the step commits exactly one token — the plain
            # decode step, bit for bit.
            FILL = cfg.vocab_size
            force_fill = self.spec_force_fill
            sample = self._sample
            H = self._spec_hist_len
            plain_w = self._plain_pages
            bidx = jnp.arange(nslots)

            def run(params, pool, tokens, keys, hist, hlen, spec_cols,
                    lora_stack, adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                # widen the page tables with the speculative headroom
                # columns (the pool carries the plain-width table so every
                # non-spec plane compiles bit-identically to a spec_k=0
                # engine); sliced back off before returning
                widened, ci = [], 0
                for sub in pool:
                    if isinstance(sub, dict) and "page_table" in sub:
                        sub = dict(sub)
                        sub["page_table"] = jnp.concatenate(
                            [sub["page_table"], spec_cols[ci]], axis=-1)
                        ci += 1
                    widened.append(sub)
                pool = widened

                def draft_fn(tok, hist, hlen):
                    # prompt-lookup drafter: find the LATEST earlier
                    # occurrence of the current (prev, tok) bigram in the
                    # slot's history and propose the k tokens that followed
                    # it.  Pure in-graph gather/compare — runs under the
                    # scan so later steps draft from tokens committed
                    # earlier in the SAME chunk.
                    if force_fill:
                        return jnp.full((nslots, k), FILL, jnp.int32), \
                            jnp.zeros((nslots,), jnp.int32)
                    prev = jnp.take_along_axis(
                        hist, jnp.maximum(hlen - 2, 0)[:, None], axis=1)[:, 0]
                    mt = (hist[:, :-1] == prev[:, None]) \
                        & (hist[:, 1:] == tok[:, None]) \
                        & (jnp.arange(H - 1)[None] + 1 < (hlen - 1)[:, None])
                    has = jnp.any(mt, axis=1)
                    jbest = (H - 2) - jnp.argmax(mt[:, ::-1], axis=1)
                    src = jbest[:, None] + 2 + jnp.arange(k)[None]
                    cand = jnp.take_along_axis(
                        hist, jnp.minimum(src, H - 1), axis=1)
                    valid = has[:, None] & (src < hlen[:, None])
                    draft = jnp.where(valid, cand.astype(jnp.int32),
                                      jnp.int32(FILL))
                    return draft, jnp.sum(valid.astype(jnp.int32), axis=1)

                def body(carry, _):
                    pool, tok, keys, hist, hlen, fin = carry
                    draft, nprop = draft_fn(tok, hist, hlen)
                    seq = jnp.concatenate([tok[:, None], draft], axis=1)
                    logits, pool = lm.verify_step(
                        params, cfg, tokens=seq, cache=pool, lora=lora_stack,
                        adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg)
                    # per-position sampling: greedy consumes no PRNG (keys
                    # pass through untouched — bit-exact vs the sequential
                    # engine); sampled mode advances each row's key once per
                    # WINDOW position, so its PRNG stream diverges from the
                    # non-speculative engine's (documented approximate: each
                    # committed token is still an exact conditional sample)
                    ts = []
                    for j in range(T):
                        t_j, keys = sample(logits[:, j], keys)
                        ts.append(t_j)
                    g = jnp.stack(ts, axis=1)                      # (B, T)
                    match = (draft == g[:, :k]).astype(jnp.int32)
                    m = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                    nxt = jnp.take_along_axis(g, (m - 1)[:, None],
                                              axis=1)[:, 0]
                    # quarantine only on COMMITTED positions: the rejected
                    # tail conditions on wrong tokens and its logits are
                    # discarded anyway
                    fin_pos = lm.finite_logits(logits)             # (B, T)
                    fin = fin & jnp.all(
                        fin_pos | (jnp.arange(T)[None] >= m[:, None]), axis=1)
                    # rollback = tracker reset: len and the drift maxima
                    # rewind to the commit point's running values (the
                    # verify layer stacked a positionwise cummax for exactly
                    # this gather); int8 codes/scales past the rolled-back
                    # len sit above it where the next write overwrites them.
                    # The cmax stacks are STRIPPED so the carry pytree keeps
                    # the plain pool structure across scan steps.
                    rolled = []
                    for sub in pool:
                        if isinstance(sub, dict) and "k_cmax" in sub:
                            d = dict(sub)
                            selm = jnp.broadcast_to(
                                (m - 1)[None, :, None, None],
                                sub["k_cmax"].shape[:2] + (1,)
                                + sub["k_cmax"].shape[3:])
                            d["k_max"] = jnp.take_along_axis(
                                sub["k_cmax"], selm, axis=2)[:, :, 0]
                            d["v_max"] = jnp.take_along_axis(
                                sub["v_cmax"], selm, axis=2)[:, :, 0]
                            d["len"] = sub["len"] - T + m[None, :]
                            del d["k_cmax"], d["v_cmax"]
                            rolled.append(d)
                        else:
                            rolled.append(sub)
                    # committed tokens append to the device history so later
                    # scan steps draft from them; uncommitted columns
                    # scatter out of bounds and drop
                    wpos = jnp.where(jnp.arange(T)[None] < m[:, None],
                                     hlen[:, None] + jnp.arange(T)[None], H)
                    hist = hist.at[bidx[:, None], wpos].set(g, mode="drop")
                    hlen = hlen + m
                    return (rolled, nxt, keys, hist, hlen, fin), (g, m, nprop)

                fin0 = jnp.ones((nslots,), jnp.bool_)
                (pool, tok, keys, hist, hlen, fin), (gs, ms, ps) = \
                    jax.lax.scan(body, (pool, tokens, keys, hist, hlen, fin0),
                                 None, length=chunk)
                drift = jnp.zeros((nslots,), jnp.bool_)
                if refresh_thr is not None:
                    for sub in pool:
                        if isinstance(sub, dict) and "k_max" in sub:
                            o = (sub["k_max"] > refresh_thr * jnp.maximum(
                                    sub["slot_k_scale"], 1e-8)) | \
                                (sub["v_max"] > refresh_thr * jnp.maximum(
                                    sub["slot_v_scale"], 1e-8))
                            drift = drift | jnp.any(o, axis=(0, 2))
                narrowed = []
                for sub in pool:
                    if isinstance(sub, dict) and "page_table" in sub:
                        sub = dict(sub)
                        sub["page_table"] = sub["page_table"][..., :plain_w]
                        narrowed.append(sub)
                    else:
                        narrowed.append(sub)
                pool = narrowed
                # gs: (slots, chunk, T) committed-candidate tokens;
                # ms/ps: (slots, chunk) commit / proposal counts per step
                return (pool, tok, keys, gs.transpose(1, 0, 2), ms.T, ps.T,
                        drift, fin)

            self._jit_decode[key] = jax.jit(run, donate_argnums=donate)
        return self._jit_decode[key]

    def _spec_history(self):
        """Host-side build of the per-slot (history, length) pair the
        speculative drafter reads on device: prompt + generated tokens,
        right-padded to ``_spec_hist_len``; position ``hlen - 1`` holds the
        pending token (``_tokens``).  Rebuilt per dispatch — the device
        copy mutates inside the scan and is deliberately discarded (host
        state stays the single source of truth across preempt/spill)."""
        H = self._spec_hist_len
        hist = np.zeros((self.num_slots, H), np.int32)
        hlen = np.zeros((self.num_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            seq = np.concatenate([
                np.asarray(s.prompt, np.int64).reshape(-1),
                np.asarray(s.tokens, np.int64).reshape(-1),
            ]).astype(np.int32)[-H:]
            hist[i, :len(seq)] = seq
            hlen[i] = len(seq)
        return jnp.asarray(hist), jnp.asarray(hlen)

    def _spec_dispatch_now(self) -> bool:
        """Adaptive spec/plain demotion: keep speculating while the accept
        EMA clears ``spec_disable_below`` committed tokens per slot-step;
        below it, demote to the plain fn (counted in ``spec_fallbacks``)
        and re-probe speculatively after ``spec_probe_every`` plain
        dispatches so a workload that turns self-similar again is
        re-detected.  Probes are cheap by construction: they clamp to a
        ONE-step chunk (the chunk-1 spec executable is already in the
        warmed ladder) so a dry probe costs about one extra plain
        dispatch instead of a full verify-width chunk, and consecutive
        dry probes back the re-probe interval off exponentially (capped
        at 16x) so a sustained adversarial trace pays a vanishing probe
        tax.  Both executables are warmed, so flipping modes never
        recompiles."""
        self._spec_probe = False
        if self.spec_k <= 0 or not self.paged:
            return False
        if self._spec_accept_ema == 0.0 \
                or self._spec_accept_ema >= self.spec_disable_below:
            self._spec_cool = 0
            self._spec_probe_interval = self.spec_probe_every
            return True
        self._spec_cool += 1
        if self._spec_cool >= self._spec_probe_interval:
            self._spec_cool = 0
            self._spec_probe = True
            return True
        self.spec_fallbacks += 1
        return False

    # ---- segment metadata (per composition, not per token) ----
    def _segments(self, cap: int):
        if self._impl(self.num_slots, cap) != "segmented":
            z = jnp.zeros((1,), jnp.int32)      # gather never reads these
            return z, z, z
        key = (self._slot_adapters.tobytes(), cap)
        if key != self._seg_key:
            perm, inv, blocks = self.fm.segment_meta(self._slot_adapters, cap, 1)
            self._seg_dev = (jnp.asarray(perm), jnp.asarray(inv),
                             jnp.asarray(blocks))
            self._seg_key = key
        return self._seg_dev

    def _spec_segments(self, cap: int):
        """Segment metadata for the speculative verify co-batch: same
        composition as ``_segments`` but ``spec_k + 1`` tokens per slot row
        (the verify window flattens row-major, matching ``segment_meta``'s
        per-token repeat).  Memoized on the composition signature, so
        steady-state dispatches never touch the host metadata path."""
        if self._impl(self.num_slots, cap) != "segmented":
            z = jnp.zeros((1,), jnp.int32)      # gather never reads these
            return z, z, z
        key = (self._slot_adapters.tobytes(), cap)
        if key != self._spec_seg_key:
            perm, inv, blocks = self.fm.segment_meta(
                self._slot_adapters, cap, self.spec_k + 1)
            self._spec_seg_dev = (jnp.asarray(perm), jnp.asarray(inv),
                                  jnp.asarray(blocks))
            self._spec_seg_key = key
        return self._spec_seg_dev

    def _prefill_segments(self, adapter_slot: int, cap: int, plen: int):
        if self._impl(1, cap) != "segmented":
            z = jnp.zeros((1,), jnp.int32)
            return z, z, z
        ids = np.full((plen,), adapter_slot, np.int32)
        perm, inv, blocks = self.fm.segment_meta(ids, cap, 1)
        return jnp.asarray(perm), jnp.asarray(inv), jnp.asarray(blocks)

    def bucket_for_prompt(self, n: int) -> int:
        """Smallest admission bucket holding an n-token prompt."""
        for b in self.prompt_buckets:
            if n <= b:
                return b
        return self.prompt_buckets[-1]

    def bucket_for_tail(self, n: int) -> int:
        """Smallest tail bucket holding an n-token private tail (chunked
        shared-prefix admission). Tail buckets are powers of two of the
        page size, capped at ``prompt_len`` — a static jit key, so any mix
        of tail lengths across sharer churn reuses the same executables."""
        for b in self.tail_buckets:
            if n <= b:
                return b
        return self.tail_buckets[-1]

    # ---- serving surface ----
    def _norm_enc_feats(self, enc_feats) -> np.ndarray:
        """Normalize one stream's encoder input to the engine's fixed
        ``(enc_len, d_model)`` frame shape. ``None`` means zero frames (the
        stub-frontend analogue of silence) so decoder-only callers — the
        serve-loop warmup included — join without change. The encoder is
        bidirectional, so frame count is STRICT: zero-padding would change
        every encoder output, not just the tail."""
        d = self.cfg.d_model
        if enc_feats is None:
            return np.zeros((self.enc_len, d), np.float32)
        enc_feats = np.asarray(enc_feats, np.float32).reshape(-1, d)
        assert enc_feats.shape[0] == self.enc_len, \
            (f"enc_feats must carry exactly enc_len={self.enc_len} frames "
             f"(got {enc_feats.shape[0]}): the encoder is bidirectional, "
             f"padding is not transparent")
        return enc_feats

    def join(self, task_id: str, prompt: np.ndarray, *,
             adapter_id: Optional[str] = None, max_new_tokens: int = 8,
             rid: int = -1, eos_id: Optional[int] = None,
             deadline: Optional[float] = None,
             enc_feats: Optional[np.ndarray] = None) -> int:
        """Admit one request: prefill its prompt (LoRA applied, K/V int8-
        quantized in-graph), scatter it into a free slot (paged: into freshly
        allocated pages), produce the first token. Returns the slot index.

        A full pool behaves per layout: the dense pool raises (its capacity
        is the static slot count — the caller must drain first); the paged
        pool **defers** — the request queues FIFO and admits during a later
        ``step_chunk`` once a slot AND enough free pages exist — returning
        -1. Deferral, not failure: a burst beyond capacity drains instead of
        crashing the serving tick.

        Admission is variable-length: the prompt is right-padded to the
        smallest prompt-length bucket that holds it (a static jit key —
        at most ``len(prompt_buckets)`` prefill executables ever compile)
        while the true length is a traced operand masking the pads out of
        attention and the KV cache. Prompts longer than the largest bucket
        keep their LAST ``prompt_len`` tokens (causal LM: the suffix
        matters) — that loses context, so it WARNS; the decode budget clamps
        to the pool's ``max_new`` capacity."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if self.cfg.is_encoder_decoder:
            enc_feats = self._norm_enc_feats(enc_feats)   # validate at join
        req = _PendingJoin(task_id=task_id, prompt=prompt,
                           adapter_id=adapter_id,
                           max_new_tokens=max_new_tokens, rid=rid,
                           eos_id=eos_id,
                           deadline=float("inf") if deadline is None
                           else float(deadline),
                           enc_feats=enc_feats
                           if self.cfg.is_encoder_decoder else None)
        if self.paged and not self.can_admit(len(prompt), prompt=prompt,
                                             adapter_id=adapter_id):
            # deferral must be able to END: a request whose prompt bucket +
            # chunk headroom (minus the pages its prefix currently shares)
            # exceeds the whole arena would pend forever (drain() and the
            # serve loop would spin) — that is a pool configuration error,
            # not backpressure. A request that only fits BECAUSE of the
            # discount and whose registered sharer later retires becomes
            # STRANDED: it stays queued without blocking others, and only
            # a full engine wedge raises (_raise_if_wedged).
            if self._never_fits(req):
                plen = self.bucket_for_prompt(min(max(len(prompt), 1),
                                                  self.prompt_len))
                base = self._pages_for(self._adm_s_max(plen)) + \
                    self._pages_for(self._headroom_tokens())
                raise ValueError(
                    f"prompt needs {base} pages (bucket {plen} + chunk "
                    f"headroom) beyond any shared prefix but the arena "
                    f"only has {self.total_pages - 1} usable pages; raise "
                    f"total_pages or shrink prompt_buckets/chunk")
            self.pending.append(req)
            self.deferrals += 1
            return -1
        if not self.free_slots():
            raise RuntimeError("no free decode slots; step_chunk() first")
        return self._admit_now(req)

    def _admit_now(self, req: _PendingJoin) -> int:
        if req.resume is not None and self.paged and self.spill is not None:
            slot = self._try_spill_resume(req)
            if slot is not None:
                return slot
        t_adm = time.perf_counter()
        prompt = req.prompt
        if len(prompt) > self.prompt_len:
            warnings.warn(
                f"prompt of {len(prompt)} tokens exceeds the engine's largest "
                f"admission bucket ({self.prompt_len}); left-truncating to "
                f"the last {self.prompt_len} tokens (context is lost — size "
                f"prompt_buckets to the workload)", RuntimeWarning,
                stacklevel=2)
            prompt = prompt[-self.prompt_len:]     # causal LM: suffix matters
        true_prompt = prompt
        # variable-length bucketed admission for every stack: attention masks
        # right-pads out of its K/V and the recurrent scans carry state
        # through them unchanged, so the bucket is the only jit key
        true_len = max(1, len(prompt))
        plen = self.bucket_for_prompt(true_len)
        if len(prompt) < plen:                     # right-pad to the bucket
            prompt = np.concatenate(
                [prompt, np.zeros(plen - len(prompt), np.int32)])
        max_new_tokens = max(1, min(req.max_new_tokens, self.max_new))
        slot = self.free_slots()[0]
        cap = self.fm.adapters.capacity()
        aslot = self.fm.adapters.index(req.adapter_id)
        if self.paged and self.chunked_prefill:
            admitted = self._try_admit_tail(req, true_prompt, true_len, slot,
                                            cap, aslot, max_new_tokens, t_adm)
            if admitted is not None:
                return admitted
        perm, inv, blocks = self._prefill_segments(aslot, cap, plen)
        # encoder operand: (1, enc_len, d) frames for enc-dec, None (an
        # empty pytree leaf — same trace) for decoder-only stacks
        enc = jnp.asarray(self._norm_enc_feats(req.enc_feats)[None]) \
            if self.cfg.is_encoder_decoder else None
        first, fin, key, cache = self._prefill_fn(cap, plen)(
            self.fm.params, jnp.asarray(prompt[None]),
            jnp.full((1,), true_len, jnp.int32), enc, self._keys[slot][None],
            self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
            perm, inv, blocks)
        self._keys = self._keys.at[slot].set(key[0])
        # the prefill consumed real device work whether or not the stream
        # survives it — record the admission for token-level charging
        self.admissions += 1
        self.tail_tokens_computed += true_len   # full prefill: whole prompt
        if req.resume is None:
            self.admitted_log.append((req.rid, req.task_id, true_len,
                                      true_len))
        # numeric health rides the admission's existing host sync: a
        # non-finite prefill (poisoned adapter / Inf activations) quarantines
        # RIGHT HERE — no pages allocated, no pool write, and crucially no
        # prefix registration (NaN K/V must never enter the COW registry
        # where later joins would map it)
        fin_ok = bool(np.asarray(fin)[0])
        if not fin_ok:
            self.quarantines += 1
        if fin_ok and self.paged:
            npages = self._pages_for(self._adm_s_max(plen))
            shared = self._match_prefix(req.adapter_id, true_prompt)
            m = len(shared)
            # continue the digest chain into the spill arena: spilled
            # prefix pages are restored by DMA into this admission's own
            # freshly allocated pages (positions m..m+k-1), verified
            # against their digests, and re-registered below — this full
            # path is the fallback when the chunked tail admission above
            # declined (nothing shareable, or not enough free pages), so
            # the prefill recomputed those positions and its content is
            # discarded in favor of the restored bit-exact pages
            spilled = self._match_spilled_prefix(req.adapter_id, true_prompt,
                                                 m)
            k = len(spilled)
            priv = self._take_pages(npages - m)
            pages = priv
            if m:
                self._share_pages(shared)
                self.prefix_hits += 1
                self.shared_pages_mapped += m
                pages = np.concatenate(
                    [np.asarray(shared, np.int32), priv])
            if k:
                blob = [
                    {key: np.concatenate([e.blob[j][key] for _, e in spilled],
                                         axis=1)
                     for key in ("k", "v", "k_scale", "v_scale")}
                    for j in range(len(spilled[0][1].blob))]
                self._restore_pages(blob, priv[:k])
                for key, _ in spilled:
                    self.spill.pop(("prefix", key))
                self.spill_prefix_hits += 1
                self.restored_pages += k
            # COW admission: the slot MAPS the shared prefix pages, but the
            # scatter points those positions at the trash page — their
            # (bit-identical) content is already in the arena and must not
            # be rewritten while other streams read it; restored spilled
            # positions are likewise masked so the scatter cannot overwrite
            # the restored content
            scatter = pages.copy()
            scatter[:m + k] = TRASH_PAGE
            self.pool = self._paged_write_fn(npages)(
                self.pool, cache, jnp.int32(slot), jnp.asarray(scatter),
                jnp.int32(true_len))
            self._ptab[slot, :npages] = pages
            self._held[slot] = npages
            self._lens[slot] = true_len
            # trim: bucket padding beyond the true length scattered zero
            # pages — release them now (always private: the shared prefix
            # never extends past the prompt); decode growth re-allocates
            keep = self._pages_for(true_len)
            if keep < npages:
                self._release_pages(self._ptab[slot, keep:npages])
                self._ptab[slot, keep:npages] = TRASH_PAGE
                self._held[slot] = keep
            self._register_prefix(req.adapter_id, true_prompt, slot,
                                  true_len, cache=cache)
            self._ptab_dirty = True
        elif fin_ok:
            self.pool = self._write_fn()(self.pool, cache, slot)
        return self._finish_admission(req, slot, aslot, first, fin_ok,
                                      true_prompt, true_len, max_new_tokens,
                                      t_adm)

    def _try_admit_tail(self, req: _PendingJoin, true_prompt: np.ndarray,
                        true_len: int, slot: int, cap: int, aslot: int,
                        max_new_tokens: int, t_adm: float) -> Optional[int]:
        """Chunked shared-prefix admission: when the prompt's leading pages
        are already in the arena (registered by a live sharer, or restorable
        from the prefix spill tier), MAP them and prefill only the private
        tail — the tail's queries attend the mapped int8 pages dequantized
        through the page vector. Returns the slot, or None to fall back to
        the always-correct full prefill (nothing shareable, or the tail
        bucket + restores need more free pages than the arena has right
        now — the admission gate budgeted for the full path, not this
        one)."""
        ps = self.page_size
        shared = self._match_prefix(req.adapter_id, true_prompt)
        spilled = self._match_spilled_prefix(req.adapter_id, true_prompt,
                                             len(shared))
        # always leave >= 1 tail token: the first generated token needs a
        # real last-position forward pass, and the boundary page decode
        # appends into must be recomputed into a PRIVATE copy — a fully
        # registered page-aligned prompt re-prefills its last page
        skip = min(len(shared) + len(spilled), (true_len - 1) // ps)
        if skip < 1:
            return None
        m_eff = min(len(shared), skip)
        k_eff = skip - m_eff
        spilled = spilled[:k_eff]
        tail_len = true_len - skip * ps
        tbucket = self.bucket_for_tail(tail_len)
        npages = self._pages_for(tbucket)
        if len(self._free_pages) < k_eff + npages:
            return None
        # ---- map phase: shared pages ref++, spilled pages restored H2D ----
        shared_eff = np.asarray(shared[:m_eff], np.int32)
        priv_restore = self._take_pages(k_eff)
        priv_tail = self._take_pages(npages)
        if m_eff:
            self._share_pages(shared_eff)
        if k_eff:
            blob = [
                {key: np.concatenate([e.blob[j][key] for _, e in spilled],
                                     axis=1)
                 for key in ("k", "v", "k_scale", "v_scale")}
                for j in range(len(spilled[0][1].blob))]
            self._restore_pages(blob, priv_restore)
            # spill entries are popped only AFTER the numeric-health gate:
            # a quarantined admission must not cost the arena its prefix
        prefix_ids = np.full((self._prefix_width,), TRASH_PAGE, np.int32)
        prefix_ids[:m_eff] = shared_eff
        prefix_ids[m_eff:skip] = priv_restore
        # ---- tail-compute phase ----
        tail = true_prompt[skip * ps:]
        if len(tail) < tbucket:
            tail = np.concatenate(
                [tail, np.zeros(tbucket - len(tail), np.int32)])
        perm, inv, blocks = self._prefill_segments(aslot, cap, tbucket)
        # exact-parity float path when EVERY mapped page still has its float
        # sidecar (live pages in _page_float, spilled pages carrying kf/vf);
        # otherwise attend the int8 arena content dequantized — correct to
        # quantization error, and the only option once the floats are gone
        use_float = (all(int(p) in self._page_float for p in shared_eff)
                     and all("kf" in e.blob[0] for _, e in spilled))
        if use_float:
            # sharers of one live prefix reuse a single assembled + uploaded
            # operand set: registered pages are immutable, so the key (the
            # mapped page ids) fully determines the content, and
            # _release_pages drops entries the moment any member id frees
            fpkey = tuple(int(x) for x in prefix_ids[:skip])
            prefix_fp = self._prefix_fp_cache.get(fpkey)
            if prefix_fp is None:
                srcs = [self._page_float[int(p)] for p in shared_eff] + \
                       [[{"k": sub["kf"], "v": sub["vf"]} for sub in e.blob]
                        for _, e in spilled]
                prefix_fp = []
                for i in range(len(srcs[0])):
                    k0 = srcs[0][i]["k"]        # (nper, ps, kv, hd)
                    bk = np.zeros((k0.shape[0], 1, self._prefix_width * ps)
                                  + k0.shape[2:], k0.dtype)
                    bv = np.zeros_like(bk)
                    for j, src in enumerate(srcs):
                        bk[:, 0, j * ps:(j + 1) * ps] = src[i]["k"]
                        bv[:, 0, j * ps:(j + 1) * ps] = src[i]["v"]
                    prefix_fp.append({"k": jnp.asarray(bk),
                                      "v": jnp.asarray(bv)})
                while len(self._prefix_fp_cache) >= 32:   # FIFO bound
                    self._prefix_fp_cache.pop(
                        next(iter(self._prefix_fp_cache)))
                self._prefix_fp_cache[fpkey] = prefix_fp
            first, fin, key, cache = self._tail_prefill_fn(
                cap, tbucket, "float")(
                self.fm.params, prefix_fp, jnp.asarray(tail[None]),
                jnp.full((1,), tail_len, jnp.int32),
                jnp.full((1,), skip * ps, jnp.int32), self._keys[slot][None],
                self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
                perm, inv, blocks)
        else:
            first, fin, key, cache = self._tail_prefill_fn(
                cap, tbucket, "pages")(
                self.fm.params, self.pool, jnp.asarray(tail[None]),
                jnp.full((1,), tail_len, jnp.int32), jnp.asarray(prefix_ids),
                jnp.full((1,), skip * ps, jnp.int32), self._keys[slot][None],
                self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
                perm, inv, blocks)
        self._keys = self._keys.at[slot].set(key[0])
        self.admissions += 1
        self.tail_tokens_computed += tail_len
        self.prefill_tokens_saved += skip * ps
        if req.resume is None:
            self.admitted_log.append((req.rid, req.task_id, true_len,
                                      tail_len))
        fin_ok = bool(np.asarray(fin)[0])
        if not fin_ok:
            # quarantined tail: roll the map phase back — shared refcounts
            # drop to their pre-join values, restored/tail pages return to
            # the free list (none were registered, so nothing re-spills),
            # and the untouched spill entries keep the prefix restorable
            self.quarantines += 1
            if m_eff:
                self._release_pages(shared_eff)
            self._release_pages(priv_restore)
            self._release_pages(priv_tail)
        else:
            # restored prefix pages get their float sidecars back from the
            # spill blob (kept exact across the D2H round trip), so they
            # keep serving float-mode tails to future sharers
            for pg, (_, e) in zip(priv_restore, spilled):
                if "kf" in e.blob[0]:
                    self._page_float[int(pg)] = [
                        {"k": sub["kf"], "v": sub["vf"]} for sub in e.blob]
            for key_, _ in spilled:
                self.spill.pop(("prefix", key_))
            if k_eff:
                self.spill_prefix_hits += 1
                self.restored_pages += k_eff
            if m_eff:
                self.prefix_hits += 1
                self.shared_pages_mapped += m_eff
            boundary = true_len // ps - skip
            self.pool = self._paged_tail_write_fn(npages)(
                self.pool, cache, jnp.int32(slot), jnp.asarray(priv_tail),
                jnp.int32(true_len), jnp.int32(boundary),
                jnp.asarray(prefix_ids), jnp.int32(skip))
            self._ptab[slot, :skip] = prefix_ids[:skip]
            self._ptab[slot, skip:skip + npages] = priv_tail
            self._held[slot] = skip + npages
            self._lens[slot] = true_len
            # trim tail-bucket padding beyond the true length (always
            # private pages; the prefix never extends past the prompt)
            keep = self._pages_for(true_len)
            if keep < skip + npages:
                self._release_pages(self._ptab[slot, keep:skip + npages])
                self._ptab[slot, keep:skip + npages] = TRASH_PAGE
                self._held[slot] = keep
            # a float-mode tail's cache is exact, so its freshly registered
            # pages earn sidecars of their own; a pages-mode tail carries
            # the prefix dequantization error and must not seed sidecars
            # that future sharers would trust as exact
            self._register_prefix(req.adapter_id, true_prompt, slot,
                                  true_len,
                                  cache=cache if use_float else None,
                                  cache_page0=skip)
            self._ptab_dirty = True
        return self._finish_admission(req, slot, aslot, first, fin_ok,
                                      true_prompt, true_len, max_new_tokens,
                                      t_adm)

    def _finish_admission(self, req: _PendingJoin, slot: int, aslot: int,
                          first, fin_ok: bool, true_prompt: np.ndarray,
                          true_len: int, max_new_tokens: int,
                          t_adm: float) -> int:
        if self.state_pool is not None:
            # strict 1:1 with the decode slot — a double allocation here is
            # a lifecycle bug (some exit path didn't free), not backpressure
            self.state_pool.alloc(slot)
        self._tokens = self._tokens.at[slot].set(first[0])
        now = time.perf_counter()
        tok0 = int(first[0])
        eos = self.eos_id if req.eos_id is None else req.eos_id
        if req.resume is not None:
            # preempted stream resuming: keep its identity/latency stamps,
            # append the re-prefill's next token to the existing stream.
            # s.prompt deliberately stays the ORIGINAL prompt — s.tokens
            # still holds everything generated, so a SECOND preemption
            # rebuilds prompt+tokens without duplicating the first resume's
            # prefix (and re-truncates from the fullest context available)
            s = req.resume
            s.tokens.append(tok0)
            s.done = (not fin_ok or len(s.tokens) >= s.max_new or
                      (s.eos_id is not None and tok0 == s.eos_id))
            if not fin_ok:
                s.status = "quarantined"
            self.slots[slot] = s
            # a stale spill entry (free pages or budget forced the fallback)
            # no longer matches the stream's state once it decodes again
            self._drop_stream_spill(s.rid)
            self.resume_costs.append(("reprefill", now - t_adm))
        else:
            self.slots[slot] = DecodeSlot(
                rid=req.rid, task_id=req.task_id, adapter_slot=aslot,
                max_new=max_new_tokens, eos_id=eos,
                tokens=[tok0], t_join=now, t_first=now,
                prompt_tokens=true_len, prompt=true_prompt,
                adapter_id=req.adapter_id, deadline=req.deadline,
                status="ok" if fin_ok else "quarantined",
                done=(not fin_ok or max_new_tokens == 1
                      or (eos is not None and tok0 == eos)),
                enc_feats=req.enc_feats)
        self._slot_adapters[slot] = aslot
        self._seg_key = None                    # composition changed
        return slot

    def leave(self, slot: int) -> DecodeSlot:
        """Retire a slot (finished or cancelled) and free it for admission
        (paged: its pages return to the free list)."""
        s = self.slots[slot]
        assert s is not None, slot
        self.slots[slot] = None
        self._slot_adapters[slot] = FREE
        self._seg_key = None                    # composition changed
        if self.paged:
            self._release_slot_pages(slot)
        if self.state_pool is not None:
            self.state_pool.free(slot)
        # keep the freed slot's cache length bounded while it idles
        for sub in self.pool:
            if isinstance(sub, dict) and "len" in sub:
                sub["len"] = sub["len"].at[:, slot].set(0)
        return s

    # ---- paged page-pressure handling ----
    def _preempt(self, slot: int):
        """Evict a live stream to reclaim its pages: it re-queues at the
        FRONT of the pending queue with its generated prefix folded into the
        prompt (re-admission also refreshes its int8 scales). With a spill
        arena attached, the victim's pages/scales/trackers/PRNG key are
        captured D2H first and resume restores them by H2D copy — exact
        token AND sampling-stream parity; the folded prompt is kept as the
        recompute fallback for when the host budget evicts the spill.
        Without an arena, sampling streams lose PRNG continuity across a
        preemption; greedy streams resume exactly."""
        s = self.slots[slot]
        if self.spill is not None:
            self._spill_stream(slot, s)
        prompt = np.concatenate([
            np.asarray(s.prompt if s.prompt is not None else [], np.int32),
            np.asarray(s.tokens, np.int32)])
        self.slots[slot] = None
        self._slot_adapters[slot] = FREE
        self._seg_key = None
        self._release_slot_pages(slot)
        if self.state_pool is not None:
            # the victim's dense state is NOT captured (spill is demoted on
            # such stacks): re-admission re-prefills the folded prompt, which
            # recomputes recurrent state exactly
            self.state_pool.free(slot)
        for sub in self.pool:
            if isinstance(sub, dict) and "len" in sub:
                sub["len"] = sub["len"].at[:, slot].set(0)
        self.pending.appendleft(_PendingJoin(
            task_id=s.task_id, prompt=prompt, adapter_id=s.adapter_id,
            max_new_tokens=s.max_new, rid=s.rid, eos_id=s.eos_id, resume=s,
            enc_feats=s.enc_feats))
        self.preemptions += 1

    def _ensure_chunk_pages(self):
        """Top every live slot up to ``len + _headroom_tokens()`` tokens of
        pages before the chunk dispatches (``chunk`` tokens, or
        ``chunk * (k + 1)`` under speculation — speculative writes land
        above ``len`` before acceptance rolls ``len`` back, so the pages
        must exist up front). When the free list runs dry, preempt the
        youngest live streams (least work redone) until it doesn't; a single
        stream that cannot fit is a configuration error (pool smaller than
        one stream's chunk growth)."""
        while True:
            live = [i for i, s in enumerate(self.slots)
                    if s is not None and not s.done]
            preempted = False
            for i in live:
                if self.slots[i] is None:       # preempted by an earlier pass
                    continue
                need = self._pages_for(self._lens[i]
                                       + self._headroom_tokens()) \
                    - self._held[i]
                if need <= 0:
                    continue
                while need > len(self._free_pages):
                    victims = [j for j in live
                               if j != i and self.slots[j] is not None
                               and not self.slots[j].done]
                    if not victims:
                        raise RuntimeError(
                            f"paged pool exhausted: {need} pages needed for "
                            f"one stream, {len(self._free_pages)} free and "
                            f"nothing left to preempt (total_pages="
                            f"{self.total_pages} is too small)")
                    self._preempt(min(
                        victims, key=lambda j: len(self.slots[j].tokens)))
                    preempted = True
                pages = self._take_pages(need)
                h = self._held[i]
                self._ptab[i, h:h + need] = pages
                self._held[i] = h + need
                self._ptab_dirty = True
            if not preempted:
                return

    def _never_fits(self, req: _PendingJoin) -> bool:
        """True when the request cannot be admitted even into an EMPTY
        arena, counting the pages its prefix currently shares — deferring
        it would spin forever."""
        plen = self.bucket_for_prompt(min(max(len(req.prompt), 1),
                                          self.prompt_len))
        m = len(self._match_prefix(req.adapter_id, req.prompt))
        return (self._pages_for(self._adm_s_max(plen)) - m
                + self._pages_for(self._headroom_tokens())) \
            > self.total_pages - 1

    def _viable_pending(self) -> list[int]:
        """Pending indices that could fit the arena at its CURRENT sharing
        state. A deferred join admitted on the strength of a prefix
        discount whose registered sharer has since retired is STRANDED: it
        stays queued (a later admission re-registering its prefix would
        unstrand it) but is invisible to the drain and exempt from the
        head-of-line fairness cap — it cannot be starved of something no
        amount of waiting provides. ``step_chunk`` raises only when the
        whole engine wedges on stranded entries (nothing live, nothing
        viable), the one state no future engine event can fix."""
        return [i for i, r in enumerate(self.pending)
                if not self._never_fits(r)]

    def _spill_resume_need(self, req: _PendingJoin) -> Optional[int]:
        """Gate-level page need to resume ``req`` from its stream spill, or
        None when no usable entry exists (nothing spilled / budget evicted
        it / it could never fit even an empty arena — the gate then prices
        the legacy re-prefill instead). A spill resume restores the TRUE
        page count held at preemption, which can exceed the re-prefill
        bucket's (truncated) estimate — pricing it honestly is what lets
        ``_try_spill_resume`` actually find its pages free."""
        if self.spill is None or req.resume is None:
            return None
        entry = self.spill.peek(("stream", req.rid))
        if entry is None:
            return None
        n = int(entry.meta["n_pages"])
        hr = self._pages_for(self._headroom_tokens())
        if n + hr > self.total_pages - 1:
            return None
        return n + hr + self._imminent_page_need()

    def _next_admissible_pending(self) -> Optional[int]:
        """Index of the next deferred join the pool can take: the (viable)
        head, or — bounded lookahead — a smaller prompt within
        ``pending_lookahead`` viable entries of it whose pages ARE free
        while the head's are not. Skip-ahead is capped: after
        ``hol_skip_cap`` consecutive bypasses the window collapses to the
        head alone until it admits, so a large blocked head is delayed,
        never starved."""
        if not self.pending or not self.free_slots():
            return None
        viable = self._viable_pending()
        window = 1 if self._hol_skips >= self.hol_skip_cap else \
            self.pending_lookahead
        for idx in viable[:window]:
            req = self.pending[idx]
            need = self._admission_need(len(req.prompt), prompt=req.prompt,
                                        adapter_id=req.adapter_id)
            spill_need = self._spill_resume_need(req)
            if spill_need is not None:
                # both resume paths must be viable: the spill restore (its
                # true page count) AND the re-prefill fallback it degrades
                # to on a digest mismatch discovered at restore time
                need = max(need, spill_need)
            if len(self._free_pages) >= need:
                return idx
        return None

    def can_admit_pending(self) -> bool:
        return self._next_admissible_pending() is not None

    def _drain_pending(self):
        """Admit deferred joins while slots and pages allow — FIFO with the
        bounded skip-ahead of ``_next_admissible_pending`` (one large prompt
        at the head no longer starves small prompts queued behind it).
        Bypassing a stranded entry never consumes the fairness budget."""
        while True:
            idx = self._next_admissible_pending()
            if idx is None:
                return
            req = self.pending[idx]
            bypassed_viable = any(not self._never_fits(self.pending[i])
                                  for i in range(idx))
            del self.pending[idx]
            if bypassed_viable:
                self._hol_skips += 1
                self.hol_bypasses += 1
            else:
                self._hol_skips = 0
            self._admit_now(req)

    # ---- failure semantics: deadlines, cancellation, terminal rejection ----
    def _reject_pending(self, p: _PendingJoin, status: str):
        p.status = status
        if p.resume is not None:
            p.resume.status = status
            p.resume.done = True
            self._drop_stream_spill(p.rid)
        self.rejected.append(p)

    def _expire_deadlines(self, now: float):
        """Deadline enforcement on chunk entry: live slots past their
        deadline are marked done (``deadline_cancelled``) and retire through
        the normal sweep with their partial tokens; expired pending entries
        are terminally rejected — ``deadline_shed`` if never admitted,
        ``deadline_cancelled`` for a preempted resume (it was mid-flight),
        ``rejected_stranded`` when the entry is stranded (satellite of the
        stranded-sharer fix: a stranded join with a deadline no longer idles
        forever)."""
        for s in self.slots:
            if s is not None and not s.done and s.deadline < now:
                s.done = True
                s.status = "deadline_cancelled"
                self.deadline_cancels += 1
        if not self.pending:
            return
        keep: collections.deque[_PendingJoin] = collections.deque()
        for p in self.pending:
            if p.deadline >= now:
                keep.append(p)
            elif self._never_fits(p):
                self._reject_pending(p, "rejected_stranded")
                self.stranded_rejections += 1
            elif p.resume is not None:
                self._reject_pending(p, "deadline_cancelled")
                self.deadline_cancels += 1
            else:
                self._reject_pending(p, "deadline_shed")
                self.deadline_sheds += 1
        if len(keep) != len(self.pending):
            self.pending = keep

    def cancel(self, rid: int):
        """Client-cancel one stream by rid wherever it lives. Returns
        ``("slot", DecodeSlot)`` for a live stream (retired through
        ``leave`` — pages refcount-released, registry references dropped),
        ``("pending", _PendingJoin)`` for a deferred or preempted entry
        (popped; a preempted resume's pages were already freed at
        preemption), or ``None`` when the rid is not in the engine."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                s.done = True
                s.status = "cancelled"
                self.cancels += 1
                return ("slot", self.leave(i))
        for i, p in enumerate(self.pending):
            if p.rid == rid:
                del self.pending[i]
                p.status = "cancelled"
                if p.resume is not None:
                    p.resume.status = "cancelled"
                    p.resume.done = True
                    self._drop_stream_spill(p.rid)
                self.cancels += 1
                return ("pending", p)
        return None

    def shed_stranded(self) -> int:
        """Terminally reject every stranded pending entry (regardless of
        deadline) into ``rejected`` — the serve loop's graceful-degradation
        path when the engine would otherwise wedge. Returns the count."""
        keep: collections.deque[_PendingJoin] = collections.deque()
        n = 0
        for p in self.pending:
            if self._never_fits(p):
                self._reject_pending(p, "rejected_stranded")
                self.stranded_rejections += 1
                n += 1
            else:
                keep.append(p)
        if n:
            self.pending = keep
        return n

    def take_rejected(self) -> list[_PendingJoin]:
        """Drain the terminally rejected pending entries (serve-loop hook)."""
        out, self.rejected = self.rejected, []
        return out

    def take_admitted(self) -> list[tuple[int, str, int, int]]:
        """Drain the (rid, task_id, true_prompt_len, tail_tokens) admission
        log — the serve loop charges prompt tokens from HERE, at actual
        admission, so a join that deferred and was later shed never carried
        a charge. ``tail_tokens`` is what the prefill ACTUALLY computed
        (< true_prompt_len when a chunked admission mapped a shared prefix);
        fair-share accounting charges it, not the full prompt — a sharer
        must not be billed for compute the registry saved it."""
        out, self.admitted_log = self.admitted_log, []
        return out

    def take_decode_charges(self) -> dict:
        """Drain the committed-decode-token log, keyed ``(task_id, rid)`` —
        the serve loop charges fair-share decode budgets from HERE.  Every
        dispatch logs the tokens each stream actually COMMITTED
        (speculative: accepted + corrected; plain: the chunk length), so
        under speculation a high-accept task is charged for its real
        throughput instead of the old uniform ``chunk x active_slots``
        split.  The rid in the key lets drain-synchronous callers skip
        streams already priced at arrival."""
        out = dict(self._decode_charges)
        self._decode_charges = collections.Counter()
        return out

    def spec_task_accept_rates(self) -> dict:
        """Per-task draft accept rate (accepted / proposed, cumulative) —
        the per-task gauges ``serving.metrics`` exports."""
        return {t: (a / p if p else 0.0)
                for t, (p, a) in self._spec_task_stats.items()}

    def _raise_if_wedged(self):
        """Nothing live, nothing viable, stranded joins pending: no future
        engine event can admit them (new joins defer behind the pending
        queue, so the re-registration that would unstrand them can never
        happen either) — drain()/the serve loop would spin forever. Loud
        configuration error instead."""
        if self.pending and self.active_count() == 0 \
                and not self._viable_pending():
            raise ValueError(
                f"{len(self.pending)} deferred prompt(s) no longer fit the "
                f"arena ({self.total_pages - 1} usable pages) — the shared "
                f"prefix they were admitted against was released and "
                f"nothing is left to free; raise total_pages or shrink "
                f"prompt_buckets/chunk")

    # ---- deadline overrun clamp (satellite) ----
    def chunk_ladder(self) -> tuple[int, ...]:
        """The only chunk lengths the clamp ever dispatches (descending):
        full, half, single-step. A small fixed ladder keeps the set of
        decode jit keys bounded — ``warm_decode_ladder`` can precompile all
        of them so deadline traffic never recompiles in steady state."""
        return tuple(sorted({self.chunk, max(1, self.chunk // 2), 1},
                            reverse=True))

    def _effective_chunk(self, live: list[int], now: float) -> int:
        """Deadlines are only checked on chunk entry, so a full chunk can
        overrun a tight SLO by ``chunk - 1`` steps. When the nearest live
        deadline is closer than a full chunk (measured against the per-step
        EMA), shrink this dispatch to the largest ladder length that still
        lands within ~one step of the deadline."""
        if not self.deadline_clamp or self._step_ema <= 0.0:
            return self.chunk
        tight = min((self.slots[i].deadline for i in live), default=float("inf"))
        if tight == float("inf"):
            return self.chunk
        room = max(1, int(np.ceil((tight - now) / self._step_ema)))
        if room >= self.chunk:
            return self.chunk
        for c in self.chunk_ladder():
            if c <= room:
                self.deadline_clamps += 1
                return c
        self.deadline_clamps += 1
        return 1

    def warm_decode_ladder(self):
        """Precompile (and dispatch once) every ladder chunk length against
        the live pool so the deadline clamp never recompiles in steady
        state. Only callable while no stream is live: the garbage rows this
        steps land in the trash page (paged) or in regions the next
        admission overwrites wholesale (dense) — the same free-slots-keep-
        stepping contract the engine already relies on. Sampling PRNG keys
        DO advance (they advance every chunk for every slot anyway)."""
        assert self.active_count() == 0, \
            "warm_decode_ladder must run on an idle engine"
        if self.paged:
            self._sync_page_table()
        cap = self.fm.adapters.capacity()
        perm, inv, blocks = self._segments(cap)
        for c in self.chunk_ladder():
            self.pool, self._tokens, self._keys, _, _, _ = \
                self._decode_fn(cap, c)(
                    self.fm.params, self.pool, self._tokens, self._keys,
                    self.fm.adapters.stacked(),
                    jnp.asarray(self._slot_adapters), perm, inv, blocks)

    def warm_speculative(self):
        """Precompile (and dispatch once) the speculative decode fn for
        every ladder chunk length, so spec/plain mode flips and deadline
        clamps never recompile in steady state.  Same idle-engine garbage
        contract as ``warm_decode_ladder``: every free slot's history is
        empty (hlen 0 -> drafter proposes nothing -> each step commits one
        token into the trash page)."""
        assert self.active_count() == 0, \
            "warm_speculative must run on an idle engine"
        if self.spec_k <= 0 or not self.paged:
            return
        self._sync_page_table()
        cap = self.fm.adapters.capacity()
        perm, inv, blocks = self._spec_segments(cap)
        hist = jnp.zeros((self.num_slots, self._spec_hist_len), jnp.int32)
        hlen = jnp.zeros((self.num_slots,), jnp.int32)
        for c in self.chunk_ladder():
            self.pool, self._tokens, self._keys, *_ = \
                self._spec_decode_fn(cap, c)(
                    self.fm.params, self.pool, self._tokens, self._keys,
                    hist, hlen, self._spec_cols,
                    self.fm.adapters.stacked(),
                    jnp.asarray(self._slot_adapters), perm, inv, blocks)

    def warm_spill(self):
        """Precompile the spill tier's D2H gather and H2D restore scatters
        so spill traffic, spilled-prefix restores and spill resumes never
        retrace in steady state. The warm round trip is a no-op: an empty
        capture reads only the trash page (garbage by contract), the
        restore scatters zeros back into it, and slot 0's running state is
        written back to itself unchanged."""
        assert self.active_count() == 0, \
            "warm_spill must run on an idle engine"
        if self.spill is None or not self.paged:
            return
        none_ = np.empty((0,), np.int32)
        blob = self._capture_pages(none_, 0)
        self._restore_pages(blob, none_)
        state = [{k: sub[k] for k in ("slot_k_scale", "slot_v_scale",
                                      "k_max", "v_max")} for sub in blob]
        self.pool = self._slot_restore_fn()(self.pool, state, jnp.int32(0),
                                            jnp.int32(int(self._lens[0])))

    def warm_chunked(self):
        """Precompile the chunked-admission planes (one tail prefill + one
        tail-page scatter per tail bucket) so sharer joins never recompile
        in steady state, whatever tail length they arrive with. The warm
        prefill attends only trash-page content behind a masked-out prefix
        window and its outputs are DISCARDED — in particular the advanced
        PRNG key, so a warmed engine's sampling streams stay bit-identical
        to an unwarmed one's. The warm scatter targets the trash page at
        slot 0 with a zero length (idle-engine garbage contract, same as
        ``warm_decode_ladder``)."""
        assert self.active_count() == 0, \
            "warm_chunked must run on an idle engine"
        if not (self.paged and self.chunked_prefill):
            return
        cap = self.fm.adapters.capacity()
        aslot = self.fm.adapters.index(None)
        prefix_ids = jnp.full((self._prefix_width,), TRASH_PAGE, jnp.int32)
        # zero float-prefix operand at the fixed (prefix_width) shape every
        # float-mode tail call uses — same dtype as the sidecar slices
        # (native cache dtype), so the warm trace is the steady-state trace
        warm_fp = [{"k": c["k"], "v": c["v"]}
                   for c, p in zip(
                       lm.init_cache(self.cfg, 1,
                                     self._prefix_width * self.page_size,
                                     kv_quant=False), self.pool)
                   if isinstance(p, dict) and "page_table" in p]
        for tb in self.tail_buckets:
            perm, inv, blocks = self._prefill_segments(aslot, cap, tb)
            self._tail_prefill_fn(cap, tb, "float")(
                self.fm.params, warm_fp, jnp.zeros((1, tb), jnp.int32),
                jnp.full((1,), tb, jnp.int32),
                jnp.zeros((1,), jnp.int32), self._keys[0][None],
                self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
                perm, inv, blocks)
            self._tail_prefill_fn(cap, tb, "pages")(
                self.fm.params, self.pool, jnp.zeros((1, tb), jnp.int32),
                jnp.full((1,), tb, jnp.int32), prefix_ids,
                jnp.zeros((1,), jnp.int32), self._keys[0][None],
                self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
                perm, inv, blocks)
            npages = self._pages_for(tb)
            cache = lm.init_cache(self.cfg, 1, npages * self.page_size,
                                  kv_quant=False)
            self.pool = self._paged_tail_write_fn(npages)(
                self.pool, cache, jnp.int32(0),
                jnp.full((npages,), TRASH_PAGE, jnp.int32), jnp.int32(0),
                jnp.int32(-1), prefix_ids, jnp.int32(0))

    def step_chunk(self) -> list[DecodeSlot]:
        """Advance every occupied slot by up to ``chunk`` tokens under one
        jitted scan; retire and return the slots that finished. Paged:
        streams already done retire FIRST (their pages fund deferred
        admissions and spare a live stream from preemption), then deferred
        admissions drain into the freed capacity, then live slots top up
        with pages for the chunk and the page table syncs. Entered with
        nothing occupied and only STRANDED deferred joins left, raises the
        wedge configuration error — checked on ENTRY so the call that
        retires the last live stream still returns it. Deadline enforcement
        runs first: expired live slots are marked done (and retire below),
        expired pending entries are terminally rejected — so a wedge of
        deadline-carrying strandeds clears itself instead of raising."""
        t0 = time.perf_counter()
        self._expire_deadlines(t0)
        if self.paged:
            self._raise_if_wedged()
        retired = [self.leave(i) for i, s in enumerate(self.slots)
                   if s is not None and s.done]
        if self.paged:
            self._drain_pending()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]
        if live and self.paged:
            self._ensure_chunk_pages()
            # preemption may have evicted members of the live set
            live = [i for i, s in enumerate(self.slots)
                    if s is not None and not s.done]
        finished = []
        if live:
            if self.paged:
                self._sync_page_table()
            eff = self._effective_chunk(live, t0)
            cap = self.fm.adapters.capacity()
            use_spec = self._spec_dispatch_now()
            if use_spec and self._spec_probe:
                eff = 1     # probes are single-step (see _spec_dispatch_now)
            t_disp = time.perf_counter()
            if use_spec:
                perm, inv, blocks = self._spec_segments(cap)
                hist, hlen = self._spec_history()
                self.pool, self._tokens, self._keys, out_g, out_m, out_p, \
                    drift, fin = self._spec_decode_fn(cap, eff)(
                        self.fm.params, self.pool, self._tokens, self._keys,
                        hist, hlen, self._spec_cols,
                        self.fm.adapters.stacked(),
                        jnp.asarray(self._slot_adapters), perm, inv, blocks)
                out_g = np.asarray(out_g)       # (slots, eff, k+1): one sync
                out_m = np.asarray(out_m)       # (slots, eff) commit counts
                out_p = np.asarray(out_p)       # (slots, eff) proposals
                fin = np.asarray(fin)
            else:
                perm, inv, blocks = self._segments(cap)
                self.pool, self._tokens, self._keys, out, drift, fin = \
                    self._decode_fn(cap, eff)(
                        self.fm.params, self.pool, self._tokens, self._keys,
                        self.fm.adapters.stacked(),
                        jnp.asarray(self._slot_adapters), perm, inv, blocks)
                out = np.asarray(out)           # one host sync per chunk
                fin = np.asarray(fin)           # rides the same sync
            # per-SCAN-STEP cost: the deadline clamp reasons in scan steps
            # either way, and a speculative step's extra verify cost is
            # exactly what the EMA must learn for the ladder to clamp right
            dt = (time.perf_counter() - t_disp) / eff
            self._step_ema = dt if self._step_ema == 0.0 \
                else 0.5 * self._step_ema + 0.5 * dt
            self.steps += eff
            if self.paged:
                for i, s in enumerate(self.slots):
                    if s is not None:
                        self._lens[i] += int(out_m[i].sum()) if use_spec \
                            else eff
            now = time.perf_counter()
            for i in live:
                s = self.slots[i]
                if use_spec:
                    committed = [int(t) for st in range(eff)
                                 for t in out_g[i, st, :out_m[i, st]]]
                    prop = int(out_p[i].sum())
                    self.spec_commits += len(committed)
                    self.draft_proposed += prop
                    self.draft_accepted += len(committed) - eff
                    ts = self._spec_task_stats.setdefault(s.task_id, [0, 0])
                    ts[0] += prop
                    ts[1] += len(committed) - eff
                else:
                    committed = [int(t) for t in out[i]]
                # fair-share accounting charges tokens actually COMMITTED
                # for this stream (accepted + corrected), never a flat
                # chunk x active_slots — see take_decode_charges()
                self._decode_charges[(s.task_id, s.rid)] += len(committed)
                take = min(len(committed), s.max_new - len(s.tokens))
                for t in committed[:take]:
                    s.tokens.append(t)
                    if s.eos_id is not None and t == s.eos_id:
                        break
                # quarantine check only for LIVE slots: a freed slot's
                # garbage row may legitimately go non-finite (stale scales)
                # and must not trip anything
                if not fin[i]:
                    s.done = True
                    s.status = "quarantined"
                    self.quarantines += 1
                    finished.append(i)
                elif len(s.tokens) >= s.max_new or (
                        s.eos_id is not None and s.tokens[-1] == s.eos_id):
                    s.done = True
                    finished.append(i)
            if use_spec:
                self.spec_dispatches += 1
                rate = sum(int(out_m[i].sum()) for i in live) \
                    / max(eff * len(live), 1)
                self._spec_accept_ema = rate if self._spec_accept_ema == 0.0 \
                    else 0.5 * self._spec_accept_ema + 0.5 * rate
                if self._spec_probe:
                    # dry probe (1.0 committed/slot-step == zero accepts)
                    # -> back off; any acceptance -> restore the base
                    # cadence and let the EMA drive re-promotion
                    self._spec_probe_interval = min(
                        self._spec_probe_interval * 2,
                        self.spec_probe_every * 16) if rate <= 1.0 \
                        else self.spec_probe_every
            self._maybe_refresh_scales(np.asarray(drift))
        retired += [self.leave(i) for i in finished]
        self.last_chunk_s = time.perf_counter() - t0
        return retired

    def drain(self) -> list[DecodeSlot]:
        """Step until every occupied slot retires (and, paged, every deferred
        admission has been served)."""
        out = []
        while self.active_count() or self.pending:
            out += self.step_chunk()
        return out

    # ---- engine snapshot / restore (durability layer) ----
    _COUNTERS = ("steps", "admissions", "deferrals", "preemptions",
                 "prefix_hits", "shared_pages_mapped", "scale_refreshes",
                 "hol_bypasses", "_hol_skips", "quarantines",
                 "deadline_cancels", "deadline_sheds", "stranded_rejections",
                 "cancels", "spilled_pages", "restored_pages",
                 "digest_failures", "spill_resumes", "spill_prefix_hits",
                 "deadline_clamps", "tail_tokens_computed",
                 "prefill_tokens_saved", "draft_proposed", "draft_accepted",
                 "spec_dispatches", "spec_commits", "spec_fallbacks")

    def _config_dict(self) -> dict:
        """Constructor kwargs that rebuild an identical engine."""
        return {
            "num_slots": self.num_slots, "max_new": self.max_new,
            "chunk": self.chunk, "kv_quant": self.kv_quant,
            "eos_id": self.eos_id, "prompt_buckets": self.prompt_buckets,
            "temperature": self.temperature, "top_k": self.top_k,
            "paged": True, "page_size": self.page_size,
            "total_pages": self.total_pages,
            "prefix_sharing": self.prefix_sharing,
            "scale_refresh": self.scale_refresh,
            "pending_lookahead": self.pending_lookahead,
            "hol_skip_cap": self.hol_skip_cap,
            "deadline_clamp": self.deadline_clamp,
            "chunked_prefill": self.chunked_prefill,
            "spec_k": self.spec_k,
            "spec_force_fill": self.spec_force_fill,
            "spec_disable_below": self.spec_disable_below,
            "spec_probe_every": self.spec_probe_every,
            "enc_len": self.enc_len,
        }

    def snapshot(self) -> EngineSnapshot:
        """Capture the engine's FULL logical state between chunks: used-page
        contents (D2H) with per-page sha256 digests, page tables, refcounts,
        the chained-digest prefix registry, per-slot sampling/PRNG/deadline
        state, the pending queue and counters. The snapshot is isolated
        (deep copies) — the live engine can keep running — and the spill
        arena rides along BY REFERENCE (it is host RAM already). Paged-only:
        the dense layout has no allocator state worth surviving a reset."""
        import copy
        assert self.paged, "snapshot/restore is a paged-arena feature"
        used = np.nonzero(self._page_refs > 0)[0].astype(np.int32)
        idx = jnp.asarray(used)
        pages, slot_state = [], []
        for sub in self._paged_subs():
            host = jax.device_get({
                "k": sub["k"][:, idx], "v": sub["v"][:, idx],
                "k_scale": sub["k_scale"][:, idx],
                "v_scale": sub["v_scale"][:, idx],
                "slot_k_scale": sub["slot_k_scale"],
                "slot_v_scale": sub["slot_v_scale"],
                "k_max": sub["k_max"], "v_max": sub["v_max"],
            })
            pages.append({k: np.asarray(host[k])
                          for k in ("k", "v", "k_scale", "v_scale")})
            slot_state.append({k: np.asarray(host[k])
                               for k in ("slot_k_scale", "slot_v_scale",
                                         "k_max", "v_max")})
        snap = EngineSnapshot(
            config=self._config_dict(),
            used_pages=used, pages=pages, page_digests={},
            slot_state=slot_state,
            ptab=self._ptab.copy(), held=self._held.copy(),
            lens=self._lens.copy(), page_refs=self._page_refs.copy(),
            slot_adapters=self._slot_adapters.copy(),
            tokens=np.asarray(self._tokens), keys=np.asarray(self._keys),
            slots=copy.deepcopy(self.slots),
            pending=copy.deepcopy(list(self.pending)),
            rejected=copy.deepcopy(self.rejected),
            registry=dict(self._prefix_registry),
            page_key=dict(self._page_key),
            counters={k: getattr(self, k) for k in self._COUNTERS},
            spill=self.spill,
            # fixed-size per-slot dense state (recurrent / cross K/V): the
            # page capture above covers only the paged arena
            dense_state=capture_dense_state(self.pool)
            if self.plan.needs_state_slots else None)
        snap.counters["admitted_log"] = list(self.admitted_log)
        snap.page_digests = {int(p): snap.page_digest(i)
                             for i, p in enumerate(used)}
        return snap

    @classmethod
    def restore(cls, fm: PhysicalFM, snap: EngineSnapshot, *,
                reuse_jits_from: Optional["DecodeEngine"] = None
                ) -> "DecodeEngine":
        """Rebuild a fresh engine (and device arena) from a snapshot. Every
        restored page's sha256 digest is recomputed and verified BEFORE any
        stream can decode against it: a corrupted page drops out of the
        registry and every live stream mapping it is requeued through the
        lossless fold-and-re-prefill path (``digest_failures`` counted) —
        recovery can recompute, but it can never serve poisoned KV.

        ``reuse_jits_from`` shares the old engine's jit caches when its
        config matches (an in-process restore after a device reset — the
        executables are code, not device state), making the restored engine
        recompile-free from the first chunk. A cross-process restore (via
        ``checkpoint.ckpt.load_snapshot``) recompiles on first use like any
        fresh engine."""
        import copy
        eng = cls(fm, **snap.config)
        if reuse_jits_from is not None and \
                reuse_jits_from._config_dict() == snap.config and \
                reuse_jits_from.fm is fm:
            for name in ("_jit_prefill", "_jit_decode", "_jit_write",
                         "_jit_rescale", "_jit_gather", "_jit_page_restore",
                         "_jit_slot_restore"):
                setattr(eng, name, getattr(reuse_jits_from, name))
        used = np.asarray(snap.used_pages)
        bad = [int(p) for i, p in enumerate(used)
               if snap.page_digest(i) != snap.page_digests[int(p)]]
        # rebuild the device arena from the host capture: full-shape host
        # arrays (zeros outside used pages), one upload per leaf
        for j, sub in enumerate(eng._paged_subs()):
            cap, st = snap.pages[j], snap.slot_state[j]
            for k in ("k", "v", "k_scale", "v_scale"):
                full = np.zeros(sub[k].shape, np.asarray(cap[k]).dtype)
                if len(used):
                    full[:, used] = cap[k]
                sub[k] = jnp.asarray(full)
            for k in ("slot_k_scale", "slot_v_scale", "k_max", "v_max"):
                sub[k] = jnp.asarray(st[k])
            sub["len"] = jnp.asarray(np.broadcast_to(
                snap.lens[None].astype(np.int32),
                (sub["len"].shape[0], len(snap.lens))))
        eng._ptab = snap.ptab.copy()
        eng._held = snap.held.copy()
        eng._lens = snap.lens.copy()
        eng._page_refs = snap.page_refs.copy()
        eng._free_pages = [p for p in range(eng.total_pages - 1, TRASH_PAGE,
                                            -1) if eng._page_refs[p] == 0]
        eng._prefix_registry = dict(snap.registry)
        eng._page_key = dict(snap.page_key)
        eng._ptab_dirty = True
        eng._slot_adapters = snap.slot_adapters.copy()
        eng._seg_key = None
        eng._tokens = jnp.asarray(snap.tokens)
        eng._keys = jnp.asarray(snap.keys)
        eng.slots = copy.deepcopy(snap.slots)
        eng.pending = collections.deque(copy.deepcopy(snap.pending))
        eng.rejected = copy.deepcopy(snap.rejected)
        if getattr(snap, "dense_state", None) is not None:
            eng.pool = restore_dense_state(eng.pool, snap.dense_state)
        if eng.state_pool is not None:
            # re-mark live slots BEFORE the bad-page requeue below: its
            # _preempt path frees the victim's state slot
            for i, s in enumerate(eng.slots):
                if s is not None:
                    eng.state_pool.alloc(i)
        counters = dict(snap.counters)
        eng.admitted_log = list(counters.pop("admitted_log", []))
        for k in cls._COUNTERS:
            setattr(eng, k, counters.get(k, getattr(eng, k)))
        if snap.spill is not None:
            eng.spill = snap.spill
        # digest-verification contract: streams mapping a corrupted page
        # requeue through the fold (their tokens are host state and intact);
        # the corrupt page's registry entry is gone before any join can map
        # it — the spill capture inside the requeue is suppressed because
        # the device content being captured is exactly what failed to verify
        for p in bad:
            eng.digest_failures += 1
            key = eng._page_key.pop(p, None)
            if key is not None:
                eng._prefix_registry.pop(key, None)
        if bad:
            badset = set(bad)
            for i, s in enumerate(eng.slots):
                if s is None or s.done:
                    continue
                if badset & {int(x) for x in eng._ptab[i, :eng._held[i]]}:
                    sp, eng.spill = eng.spill, None
                    try:
                        eng._preempt(i)
                    finally:
                        eng.spill = sp
        return eng
