"""Continuous-batching decode engine: segmented-LoRA token serving over a
persistent int8 KV pool — dense slot-contiguous or block-paged.

Autoregressive serving is where FMplex's co-location wins compound: every
decode step re-uses the shared backbone across all co-resident tasks, so the
per-step cost of multi-task isolation must be ~zero. The engine owns:

  * a **slot pool** — a fixed, bucketed number of decode slots backed by one
    persistent KV cache allocated ONCE. Two layouts:

      - *dense* (``paged=False``): ``lm.init_cache(kv_quant=True)`` — one
        contiguous ``(num_slots, s_max)`` int8 region per slot with
        per-(slot, kv-head) scales fixed at prefill admission
        (``kernels.decode_attention_int8``). Every stream RESERVES its
        worst-case length, so the slot count — not memory — caps colocation.
      - *paged* (``paged=True``): one global arena of ``total_pages``
        fixed-size pages (int8 K/V + per-(page, kv-head) scales,
        ``page_size`` tokens each) shared by every slot, addressed through a
        device-resident per-slot page table. Admission prefill scatters the
        prompt into freshly allocated pages, decode appends a page on demand
        (the host allocator tops slots up to ``len + chunk`` tokens before
        each chunk), and retire returns pages to the free list — so
        concurrency is bounded by TOTAL TOKENS IN FLIGHT, not
        ``num_slots × s_max``. Attention gathers K/V through the page table
        inside the Pallas kernel grid (``kernels.paged_decode_attention``;
        jnp gather oracle on CPU). Page 0 is the reserved trash page: free
        slots keep stepping (static shapes) and their garbage writes land
        there, never in a live stream's pages.

  * **admission prefill** — a joining request's prompt runs a single jitted
    prefill (LoRA applied, K/V quantized in-graph) and is scattered into its
    slot (dense: one ``dynamic_update_slice`` per cache leaf; paged: a page
    scatter into the allocated page ids). Admission is **variable-length**:
    prompts are right-padded to the smallest of 2-3 *prompt-length buckets*
    (a static jit-cache key), while the TRUE length rides along as a traced
    operand — pad keys are masked out of attention, the cache ``len`` is
    per-row exact, and the first token comes from the last REAL prompt
    position. On a full pool, a paged ``join`` **defers** (FIFO pending
    queue drained as slots and pages free up) instead of raising — a burst
    of admissions beyond capacity queues and drains across chunks; the
    dense layout keeps the historical raise.

  * **chunked decode** — ``step_chunk`` advances ALL occupied slots ``chunk``
    tokens under one jitted ``lax.scan`` (device-resident sampling: one
    dispatch and one host sync per chunk, not per token), greedy by default
    with per-slot PRNG key state for temperature/top-k sampling. If the free
    list cannot cover a live stream's next chunk, the youngest live stream is
    **preempted**: its pages return to the pool and it re-queues with its
    generated prefix folded into the prompt (re-admission also refreshes its
    int8 scales). Memory-aware loop admission (``ServeLoop``) keeps a chunk
    of decode headroom per admit precisely so this path stays rare.

  * **cached SGMV metadata** — segment metadata for the S=1 token co-batch is
    built once per batch *composition* and reused every step; steady-state
    decode performs zero host-side sorts and zero recompiles: jits stay keyed
    on (slot bucket, adapter slot bucket, chunk) and
    (adapter slot bucket, prompt bucket) — page tables, true lengths and page
    ids are all TRACED operands, so join/leave churn and page allocation
    never retrace. The LoRA path per jit key follows
    ``PhysicalFM.resolve_lora_impl`` (gather vs segmented crossover;
    ``lora_impl="auto"`` is the server default).

int8 KV scale drift: quantization scales are fixed ONCE at prefill admission
(paged: stamped per page from the slot's admission scales). Decode-era K/V
whose magnitude outgrows the prompt-era range are clipped to ±127·scale — the
engine never rescales a live slot. The divergence this introduces is bounded
and grows slowly with decode length: empirically
(``tests/test_decode_engine.py::test_int8_scale_drift_bounded``) a decode
tail 3× longer than the prompt whose K/V magnitude drifts to 3× the
admission-scale range keeps attention-output relative divergence under ~0.8
(vs ~0.06 with no drift), and at the model level a decode 4× the prompt
length keeps logit relative divergence under 0.5. Decodes far beyond a
``max_new`` of a few hundred tokens should either re-admit (prefill on the
generated prefix refreshes scales — the paged preemption path does exactly
this) or use ``kv_quant=False`` with the dense layout. Per-page scales make
periodic per-page rescale a natural follow-up (see ROADMAP).
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.physical import PAD_SENTINEL, PhysicalFM, bucket_for
from repro.models import lm

FREE = PAD_SENTINEL   # free-slot adapter sentinel (same as run_batch padding)
TRASH_PAGE = 0        # arena page absorbing free-slot garbage writes


def default_prompt_buckets(prompt_len: int) -> tuple[int, ...]:
    """2-3 admission buckets: quarter, half and full ``prompt_len`` (deduped,
    ascending). Small enough that every bucket's prefill executable warms
    quickly; coarse enough that steady state never recompiles."""
    return tuple(sorted({max(1, prompt_len // 4),
                         max(1, prompt_len // 2), prompt_len}))


def make_sampler(temperature: float, top_k: int):
    """Token sampler used inside the jitted prefill/decode graphs.

    ``sample(logits (B, V), keys (B, 2) uint32) -> (tokens (B,), keys')``.
    Greedy when ``temperature <= 0`` (keys pass through untouched); otherwise
    temperature-scaled categorical over the top-k logits, one PRNG key per
    row so co-batched streams sample independently."""
    if temperature <= 0:
        def sample(logits, keys):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
        return sample

    def sample(logits, keys):
        split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
        next_keys, use_keys = split[:, 0], split[:, 1]
        l = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jax.lax.top_k(l, top_k)[0][:, -1]
            l = jnp.where(l >= kth[:, None], l, -1e30)
        toks = jax.vmap(jax.random.categorical)(use_keys, l)
        return toks.astype(jnp.int32), next_keys
    return sample


@dataclasses.dataclass
class DecodeSlot:
    """One occupied decode stream."""
    rid: int
    task_id: str
    adapter_slot: int
    max_new: int
    eos_id: Optional[int]
    tokens: list          # generated token ids (first one from prefill)
    t_join: float
    t_first: float        # wall time of the first generated token (TTFT end)
    prompt_tokens: int = 0   # TRUE (post-truncation) admitted prompt length
    done: bool = False
    prompt: Optional[np.ndarray] = None   # admitted prompt (paged: requeue)
    adapter_id: Optional[str] = None


@dataclasses.dataclass
class _PendingJoin:
    """A deferred admission (paged pool full) waiting in the FIFO queue."""
    task_id: str
    prompt: np.ndarray
    adapter_id: Optional[str]
    max_new_tokens: int
    rid: int
    eos_id: Optional[int]
    resume: Optional[DecodeSlot] = None   # preempted stream being re-admitted


class DecodeEngine:
    """Slot-based continuous-batching token server bound to one PhysicalFM."""

    def __init__(self, fm: PhysicalFM, *, num_slots: int = 8,
                 prompt_len: Optional[int] = None, max_new: int = 32,
                 chunk: int = 4, kv_quant: bool = True,
                 eos_id: Optional[int] = None,
                 prompt_buckets: Optional[tuple] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, paged: bool = False,
                 page_size: int = 16, total_pages: Optional[int] = None):
        cfg = fm.cfg
        assert cfg.vocab_size > 0 and not cfg.is_representation, \
            "DecodeEngine serves generative decoder LMs (vocab head required)"
        assert not cfg.is_encoder_decoder, \
            "enc-dec decode needs per-slot encoder state (not supported yet)"
        self.fm = fm
        self.cfg = cfg
        self.num_slots = bucket_for(num_slots)
        self.prompt_len = prompt_len or fm.input_len
        # variable-length admission masks pads out of ATTENTION; recurrent
        # blocks (mamba/xLSTM) would still scan right-pad tokens into their
        # state, so hybrid stacks keep the single full-length bucket with
        # the legacy left-pad (pads attended, positionally before the prompt)
        from repro.configs.base import ATTN
        self.var_len = all(b == ATTN for b in cfg.blocks)
        if prompt_buckets is None:
            prompt_buckets = default_prompt_buckets(self.prompt_len) \
                if self.var_len else (self.prompt_len,)
        self.prompt_buckets = tuple(sorted(set(int(b) for b in prompt_buckets)))
        self.prompt_len = self.prompt_buckets[-1]   # largest bucket is the cap
        self.max_new = max_new
        self.chunk = chunk
        self.kv_quant = kv_quant
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample = make_sampler(self.temperature, self.top_k)
        # per-slot PRNG key state; threaded through the decode scan carry
        self._keys = jax.random.split(jax.random.PRNGKey(sample_seed),
                                      self.num_slots)
        self.s_max = self.prompt_len + max_new + 1
        self.paged = paged
        if paged:
            assert kv_quant, "the paged arena is int8-only (kv_quant=True)"
            assert self.var_len, \
                "paged pools need attention-only stacks (recurrent state " \
                "is per-slot dense)"
            self.page_size = page_size
            self.pages_per_slot = -(-self.s_max // page_size)
            if total_pages is None:        # dense-equivalent memory + trash
                total_pages = 1 + self.num_slots * self.pages_per_slot
            assert total_pages >= 2, "need at least one usable page"
            self.total_pages = total_pages
            self.pool = lm.init_cache(cfg, self.num_slots, self.s_max,
                                      kv_quant=True, paged=True,
                                      page_size=page_size,
                                      num_pages=total_pages)
            # host-side allocator state; the device page table is synced
            # from _ptab before any decode dispatch that follows a change
            self._free_pages = list(range(total_pages - 1, TRASH_PAGE, -1))
            self._ptab = np.zeros((self.num_slots, self.pages_per_slot),
                                  np.int32)
            self._held = np.zeros((self.num_slots,), np.int64)
            self._lens = np.zeros((self.num_slots,), np.int64)
            self._ptab_dirty = True
            self.pending: collections.deque[_PendingJoin] = collections.deque()
            self.deferrals = 0
            self.preemptions = 0
        else:
            # the persistent pool: allocated once, updated in place (donated)
            self.pool = lm.init_cache(cfg, self.num_slots, self.s_max,
                                      kv_quant=kv_quant)
            self.pending = collections.deque()
        self._tokens = jnp.zeros((self.num_slots,), jnp.int32)  # last token/slot
        self.slots: list[Optional[DecodeSlot]] = [None] * self.num_slots
        self._slot_adapters = np.full((self.num_slots,), FREE, np.int32)
        self._jit_prefill: dict[tuple, Callable] = {}
        self._jit_decode: dict[tuple, Callable] = {}
        self._jit_write: dict = {}      # dense: {None: fn}; paged: {npages: fn}
        self._seg_key = None        # composition signature of cached metadata
        self._seg_dev = None        # device-uploaded (perm, inv, blocks)
        self.steps = 0              # decode steps executed (all slots)
        self.last_chunk_s = 0.0

    # ---- occupancy ----
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def pending_count(self) -> int:
        return len(self.pending)

    def pending_rids(self) -> list[int]:
        return [p.rid for p in self.pending]

    def pending_task_ids(self) -> list[str]:
        return [p.task_id for p in self.pending]

    def compile_count(self) -> int:
        """Total jitted executables (prefill + decode + pool writes); steady
        state across request join/leave churn must not grow this."""
        fns = (list(self._jit_prefill.values()) +
               list(self._jit_decode.values()) +
               list(self._jit_write.values()))
        return sum(f._cache_size() if hasattr(f, "_cache_size") else 1
                   for f in fns)

    # ---- page accounting (paged layout) ----
    def free_page_count(self) -> int:
        return len(self._free_pages) if self.paged else 0

    def used_page_count(self) -> int:
        if not self.paged:
            return 0
        return (self.total_pages - 1) - len(self._free_pages)

    def page_occupancy(self) -> float:
        """Fraction of usable (non-trash) pages held by streams."""
        if not self.paged:
            return 0.0
        return self.used_page_count() / max(self.total_pages - 1, 1)

    def _pages_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.page_size)

    def _imminent_page_need(self) -> int:
        """Pages the LIVE streams will allocate for their next chunk — the
        watermark an admission must clear on top of its own need, so letting
        one more stream in doesn't immediately preempt a running one."""
        need = 0
        for i, s in enumerate(self.slots):
            if s is not None and not s.done:
                need += max(0, self._pages_for(self._lens[i] + self.chunk)
                            - self._held[i])
        return need

    def _admission_need(self, prompt_tokens: int) -> int:
        plen = self.bucket_for_prompt(min(max(prompt_tokens, 1),
                                          self.prompt_len))
        return (self._pages_for(self._adm_s_max(plen))
                + self._pages_for(self.chunk)
                + self._imminent_page_need())

    def can_admit(self, prompt_tokens: int = 1) -> bool:
        """Would an admission of an ``prompt_tokens``-token prompt proceed
        right now? Dense: a free slot. Paged: a free slot, nothing already
        deferred ahead of it (FIFO), and free pages covering the prompt's
        admission bucket PLUS a chunk of decode headroom for this stream AND
        for every live one — the memory-aware gate ``ServeLoop`` consults
        before dispatching a prefill. Deliberately conservative by one chunk
        per live stream: over-admitting converts into preemptions, which
        redo prefill work and can truncate long streams."""
        if not self.free_slots():
            return False
        if not self.paged:
            return True
        if self.pending:
            return False
        return len(self._free_pages) >= self._admission_need(prompt_tokens)

    def _take_pages(self, n: int) -> np.ndarray:
        assert len(self._free_pages) >= n
        return np.array([self._free_pages.pop() for _ in range(n)], np.int32)

    def _release_slot_pages(self, slot: int):
        self._free_pages.extend(int(p) for p in
                                self._ptab[slot, :self._held[slot]])
        self._ptab[slot] = TRASH_PAGE
        self._held[slot] = 0
        self._lens[slot] = 0
        self._ptab_dirty = True

    def _sync_page_table(self):
        """Push the host page table to every attention sublayer's device
        leaf. Values-only: the (num_slots, pages_per_slot) shape is static,
        so syncing never retraces."""
        if not self._ptab_dirty:
            return
        for sub in self.pool:
            if isinstance(sub, dict) and "page_table" in sub:
                nper = sub["page_table"].shape[0]
                sub["page_table"] = jnp.asarray(
                    np.broadcast_to(self._ptab[None],
                                    (nper,) + self._ptab.shape))
        self._ptab_dirty = False

    # ---- jitted planes ----
    @staticmethod
    def _donate(*argnums):
        return argnums if jax.default_backend() != "cpu" else ()

    def _impl(self, rows: int, cap: int) -> str:
        """LoRA path for a ``rows``-row co-batch. Resolved from the slot
        bucket (not the live adapter count) so the choice is stable within
        each compiled (rows, cap) jit key."""
        return self.fm.resolve_lora_impl(rows, num_adapters=cap)

    def _adm_s_max(self, plen: int) -> int:
        """Admission-prefill cache length for one prompt bucket: the paged
        scatter needs a whole number of pages; dense scatters into s_max."""
        if self.paged:
            return self._pages_for(plen) * self.page_size
        return self.s_max

    def _prefill_fn(self, cap: int, plen: int):
        """Admission prefill for one prompt-length bucket. The bucket length
        is a static jit key; the TRUE prompt length is a traced operand, so
        every length within the bucket reuses the executable."""
        key = (cap, plen)
        if key not in self._jit_prefill:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(1, cap)
            s_max, kvq, sample = self._adm_s_max(plen), self.kv_quant, \
                self._sample

            @jax.jit
            def run(params, tokens, true_len, rng_key, lora_stack,
                    adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                cache = lm.init_cache(cfg, 1, s_max, kv_quant=kvq)
                logits, cache = lm.prefill(
                    params, cfg, tokens=tokens, cache=cache, lora=lora_stack,
                    adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg,
                    seq_lens=true_len)
                first, rng_key = sample(logits, rng_key)
                return first, rng_key, cache

            self._jit_prefill[key] = run
        return self._jit_prefill[key]

    def _write_fn(self):
        """Dense admission scatter: one dynamic_update_slice per cache leaf
        along the slot (batch) axis."""
        if None not in self._jit_write:
            donate = self._donate(0)

            def write(pool, cache, slot):
                # every cache leaf is (nper, batch, ...): scatter the one-row
                # prefill cache into the pool's slot along the batch axis
                return jax.tree.map(
                    lambda p, c: jax.lax.dynamic_update_slice_in_dim(
                        p, c.astype(p.dtype), slot, axis=1), pool, cache)

            self._jit_write[None] = jax.jit(write, donate_argnums=donate)
        return self._jit_write[None]

    def _paged_write_fn(self, npages: int):
        """Paged admission scatter for one prompt bucket (``npages`` pages):
        the one-row prefill cache reshapes into pages and scatters into the
        arena at the allocated page ids (traced), the admission scales stamp
        both the pages and the slot's scale row, and the slot's ``len`` is
        set to the TRUE prompt length. Page ids, slot and length are traced
        operands — allocation churn never retraces."""
        if npages not in self._jit_write:
            donate = self._donate(0)
            ps = self.page_size

            def write(pool, cache, slot, page_idx, true_len):
                out = []
                for psub, csub in zip(pool, cache):
                    kq = csub["k"][:, 0]            # (nper, S, kv, hd)
                    nper, _, kv, hd = kq.shape
                    kq = kq.reshape(nper, npages, ps, kv, hd)
                    vq = csub["v"][:, 0].reshape(nper, npages, ps, kv, hd)
                    ks = csub["k_scale"][:, 0]      # (nper, kv)
                    vs = csub["v_scale"][:, 0]
                    d = dict(psub)
                    d["k"] = psub["k"].at[:, page_idx].set(
                        kq.astype(psub["k"].dtype))
                    d["v"] = psub["v"].at[:, page_idx].set(
                        vq.astype(psub["v"].dtype))
                    d["k_scale"] = psub["k_scale"].at[:, page_idx].set(
                        jnp.broadcast_to(ks[:, None], (nper, npages, kv)))
                    d["v_scale"] = psub["v_scale"].at[:, page_idx].set(
                        jnp.broadcast_to(vs[:, None], (nper, npages, kv)))
                    d["slot_k_scale"] = psub["slot_k_scale"].at[:, slot].set(ks)
                    d["slot_v_scale"] = psub["slot_v_scale"].at[:, slot].set(vs)
                    d["len"] = psub["len"].at[:, slot].set(true_len)
                    out.append(d)
                return out

            self._jit_write[npages] = jax.jit(write, donate_argnums=donate)
        return self._jit_write[npages]

    def _decode_fn(self, cap: int, chunk: int):
        key = (self.num_slots, cap, chunk)
        if key not in self._jit_decode:
            cfg, bt = self.cfg, self.fm.seg_block_t
            impl = self._impl(self.num_slots, cap)
            donate = self._donate(1)

            sample = self._sample

            def run(params, pool, tokens, keys, lora_stack, adapter_idx,
                    perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}

                def body(carry, _):
                    pool, tok, keys = carry
                    logits, pool = lm.decode_step(
                        params, cfg, tokens=tok, cache=pool, lora=lora_stack,
                        adapter_idx=adapter_idx, lora_impl=impl, lora_seg=seg)
                    nxt, keys = sample(logits, keys)
                    return (pool, nxt, keys), nxt

                (pool, tok, keys), out = jax.lax.scan(
                    body, (pool, tokens, keys), None, length=chunk)
                return pool, tok, keys, out.T                # (slots, chunk)

            self._jit_decode[key] = jax.jit(run, donate_argnums=donate)
        return self._jit_decode[key]

    # ---- segment metadata (per composition, not per token) ----
    def _segments(self, cap: int):
        if self._impl(self.num_slots, cap) != "segmented":
            z = jnp.zeros((1,), jnp.int32)      # gather never reads these
            return z, z, z
        key = (self._slot_adapters.tobytes(), cap)
        if key != self._seg_key:
            perm, inv, blocks = self.fm.segment_meta(self._slot_adapters, cap, 1)
            self._seg_dev = (jnp.asarray(perm), jnp.asarray(inv),
                             jnp.asarray(blocks))
            self._seg_key = key
        return self._seg_dev

    def _prefill_segments(self, adapter_slot: int, cap: int, plen: int):
        if self._impl(1, cap) != "segmented":
            z = jnp.zeros((1,), jnp.int32)
            return z, z, z
        ids = np.full((plen,), adapter_slot, np.int32)
        perm, inv, blocks = self.fm.segment_meta(ids, cap, 1)
        return jnp.asarray(perm), jnp.asarray(inv), jnp.asarray(blocks)

    def bucket_for_prompt(self, n: int) -> int:
        """Smallest admission bucket holding an n-token prompt."""
        for b in self.prompt_buckets:
            if n <= b:
                return b
        return self.prompt_buckets[-1]

    # ---- serving surface ----
    def join(self, task_id: str, prompt: np.ndarray, *,
             adapter_id: Optional[str] = None, max_new_tokens: int = 8,
             rid: int = -1, eos_id: Optional[int] = None) -> int:
        """Admit one request: prefill its prompt (LoRA applied, K/V int8-
        quantized in-graph), scatter it into a free slot (paged: into freshly
        allocated pages), produce the first token. Returns the slot index.

        A full pool behaves per layout: the dense pool raises (its capacity
        is the static slot count — the caller must drain first); the paged
        pool **defers** — the request queues FIFO and admits during a later
        ``step_chunk`` once a slot AND enough free pages exist — returning
        -1. Deferral, not failure: a burst beyond capacity drains instead of
        crashing the serving tick.

        Admission is variable-length: the prompt is right-padded to the
        smallest prompt-length bucket that holds it (a static jit key —
        at most ``len(prompt_buckets)`` prefill executables ever compile)
        while the true length is a traced operand masking the pads out of
        attention and the KV cache. Prompts longer than the largest bucket
        keep their LAST ``prompt_len`` tokens (causal LM: the suffix
        matters) — that loses context, so it WARNS; the decode budget clamps
        to the pool's ``max_new`` capacity."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = _PendingJoin(task_id=task_id, prompt=prompt,
                           adapter_id=adapter_id,
                           max_new_tokens=max_new_tokens, rid=rid,
                           eos_id=eos_id)
        if self.paged and not self.can_admit(len(prompt)):
            # deferral must be able to END: a request whose prompt bucket +
            # chunk headroom exceeds the whole arena would pend forever
            # (drain() and the serve loop would spin) — that is a pool
            # configuration error, not backpressure
            plen = self.bucket_for_prompt(min(max(len(prompt), 1),
                                              self.prompt_len))
            base = self._pages_for(self._adm_s_max(plen)) + \
                self._pages_for(self.chunk)
            if base > self.total_pages - 1:
                raise ValueError(
                    f"prompt needs {base} pages (bucket {plen} + chunk "
                    f"headroom) but the arena only has "
                    f"{self.total_pages - 1} usable pages; raise "
                    f"total_pages or shrink prompt_buckets/chunk")
            self.pending.append(req)
            self.deferrals += 1
            return -1
        if not self.free_slots():
            raise RuntimeError("no free decode slots; step_chunk() first")
        return self._admit_now(req)

    def _admit_now(self, req: _PendingJoin) -> int:
        prompt = req.prompt
        if len(prompt) > self.prompt_len:
            warnings.warn(
                f"prompt of {len(prompt)} tokens exceeds the engine's largest "
                f"admission bucket ({self.prompt_len}); left-truncating to "
                f"the last {self.prompt_len} tokens (context is lost — size "
                f"prompt_buckets to the workload)", RuntimeWarning,
                stacklevel=2)
            prompt = prompt[-self.prompt_len:]     # causal LM: suffix matters
        true_prompt = prompt
        if self.var_len:
            true_len = max(1, len(prompt))
            plen = self.bucket_for_prompt(true_len)
            if len(prompt) < plen:                 # right-pad to the bucket
                prompt = np.concatenate(
                    [prompt, np.zeros(plen - len(prompt), np.int32)])
        else:                                      # hybrid stack: legacy pad
            plen = true_len = self.prompt_len
            if len(prompt) < plen:
                prompt = np.concatenate(
                    [np.zeros(plen - len(prompt), np.int32), prompt])
        max_new_tokens = max(1, min(req.max_new_tokens, self.max_new))
        slot = self.free_slots()[0]
        cap = self.fm.adapters.capacity()
        aslot = self.fm.adapters.index(req.adapter_id)
        perm, inv, blocks = self._prefill_segments(aslot, cap, plen)
        first, key, cache = self._prefill_fn(cap, plen)(
            self.fm.params, jnp.asarray(prompt[None]),
            jnp.full((1,), true_len, jnp.int32), self._keys[slot][None],
            self.fm.adapters.stacked(), jnp.full((1,), aslot, jnp.int32),
            perm, inv, blocks)
        self._keys = self._keys.at[slot].set(key[0])
        if self.paged:
            npages = self._pages_for(self._adm_s_max(plen))
            pages = self._take_pages(npages)
            self.pool = self._paged_write_fn(npages)(
                self.pool, cache, jnp.int32(slot), jnp.asarray(pages),
                jnp.int32(true_len))
            self._ptab[slot, :npages] = pages
            self._held[slot] = npages
            self._lens[slot] = true_len
            # trim: bucket padding beyond the true length scattered zero
            # pages — return them now; decode growth re-allocates on demand
            keep = self._pages_for(true_len)
            if keep < npages:
                self._free_pages.extend(int(p) for p in
                                        self._ptab[slot, keep:npages])
                self._ptab[slot, keep:npages] = TRASH_PAGE
                self._held[slot] = keep
            self._ptab_dirty = True
        else:
            self.pool = self._write_fn()(self.pool, cache, slot)
        self._tokens = self._tokens.at[slot].set(first[0])
        now = time.perf_counter()
        tok0 = int(first[0])
        eos = self.eos_id if req.eos_id is None else req.eos_id
        if req.resume is not None:
            # preempted stream resuming: keep its identity/latency stamps,
            # append the re-prefill's next token to the existing stream.
            # s.prompt deliberately stays the ORIGINAL prompt — s.tokens
            # still holds everything generated, so a SECOND preemption
            # rebuilds prompt+tokens without duplicating the first resume's
            # prefix (and re-truncates from the fullest context available)
            s = req.resume
            s.tokens.append(tok0)
            s.done = (len(s.tokens) >= s.max_new or
                      (s.eos_id is not None and tok0 == s.eos_id))
            self.slots[slot] = s
        else:
            self.slots[slot] = DecodeSlot(
                rid=req.rid, task_id=req.task_id, adapter_slot=aslot,
                max_new=max_new_tokens, eos_id=eos,
                tokens=[tok0], t_join=now, t_first=now,
                prompt_tokens=true_len, prompt=true_prompt,
                adapter_id=req.adapter_id,
                done=(max_new_tokens == 1 or (eos is not None and tok0 == eos)))
        self._slot_adapters[slot] = aslot
        self._seg_key = None                    # composition changed
        return slot

    def leave(self, slot: int) -> DecodeSlot:
        """Retire a slot (finished or cancelled) and free it for admission
        (paged: its pages return to the free list)."""
        s = self.slots[slot]
        assert s is not None, slot
        self.slots[slot] = None
        self._slot_adapters[slot] = FREE
        self._seg_key = None                    # composition changed
        if self.paged:
            self._release_slot_pages(slot)
        # keep the freed slot's cache length bounded while it idles
        for sub in self.pool:
            if isinstance(sub, dict) and "len" in sub:
                sub["len"] = sub["len"].at[:, slot].set(0)
        return s

    # ---- paged page-pressure handling ----
    def _preempt(self, slot: int):
        """Evict a live stream to reclaim its pages: it re-queues at the
        FRONT of the pending queue with its generated prefix folded into the
        prompt (re-admission also refreshes its int8 scales). Sampling
        streams lose PRNG continuity across a preemption; greedy streams
        resume exactly."""
        s = self.slots[slot]
        prompt = np.concatenate([
            np.asarray(s.prompt if s.prompt is not None else [], np.int32),
            np.asarray(s.tokens, np.int32)])
        self.slots[slot] = None
        self._slot_adapters[slot] = FREE
        self._seg_key = None
        self._release_slot_pages(slot)
        for sub in self.pool:
            if isinstance(sub, dict) and "len" in sub:
                sub["len"] = sub["len"].at[:, slot].set(0)
        self.pending.appendleft(_PendingJoin(
            task_id=s.task_id, prompt=prompt, adapter_id=s.adapter_id,
            max_new_tokens=s.max_new, rid=s.rid, eos_id=s.eos_id, resume=s))
        self.preemptions += 1

    def _ensure_chunk_pages(self):
        """Top every live slot up to ``len + chunk`` tokens of pages before
        the chunk dispatches. When the free list runs dry, preempt the
        youngest live streams (least work redone) until it doesn't; a single
        stream that cannot fit is a configuration error (pool smaller than
        one stream's chunk growth)."""
        while True:
            live = [i for i, s in enumerate(self.slots)
                    if s is not None and not s.done]
            preempted = False
            for i in live:
                if self.slots[i] is None:       # preempted by an earlier pass
                    continue
                need = self._pages_for(self._lens[i] + self.chunk) \
                    - self._held[i]
                if need <= 0:
                    continue
                while need > len(self._free_pages):
                    victims = [j for j in live
                               if j != i and self.slots[j] is not None
                               and not self.slots[j].done]
                    if not victims:
                        raise RuntimeError(
                            f"paged pool exhausted: {need} pages needed for "
                            f"one stream, {len(self._free_pages)} free and "
                            f"nothing left to preempt (total_pages="
                            f"{self.total_pages} is too small)")
                    self._preempt(min(
                        victims, key=lambda j: len(self.slots[j].tokens)))
                    preempted = True
                pages = self._take_pages(need)
                h = self._held[i]
                self._ptab[i, h:h + need] = pages
                self._held[i] = h + need
                self._ptab_dirty = True
            if not preempted:
                return

    def _drain_pending(self):
        """FIFO-admit deferred joins while slots and pages allow."""
        while self.pending and self.can_admit_pending():
            self._admit_now(self.pending.popleft())

    def can_admit_pending(self) -> bool:
        if not self.pending or not self.free_slots():
            return False
        return len(self._free_pages) >= \
            self._admission_need(len(self.pending[0].prompt))

    def step_chunk(self) -> list[DecodeSlot]:
        """Advance every occupied slot by up to ``chunk`` tokens under one
        jitted scan; retire and return the slots that finished. Paged:
        streams already done retire FIRST (their pages fund deferred
        admissions and spare a live stream from preemption), then deferred
        admissions drain into the freed capacity, then live slots top up
        with pages for the chunk and the page table syncs."""
        t0 = time.perf_counter()
        retired = [self.leave(i) for i, s in enumerate(self.slots)
                   if s is not None and s.done]
        if self.paged:
            self._drain_pending()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]
        if live and self.paged:
            self._ensure_chunk_pages()
            # preemption may have evicted members of the live set
            live = [i for i, s in enumerate(self.slots)
                    if s is not None and not s.done]
        finished = []
        if live:
            if self.paged:
                self._sync_page_table()
            cap = self.fm.adapters.capacity()
            perm, inv, blocks = self._segments(cap)
            self.pool, self._tokens, self._keys, out = \
                self._decode_fn(cap, self.chunk)(
                    self.fm.params, self.pool, self._tokens, self._keys,
                    self.fm.adapters.stacked(),
                    jnp.asarray(self._slot_adapters), perm, inv, blocks)
            out = np.asarray(out)               # one host sync per chunk
            self.steps += self.chunk
            if self.paged:
                for i, s in enumerate(self.slots):
                    if s is not None:
                        self._lens[i] += self.chunk
            now = time.perf_counter()
            for i in live:
                s = self.slots[i]
                take = min(self.chunk, s.max_new - len(s.tokens))
                for t in out[i, :take]:
                    s.tokens.append(int(t))
                    if s.eos_id is not None and int(t) == s.eos_id:
                        break
                if len(s.tokens) >= s.max_new or (
                        s.eos_id is not None and s.tokens[-1] == s.eos_id):
                    s.done = True
                    finished.append(i)
        retired += [self.leave(i) for i in finished]
        self.last_chunk_s = time.perf_counter() - t0
        return retired

    def drain(self) -> list[DecodeSlot]:
        """Step until every occupied slot retires (and, paged, every deferred
        admission has been served)."""
        out = []
        while self.active_count() or self.pending:
            out += self.step_chunk()
        return out
