"""Per-sublayer cache-manager plane: serving-state plans + state-slot pool.

The decode engine used to treat its cache as "one paged attention arena",
hard-gating every serving plane (var-len bucketed prefill, paged admission,
prefix sharing, speculation, spill, snapshot) to attention-only stacks. This
module makes the cache contract per-sublayer instead:

  * ``CachePlan.for_config`` walks the period layout and gives every
    sublayer a ``SublayerPlan`` — does its serving state live in the shared
    page arena (attention KV: grows with decoded tokens, int8, pageable) or
    in fixed-size per-slot state (recurrent conv/SSM/LSTM state and
    encoder-output cross K/V: written once at admission or advanced in
    place, no growth) — plus aggregate CAPABILITY flags the engine
    negotiates against instead of asserting:

      - ``prefix_sharing_ok`` / ``chunked_prefill_ok``: shared pages capture
        only attention KV. A recurrent sublayer's state at the shared-prefix
        boundary is stream-private and never mapped, so a sharer that skipped
        the prefix compute would decode from the wrong state — sharing stays
        attention-only and the engine demotes it cleanly on hybrid stacks.
      - ``speculative_ok``: draft rollback is a pure length/tracker reset on
        paged attention state; recurrent state advanced through rejected
        draft positions cannot rewind, and the verify forward has no
        encoder-decoder mode — speculation demotes to plain decode.
      - ``spill_resume_ok``: the stream spill captures pages + quantization
        trackers only. Stacks with per-slot dense state fall back to the
        fold-and-re-prefill preemption path, which recomputes recurrent
        state exactly.

  * ``StateSlotPool`` is the allocator for the fixed-size side: one state
    slot per live stream, allocated at admission and freed on every exit
    path (retire / preempt / cancel / quarantine), with occupancy gauges
    (in-use, peak, deferrals on slot pressure) mirroring the page gauges so
    hybrid occupancy is observable like page occupancy. The tensors
    themselves stay in the engine's pool (the batch axis IS the slot pool);
    this object owns lifecycle + accounting, which is what the admission
    gate and the property-test invariants consume.

  * ``capture_dense_state`` / ``restore_dense_state`` extend the snapshot /
    restore plane to the fixed-size side: recurrent subs capture every leaf,
    paged cross-attention subs capture their ``ck``/``cv`` sidecars, pure
    page-arena subs contribute nothing new (their per-slot trackers already
    ride ``EngineSnapshot.slot_state``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig

RECURRENT_KINDS = (MAMBA, MLSTM, SLSTM)


@dataclasses.dataclass(frozen=True)
class SublayerPlan:
    """Serving-state declaration for one sublayer of the period layout."""
    kind: str              # ATTN / MAMBA / MLSTM / SLSTM
    paged: bool            # state lives in the shared int8 page arena
    grows: bool            # state grows with decoded tokens (attention KV)
    has_cross: bool        # per-slot encoder-output K/V rides beside it

    @property
    def fixed_state(self) -> bool:
        """True when (part of) this sublayer's state is fixed-size per-slot
        dense state — recurrent state, or cross-attention sidecars."""
        return self.kind in RECURRENT_KINDS or self.has_cross


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """The whole stack's cache contract + negotiated capability flags."""
    sublayers: tuple[SublayerPlan, ...]
    paged: bool                 # a page arena exists (>= 1 paged sublayer)
    has_attention: bool
    has_recurrent: bool
    has_encoder: bool
    prefix_sharing_ok: bool
    chunked_prefill_ok: bool
    speculative_ok: bool
    spill_resume_ok: bool

    @property
    def needs_state_slots(self) -> bool:
        return any(s.fixed_state for s in self.sublayers)

    @classmethod
    def for_config(cls, cfg: ModelConfig, paged: bool) -> "CachePlan":
        from repro.models import blocks as blk
        layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
        has_attn = any(lay.kind == ATTN for lay in layout)
        has_rec = any(lay.kind in RECURRENT_KINDS for lay in layout)
        has_enc = cfg.is_encoder_decoder
        # a page arena only makes sense with attention KV to page; a pure
        # recurrent stack's whole serving state is fixed-size state slots
        paged = bool(paged and has_attn)
        subs = tuple(SublayerPlan(
            kind=lay.kind,
            paged=paged and lay.kind == ATTN,
            grows=lay.kind == ATTN,
            has_cross=lay.has_cross) for lay in layout)
        attn_only = not has_rec and not has_enc
        return cls(
            sublayers=subs, paged=paged, has_attention=has_attn,
            has_recurrent=has_rec, has_encoder=has_enc,
            prefix_sharing_ok=paged and attn_only,
            chunked_prefill_ok=paged and attn_only,
            speculative_ok=paged and attn_only,
            spill_resume_ok=paged and attn_only)


class StateSlotPool:
    """Lifecycle + gauges for the fixed-size per-slot serving state.

    One state slot per live stream, 1:1 with the engine's decode slots (the
    state tensors' batch axis). ``alloc`` is strict — double allocation is
    an engine lifecycle bug, exactly what the property tests churn for —
    and every exit path must ``free``. ``note_deferral`` counts admissions
    deferred on state-slot pressure (the hybrid analogue of page-pressure
    deferrals)."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._in_use = np.zeros((num_slots,), bool)
        self.peak_in_use = 0
        self.slot_deferrals = 0
        self.allocs = 0
        self.frees = 0

    def alloc(self, slot: int):
        assert not self._in_use[slot], f"state slot {slot} double-allocated"
        self._in_use[slot] = True
        self.allocs += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use_count())

    def free(self, slot: int):
        assert self._in_use[slot], f"state slot {slot} double-freed"
        self._in_use[slot] = False
        self.frees += 1

    def note_deferral(self):
        self.slot_deferrals += 1

    def in_use(self, slot: int) -> bool:
        return bool(self._in_use[slot])

    def in_use_count(self) -> int:
        return int(self._in_use.sum())

    def available(self) -> int:
        return self.num_slots - self.in_use_count()

    def slots_in_use(self) -> set[int]:
        return {int(i) for i in np.nonzero(self._in_use)[0]}

    def gauges(self) -> dict:
        return {
            "state_slots_total": self.num_slots,
            "state_slots_in_use": self.in_use_count(),
            "state_slots_peak": self.peak_in_use,
            "state_slot_deferrals": self.slot_deferrals,
        }


def dense_state_keys(sub) -> list[str]:
    """Per-slot dense state keys of one pool sub: everything for recurrent
    subs, the ``ck``/``cv`` sidecars for (paged) cross-attention subs,
    nothing for pure page-arena subs (their per-slot quantization trackers
    are captured separately) or dense attention subs."""
    if not isinstance(sub, dict):
        return []
    if "page_table" in sub or "k" in sub:
        return [k for k in ("ck", "cv") if k in sub]
    return sorted(sub)


def capture_dense_state(pool) -> list[Optional[dict]]:
    """Host (D2H) copies of the fixed-size per-slot state, one entry per
    pool sub (None when the sub has none) — the snapshot-plane counterpart
    of the used-page capture."""
    out = []
    for sub in pool:
        keys = dense_state_keys(sub)
        out.append({k: np.asarray(jax.device_get(sub[k])) for k in keys}
                   if keys else None)
    return out


def restore_dense_state(pool, state: Optional[list]) -> list:
    """Upload a ``capture_dense_state`` payload back into a fresh pool."""
    import jax.numpy as jnp
    if state is None:
        return pool
    new = []
    for sub, st in zip(pool, state):
        if st:
            sub = dict(sub, **{k: jnp.asarray(v) for k, v in st.items()})
        new.append(sub)
    return new
