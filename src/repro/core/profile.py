"""Per-backbone performance profiles (§6 Monitoring & Profiling).

FM-level estimates (memory, load time, service time as a function of batch
size) are computed once per backbone and reused by every task bound to it;
task extensions add only a small per-sub-batch term. The service-time model is
``l(b) = alpha + beta·b`` — a fixed launch overhead plus a per-request slope —
which matches accelerator batching curves up to the throughput knee ``b_max``
(beyond which FMplex stops extending batches; see paper Fig. 1).

Profiles are calibrated from real measurements (``profile_backbone``) on the
real-execution plane, or taken from Table-3-style constants for simulation.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class FMProfile:
    name: str
    alpha: float = 2e-3            # fixed per-batch overhead (s)
    beta: float = 1e-3             # per-request slope (s)
    b_max: int = 16                # throughput knee
    memory_bytes: int = 0          # backbone weights residency
    load_time_s: float = 1.0       # cold-load + warmup
    adapter_alpha: float = 2e-4    # per-sub-batch adapter switch cost (s)
    adapter_beta: float = 1e-4     # per-request adapter compute slope (s)
    task_memory_bytes: int = 0     # typical per-task extension residency
    task_load_s: float = 0.02      # per-task extension load
    # per-deployed-instance runtime overhead (context, workspace, allocator)
    instance_overhead_bytes: int = 300 << 20

    def l(self, b: int) -> float:
        """Backbone service time for a batch of size b."""
        return self.alpha + self.beta * max(b, 0) if b > 0 else 0.0

    def exec_time(self, total: int, adapter_sizes: list[int]) -> float:
        """Backbone pass over the co-batch + sequential adapter sub-batches."""
        t = self.l(total)
        for bs in adapter_sizes:
            t += self.adapter_alpha + self.adapter_beta * bs
        return t

    def effective_per_request(self, b: int) -> float:
        """l_i(b): amortized per-request service time in a size-b co-batch."""
        return self.l(b) / max(b, 1)


def profile_backbone(run_batch, sizes=(1, 2, 4, 8, 16), name="fm",
                     warmup: int = 1) -> FMProfile:
    """Calibrate alpha/beta/b_max by timing ``run_batch(b)`` on real hardware.

    Least-squares fit of l(b) = alpha + beta·b; b_max is the knee where
    marginal throughput gain per doubling drops below 10%.
    """
    xs, ys = [], []
    for b in sizes:
        for _ in range(warmup):
            run_batch(b)
        t0 = time.perf_counter()
        run_batch(b)
        ys.append(time.perf_counter() - t0)
        xs.append(b)
    n = len(xs)
    sx, sy = sum(xs), sum(ys)
    sxx = sum(x * x for x in xs)
    sxy = sum(x * y for x, y in zip(xs, ys))
    beta = (n * sxy - sx * sy) / max(n * sxx - sx * sx, 1e-12)
    alpha = max((sy - beta * sx) / n, 1e-6)
    beta = max(beta, 1e-9)
    # knee: throughput(b) = b / l(b); find where gain per doubling < 10%
    b_max = sizes[-1]
    for lo, hi in zip(sizes, sizes[1:]):
        thr_lo = lo / (alpha + beta * lo)
        thr_hi = hi / (alpha + beta * hi)
        if thr_hi / thr_lo < 1.10:
            b_max = hi
            break
    return FMProfile(name=name, alpha=alpha, beta=beta, b_max=b_max)
