"""Batch executor: runs a BFQ-formed batch against a physical FM (real plane).

Request path (paper Fig. 4 steps 4–7): the scheduler's co-batch executes ONE
shared backbone pass; per-task LoRA deltas are applied grouped by adapter
(compatible sub-batches — rows are adapter-sorted so the segmented-LoRA
kernel sees single-adapter blocks); finally each request's task decoder head
produces the output.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.physical import PhysicalFM
from repro.core.request import Batch
from repro.core.vfm import VFM


class Executor:
    def __init__(self, fm: PhysicalFM):
        self.fm = fm

    def execute(self, batch: Batch, vfms: dict[str, VFM]) -> dict[int, object]:
        """Returns {request id: task output}. Measures wall time on the batch."""
        t0 = time.perf_counter()
        # adapter-sorted layout: concatenate sub-batches (one adapter each)
        order, embeds, aidx = [], [], []
        for adapter_id, reqs in batch.sub_batches:
            ai = self.fm.adapters.index(adapter_id)
            for r in reqs:
                order.append(r)
                x = r.payload
                if x is None:
                    x = np.zeros((self.fm.input_len, self.fm.cfg.d_model),
                                 np.float32)
                embeds.append(x)
                aidx.append(ai)
        feats = self.fm.run_batch(np.stack(embeds), np.asarray(aidx, np.int32))
        out = {}
        for i, r in enumerate(order):
            head = self.fm.heads.get(r.task_id)
            y = head(feats[i]) if head is not None else feats[i]
            out[r.rid] = y
        self.last_exec_s = time.perf_counter() - t0
        return out
