"""Batch executor: runs BFQ-formed batches against a physical FM (real plane).

The executor owns both halves of the serve data path (paper Fig. 4 steps 4-7,
segmented-LoRA formulation), split by workload:

**Pooled-feature path** (``execute`` — one shared forward per batch):

  1. adapter sort   — the scheduler's co-batch arrives as adapter-compatible
     sub-batches (``Batch.sub_batches``); the executor concatenates them so
     rows sharing an adapter are contiguous, and maps each row's adapter id
     to its slot in the FM's ``AdapterStore`` (sentinel == store capacity
     means "base model, no adapter").
  2. block metadata — ``PhysicalFM.run_batch_device`` flattens the sorted
     batch token-major and builds the SGMV metadata ONCE per batch
     composition on the host (memoized in ``PhysicalFM.seg_meta_cache``).
  3. SGMV backbone  — one shared backbone pass; q/v LoRA deltas dispatch
     through ``kernels.ops.segmented_lora`` (Pallas on TPU, jnp oracle on
     CPU) — no per-request (B, d, r) weight materialization.
  4. task heads     — pooled features STAY ON DEVICE; each task's decoder
     head runs batched under one jit per task signature over its feature
     sub-array. Heads that do not trace (impure / numpy-bound) fall back to
     host-side batched or per-row application — verdicts are probed once and
     cached per (task, head) pair.

**Double-buffered dispatch** (``execute_async``): the pooled path splits into
host prep + device dispatch (returns immediately) and a deferred ``resolve``
(head application + host sync). The event loop (``core.serve_loop``) dispatches
tick N+1 — whose ``np.stack`` co-batch assembly runs on the host while the
device still executes tick N — BEFORE resolving tick N, so host prep and
device compute overlap. ``execute`` keeps the synchronous contract
(``execute_async(...).resolve()``).

Generative requests (``Request.max_new_tokens > 0``) are served by the event
loop directly: admission prefill into the FM's persistent ``DecodeEngine``
slot pool, then chunked decode interleaved with pooled batches (see
``core.serve_loop.ServeLoop``).

Batch shapes are bucketed (batch size AND adapter slot count), so steady-state
serving reuses compiled executables — zero recompiles as tasks come and go
within slot capacity.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import zlib

import jax
import numpy as np

from repro.core.physical import PhysicalFM
from repro.core.request import Batch
from repro.core.vfm import VFM


@dataclasses.dataclass
class HeadFailure:
    """Sentinel result for rows whose task head raised past the executor's
    bounded retries. Per-task failure isolation: one misbehaving head fails
    ONLY its own task's requests — the shared backbone pass and every other
    task's head in the same co-batch resolve normally. The serve loop maps
    these to ``status == "head_failed"``."""
    task_id: str
    error: str


class PendingBatch:
    """An in-flight pooled batch: host prep + device dispatch have happened,
    head application and the host sync are deferred to ``resolve()``. Holding
    one of these while assembling the next co-batch is what overlaps tick
    N+1's host prep with tick N's device step (double buffering)."""

    def __init__(self, executor: "Executor", batch: Batch, order, feats_dev):
        self._executor = executor
        self.batch = batch
        self._order = order
        self._feats_dev = feats_dev
        self._out = None

    def resolve(self) -> dict[int, object]:
        """Block on the device step, apply per-task heads, return
        {request id: task output}. Idempotent."""
        if self._out is None:
            self._out = self._executor._finish(self._order, self._feats_dev)
        return self._out


class Executor:
    def __init__(self, fm: PhysicalFM, *, head_retries: int = 2,
                 head_backoff_s: float = 0.005, retry_jitter: float = 0.5,
                 retry_seed: int = 0):
        self.fm = fm
        # task_id -> (head object, mode); the head is stored so a rebound task
        # with a NEW head re-probes (id()-keyed caching would let a recycled
        # id inherit a stale verdict on this persistent object). mode is
        # "device" (jitted on-device), "batched" (host, vectorized) or "row".
        self._head_mode: dict[str, tuple[object, str]] = {}
        self._head_jit: dict[str, object] = {}      # task_id -> jitted head
        # per-task head fault isolation (HeadFailure): bounded retries with
        # exponential backoff before the task's rows fail terminally
        self.head_retries = max(0, int(head_retries))
        self.head_backoff_s = float(head_backoff_s)
        self.head_failures = collections.Counter()  # task_id -> give-ups
        self.retries = 0                            # head re-attempts (all)
        # bounded seeded retry jitter: a purely deterministic exponential
        # backoff retries co-failing tasks in LOCKSTEP (every victim of one
        # transient fault hammers the recovering dependency at the same
        # instants); each task's delays are scaled by a per-task seeded
        # factor in [1-jitter, 1+jitter] so retry schedules desynchronize
        # while staying reproducible and bounded
        self.retry_jitter = min(max(float(retry_jitter), 0.0), 0.95)
        self.retry_seed = int(retry_seed)
        self._retry_rng: dict[str, np.random.RandomState] = {}
        self.retry_delays: dict[str, list[float]] = collections.defaultdict(
            list)                                   # task_id -> slept delays

    @staticmethod
    def _bucketed_rows(feats_dev, idxs: list[int]):
        """Gather a task's feature rows padded to the batch bucket (row 0
        repeated): the head jit then sees one shape per bucket instead of
        one per exact sub-batch size — the event loop produces arbitrary
        sizes every tick, and an unbucketed head retrace costs more than the
        batch it serves."""
        import jax.numpy as jnp

        from repro.core.physical import bucket_for
        pad = bucket_for(len(idxs)) - len(idxs)
        rows = np.asarray(idxs + [idxs[0]] * pad)
        return feats_dev[jnp.asarray(rows)]

    def _run_device_head(self, tid: str, feats_dev, idxs: list[int]):
        y = self._head_jit[tid](self._bucketed_rows(feats_dev, idxs))
        return list(np.asarray(y)[:len(idxs)])

    def _apply_head(self, tid: str, head, feats_dev, feats_fn,
                    idxs: list[int]):
        """Apply one task's head over its feature sub-array — jitted on device
        when the head traces, host-batched when it vectorizes, per-row
        otherwise. ``feats_fn`` materializes the host copy of the features
        lazily, so steady-state batches whose heads all run on device never
        pull the feature array to the host. The verdict is probed on the
        head's first multi-row batch: its batched output must match per-row
        application on the first and last rows (a shape check alone is not
        enough — a head that reduces over its input, e.g. mean-centering,
        returns the right shape with cross-row-contaminated values). The
        probe costs two extra row calls; heads are assumed pure over
        features. n_t == 1 always goes per-row (the conventions are
        indistinguishable there)."""
        if len(idxs) <= 1:
            return [head(feats_fn()[i]) for i in idxs]
        cached = self._head_mode.get(tid)
        if cached is not None and cached[0] is head:
            mode = cached[1]
            if mode == "device":
                return self._run_device_head(tid, feats_dev, idxs)
            if mode == "batched":
                return list(head(feats_fn()[idxs]))
            return [head(feats_fn()[i]) for i in idxs]
        feats = feats_fn()                          # probing needs host rows
        if not np.ptp(feats[idxs], axis=0).any():
            # identical probe rows can't discriminate batched from reducing
            # heads (e.g. all-default zero payloads) — apply per-row and defer
            # the verdict to a batch with distinct rows
            return [head(feats[i]) for i in idxs]
        row0 = head(feats[idxs[0]])
        rowN = head(feats[idxs[-1]])          # catches row-position-dependent

        def matches(y):
            return (getattr(y, "shape", (None,))[0] == len(idxs)
                    and np.asarray(y[0]).shape == np.asarray(row0).shape
                    and np.allclose(np.asarray(y[0]), np.asarray(row0),
                                    atol=1e-5)
                    and np.asarray(y[-1]).shape == np.asarray(rowN).shape
                    and np.allclose(np.asarray(y[-1]), np.asarray(rowN),
                                    atol=1e-5))

        # device first: one jitted executable per (task, head, bucket)
        try:
            fn = jax.jit(head)
            y = np.asarray(fn(self._bucketed_rows(feats_dev, idxs)))[:len(idxs)]
            if matches(y):
                self._head_jit[tid] = fn
                self._head_mode[tid] = (head, "device")
                return list(y)
        except Exception:
            pass
        try:
            y = head(feats[idxs])
            ok = matches(y)
        except Exception:
            y, ok = None, False
        self._head_mode[tid] = (head, "batched" if ok else "row")
        if ok:
            return list(y)                    # reuse the probed batched output
        return [head(feats[i]) for i in idxs]

    def _retry_factor(self, tid: str) -> float:
        """Per-task jitter multiplier in [1 - retry_jitter, 1 + retry_jitter),
        drawn from a stream seeded by (task id, retry_seed) — stable across
        processes (crc32, not the salted builtin hash) so retry schedules
        are reproducible yet distinct per task."""
        if self.retry_jitter <= 0.0:
            return 1.0
        rng = self._retry_rng.get(tid)
        if rng is None:
            seed = (zlib.crc32(tid.encode()) ^ self.retry_seed) & 0xFFFFFFFF
            rng = self._retry_rng[tid] = np.random.RandomState(seed)
        return 1.0 + self.retry_jitter * (2.0 * rng.random_sample() - 1.0)

    def _apply_head_isolated(self, tid: str, head, feats_dev, feats_fn,
                             idxs: list[int]):
        """Failure-isolation wrapper around ``_apply_head``: a raising head
        is retried ``head_retries`` times with exponential backoff (transient
        faults — an OOM'd jit, a flaky host hook — usually clear), and a head
        that keeps raising fails ONLY this task's rows with ``HeadFailure``
        sentinels. The cached probe verdict and jit are dropped on every
        failure so a head that recovers later re-probes from scratch instead
        of replaying a stale mode. Backoff delays carry bounded per-task
        seeded jitter (``retry_jitter``) so tasks co-failing on one shared
        transient fault do not retry in lockstep; delays are recorded in
        ``retry_delays`` per task."""
        delay = self.head_backoff_s
        err: Exception = RuntimeError("head failed")
        for attempt in range(self.head_retries + 1):
            try:
                return self._apply_head(tid, head, feats_dev, feats_fn, idxs)
            except Exception as e:      # noqa: BLE001 — isolation boundary
                err = e
                self._head_mode.pop(tid, None)
                self._head_jit.pop(tid, None)
                if attempt < self.head_retries:
                    self.retries += 1
                    d = delay * self._retry_factor(tid)
                    self.retry_delays[tid].append(d)
                    time.sleep(d)
                    delay *= 2
        self.head_failures[tid] += 1
        fail = HeadFailure(task_id=tid,
                           error=f"{type(err).__name__}: {err}")
        return [fail] * len(idxs)

    def execute_async(self, batch: Batch, vfms: dict[str, VFM]) -> PendingBatch:
        """Host prep + device dispatch, NO host sync: returns a
        ``PendingBatch`` whose ``resolve()`` applies heads and syncs. JAX
        dispatch is asynchronous, so the device works through the backbone
        pass while the caller assembles the next batch."""
        # adapter-sorted layout: concatenate sub-batches (one adapter each)
        order, embeds, aidx = [], [], []
        for adapter_id, reqs in batch.sub_batches:
            ai = self.fm.adapters.index(adapter_id)
            for r in reqs:
                order.append(r)
                x = r.payload
                if x is None:
                    x = np.zeros((self.fm.input_len, self.fm.cfg.d_model),
                                 np.float32)
                embeds.append(x)
                aidx.append(ai)
        feats_dev = self.fm.run_batch_device(np.stack(embeds),
                                             np.asarray(aidx, np.int32))
        return PendingBatch(self, batch, order, feats_dev)

    def execute(self, batch: Batch, vfms: dict[str, VFM]) -> dict[int, object]:
        """Synchronous contract: dispatch + resolve in one call.
        ``last_exec_s`` covers this whole call; it is only stamped here —
        for async batches the dispatch→resolve span includes whatever
        interleaved work ran in between, which is not an executor cost."""
        t0 = time.perf_counter()
        out = self.execute_async(batch, vfms).resolve()
        self.last_exec_s = time.perf_counter() - t0
        return out

    def _finish(self, order, feats_dev) -> dict[int, object]:
        """Deferred half of ``execute_async``: per-task heads over the device
        features + host sync."""
        # host copy, materialized lazily: only headless requests, probes, and
        # fallback-mode heads need it — all-device-head batches never pull
        feats_np: list = [None]

        def feats_fn():
            if feats_np[0] is None:
                feats_np[0] = np.asarray(feats_dev)
            return feats_np[0]

        # per-task batched head application over feature sub-arrays
        by_task: dict[str, list[int]] = {}
        for i, r in enumerate(order):
            by_task.setdefault(r.task_id, []).append(i)
        out = {}
        for tid, idxs in by_task.items():
            head = self.fm.heads.get(tid)
            ys = [feats_fn()[i] for i in idxs] if head is None \
                else self._apply_head_isolated(tid, head, feats_dev,
                                               feats_fn, idxs)
            for i, y in zip(idxs, ys):
                out[order[i].rid] = y
        # evict verdicts of detached tasks (persistent executor: don't retain
        # dead head closures for the life of the server)
        self._head_mode = {t: v for t, v in self._head_mode.items()
                           if t in self.fm.heads}
        self._head_jit = {t: v for t, v in self._head_jit.items()
                          if t in self.fm.heads}
        return out
