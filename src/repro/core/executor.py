"""Batch executor: runs BFQ-formed batches against a physical FM (real plane).

The executor owns both halves of the serve data path (paper Fig. 4 steps 4-7,
segmented-LoRA formulation), split by workload:

**Pooled-feature path** (``execute`` — one shared forward per batch):

  1. adapter sort   — the scheduler's co-batch arrives as adapter-compatible
     sub-batches (``Batch.sub_batches``); the executor concatenates them so
     rows sharing an adapter are contiguous, and maps each row's adapter id
     to its slot in the FM's ``AdapterStore`` (sentinel == store capacity
     means "base model, no adapter").
  2. block metadata — ``PhysicalFM.run_batch_device`` flattens the sorted
     batch token-major and builds the SGMV metadata ONCE per batch
     composition on the host (memoized in ``PhysicalFM.seg_meta_cache``).
  3. SGMV backbone  — one shared backbone pass; q/v LoRA deltas dispatch
     through ``kernels.ops.segmented_lora`` (Pallas on TPU, jnp oracle on
     CPU) — no per-request (B, d, r) weight materialization.
  4. task heads     — pooled features STAY ON DEVICE; each task's decoder
     head runs batched under one jit per task signature over its feature
     sub-array. Heads that do not trace (impure / numpy-bound) fall back to
     host-side batched or per-row application — verdicts are probed once and
     cached per (task, head) pair.

**Prefill+decode path** (``execute_generate`` — generative requests,
``Request.max_new_tokens > 0``): requests stream through the FM's
``DecodeEngine`` — admission prefill into a persistent int8 KV slot pool,
then chunked segmented-LoRA decode with continuous batching: as slots
retire, queued requests join between chunks, so one call serves a batch
larger than the pool with zero recompiles.

Batch shapes are bucketed (batch size AND adapter slot count), so steady-state
serving reuses compiled executables — zero recompiles as tasks come and go
within slot capacity.
"""
from __future__ import annotations

import collections
import time

import jax
import numpy as np

from repro.core.physical import PhysicalFM
from repro.core.request import Batch
from repro.core.vfm import VFM


class Executor:
    def __init__(self, fm: PhysicalFM):
        self.fm = fm
        # task_id -> (head object, mode); the head is stored so a rebound task
        # with a NEW head re-probes (id()-keyed caching would let a recycled
        # id inherit a stale verdict on this persistent object). mode is
        # "device" (jitted on-device), "batched" (host, vectorized) or "row".
        self._head_mode: dict[str, tuple[object, str]] = {}
        self._head_jit: dict[str, object] = {}      # task_id -> jitted head

    def _run_device_head(self, tid: str, feats_dev, idxs: list[int]):
        import jax.numpy as jnp
        y = self._head_jit[tid](feats_dev[jnp.asarray(np.asarray(idxs))])
        return list(np.asarray(y))

    def _apply_head(self, tid: str, head, feats_dev, feats_fn,
                    idxs: list[int]):
        """Apply one task's head over its feature sub-array — jitted on device
        when the head traces, host-batched when it vectorizes, per-row
        otherwise. ``feats_fn`` materializes the host copy of the features
        lazily, so steady-state batches whose heads all run on device never
        pull the feature array to the host. The verdict is probed on the
        head's first multi-row batch: its batched output must match per-row
        application on the first and last rows (a shape check alone is not
        enough — a head that reduces over its input, e.g. mean-centering,
        returns the right shape with cross-row-contaminated values). The
        probe costs two extra row calls; heads are assumed pure over
        features. n_t == 1 always goes per-row (the conventions are
        indistinguishable there)."""
        if len(idxs) <= 1:
            return [head(feats_fn()[i]) for i in idxs]
        cached = self._head_mode.get(tid)
        if cached is not None and cached[0] is head:
            mode = cached[1]
            if mode == "device":
                return self._run_device_head(tid, feats_dev, idxs)
            if mode == "batched":
                return list(head(feats_fn()[idxs]))
            return [head(feats_fn()[i]) for i in idxs]
        feats = feats_fn()                          # probing needs host rows
        if not np.ptp(feats[idxs], axis=0).any():
            # identical probe rows can't discriminate batched from reducing
            # heads (e.g. all-default zero payloads) — apply per-row and defer
            # the verdict to a batch with distinct rows
            return [head(feats[i]) for i in idxs]
        row0 = head(feats[idxs[0]])
        rowN = head(feats[idxs[-1]])          # catches row-position-dependent

        def matches(y):
            return (getattr(y, "shape", (None,))[0] == len(idxs)
                    and np.asarray(y[0]).shape == np.asarray(row0).shape
                    and np.allclose(np.asarray(y[0]), np.asarray(row0),
                                    atol=1e-5)
                    and np.asarray(y[-1]).shape == np.asarray(rowN).shape
                    and np.allclose(np.asarray(y[-1]), np.asarray(rowN),
                                    atol=1e-5))

        # device first: one jitted executable per (task, head) signature
        try:
            fn = jax.jit(head)
            import jax.numpy as jnp
            y = np.asarray(fn(feats_dev[jnp.asarray(np.asarray(idxs))]))
            if matches(y):
                self._head_jit[tid] = fn
                self._head_mode[tid] = (head, "device")
                return list(y)
        except Exception:
            pass
        try:
            y = head(feats[idxs])
            ok = matches(y)
        except Exception:
            y, ok = None, False
        self._head_mode[tid] = (head, "batched" if ok else "row")
        if ok:
            return list(y)                    # reuse the probed batched output
        return [head(feats[i]) for i in idxs]

    def execute(self, batch: Batch, vfms: dict[str, VFM]) -> dict[int, object]:
        """Returns {request id: task output}. Measures wall time on the batch."""
        t0 = time.perf_counter()
        # adapter-sorted layout: concatenate sub-batches (one adapter each)
        order, embeds, aidx = [], [], []
        for adapter_id, reqs in batch.sub_batches:
            ai = self.fm.adapters.index(adapter_id)
            for r in reqs:
                order.append(r)
                x = r.payload
                if x is None:
                    x = np.zeros((self.fm.input_len, self.fm.cfg.d_model),
                                 np.float32)
                embeds.append(x)
                aidx.append(ai)
        feats_dev = self.fm.run_batch_device(np.stack(embeds),
                                             np.asarray(aidx, np.int32))
        # host copy, materialized lazily: only headless requests, probes, and
        # fallback-mode heads need it — all-device-head batches never pull
        feats_np: list = [None]

        def feats_fn():
            if feats_np[0] is None:
                feats_np[0] = np.asarray(feats_dev)
            return feats_np[0]

        # per-task batched head application over feature sub-arrays
        by_task: dict[str, list[int]] = {}
        for i, r in enumerate(order):
            by_task.setdefault(r.task_id, []).append(i)
        out = {}
        for tid, idxs in by_task.items():
            head = self.fm.heads.get(tid)
            ys = [feats_fn()[i] for i in idxs] if head is None \
                else self._apply_head(tid, head, feats_dev, feats_fn, idxs)
            for i, y in zip(idxs, ys):
                out[order[i].rid] = y
        # evict verdicts of detached tasks (persistent executor: don't retain
        # dead head closures for the life of the server)
        self._head_mode = {t: v for t, v in self._head_mode.items()
                           if t in self.fm.heads}
        self._head_jit = {t: v for t, v in self._head_jit.items()
                          if t in self.fm.heads}
        self.last_exec_s = time.perf_counter() - t0
        return out

    def execute_generate(self, batch: Batch, vfms: dict[str, VFM],
                         engine) -> dict[int, object]:
        """Serve generative requests through the continuous-batching
        ``DecodeEngine``: admit into free slots, advance chunked decode,
        re-admit as slots retire. Returns {request id: generated token ids}.
        Also stamps ``Request.first_token_time`` (TTFT) on each request."""
        t0 = time.perf_counter()
        pending = collections.deque(
            r for _, reqs in batch.sub_batches for r in reqs)
        by_rid = {r.rid: r for r in pending}
        out: dict[int, object] = {}

        def retire(slots):
            now = time.perf_counter()
            for s in slots:
                r = by_rid.get(s.rid)
                if r is not None:
                    r.first_token_time = s.t_first
                    # per-request completion: a short request co-batched with
                    # a long one finishes at ITS retire chunk, not at the end
                    # of the whole drain (keeps TPOT honest; on_complete
                    # preserves an already-stamped finish_time)
                    r.finish_time = now
                out[s.rid] = np.asarray(s.tokens, np.int32)

        while pending or engine.active_count():
            while pending and engine.free_slots():
                r = pending.popleft()
                ext = vfms[r.task_id].extensions
                engine.join(r.task_id, r.payload,
                            adapter_id=ext.adapter_id,
                            max_new_tokens=r.max_new_tokens, rid=r.rid)
            retire(engine.step_chunk())
        self.last_exec_s = time.perf_counter() - t0
        return out
