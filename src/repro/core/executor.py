"""Batch executor: runs a BFQ-formed batch against a physical FM (real plane).

Serve data path (paper Fig. 4 steps 4-7, segmented-LoRA formulation):

  1. adapter sort   — the scheduler's co-batch arrives as adapter-compatible
     sub-batches (``Batch.sub_batches``); the executor concatenates them so
     rows sharing an adapter are contiguous, and maps each row's adapter id
     to its slot in the FM's ``AdapterStore`` (sentinel == store capacity
     means "base model, no adapter").
  2. block metadata — ``PhysicalFM.run_batch`` flattens the sorted batch
     token-major and builds the SGMV metadata ONCE per batch on the host
     (``kernels.segmented_lora.segment_metadata``): a permutation into
     block-padded single-adapter segments, its inverse, and one adapter id
     per ``block_t`` token block.
  3. SGMV backbone  — one shared backbone pass; at every attention sublayer
     the q/v LoRA deltas dispatch through ``kernels.ops.segmented_lora``
     (Pallas on TPU, jnp oracle on CPU), so each (block_t, d) tile multiplies
     against exactly one adapter's (d, r) @ (r, out) — no per-request
     (B, d, r) weight materialization.
  4. task heads     — pooled features are split per task and each task's
     decoder head is applied ONCE over its feature sub-array (batched), not
     per request; heads that are not batch-aware fall back to per-row
     application.

Batch shapes are bucketed (batch size AND adapter slot count), so steady-state
serving reuses compiled executables — zero recompiles as tasks come and go
within slot capacity.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.physical import PhysicalFM
from repro.core.request import Batch
from repro.core.vfm import VFM


class Executor:
    def __init__(self, fm: PhysicalFM):
        self.fm = fm
        # task_id -> (head object, batch-aware verdict); the head is stored so
        # a rebound task with a NEW head re-probes (id()-keyed caching would
        # let a recycled id inherit a stale verdict on this persistent object)
        self._batch_aware: dict[str, tuple[object, bool]] = {}

    def _apply_head(self, tid: str, head, feats: np.ndarray, idxs: list[int]):
        """Apply one task's head over its feature sub-array — batched when the
        head vectorizes over rows, per-row otherwise. The verdict is probed on
        the head's first multi-row batch: its batched output must match
        per-row application on the first row (a shape check alone is not
        enough — a head that reduces over its input, e.g. mean-centering,
        returns the right shape with cross-row-contaminated values). The probe
        costs one extra row-0 call; heads are assumed pure over features.
        n_t == 1 always goes per-row (the conventions are indistinguishable
        there)."""
        if len(idxs) <= 1:
            return [head(feats[i]) for i in idxs]
        cached = self._batch_aware.get(tid)
        if cached is not None and cached[0] is head:
            if cached[1]:
                return list(head(feats[idxs]))
            return [head(feats[i]) for i in idxs]
        if not np.ptp(feats[idxs], axis=0).any():
            # identical probe rows can't discriminate batched from reducing
            # heads (e.g. all-default zero payloads) — apply per-row and defer
            # the verdict to a batch with distinct rows
            return [head(feats[i]) for i in idxs]
        try:
            y = head(feats[idxs])
            row0 = head(feats[idxs[0]])
            rowN = head(feats[idxs[-1]])      # catches row-position-dependent
            ok = (getattr(y, "shape", (None,))[0] == len(idxs)
                  and np.asarray(y[0]).shape == np.asarray(row0).shape
                  and np.allclose(np.asarray(y[0]), np.asarray(row0))
                  and np.asarray(y[-1]).shape == np.asarray(rowN).shape
                  and np.allclose(np.asarray(y[-1]), np.asarray(rowN)))
        except Exception:
            y, ok = None, False
        self._batch_aware[tid] = (head, ok)
        if ok:
            return list(y)                    # reuse the probed batched output
        return [head(feats[i]) for i in idxs]

    def execute(self, batch: Batch, vfms: dict[str, VFM]) -> dict[int, object]:
        """Returns {request id: task output}. Measures wall time on the batch."""
        t0 = time.perf_counter()
        # adapter-sorted layout: concatenate sub-batches (one adapter each)
        order, embeds, aidx = [], [], []
        for adapter_id, reqs in batch.sub_batches:
            ai = self.fm.adapters.index(adapter_id)
            for r in reqs:
                order.append(r)
                x = r.payload
                if x is None:
                    x = np.zeros((self.fm.input_len, self.fm.cfg.d_model),
                                 np.float32)
                embeds.append(x)
                aidx.append(ai)
        feats = self.fm.run_batch(np.stack(embeds), np.asarray(aidx, np.int32))
        # per-task batched head application over feature sub-arrays
        by_task: dict[str, list[int]] = {}
        for i, r in enumerate(order):
            by_task.setdefault(r.task_id, []).append(i)
        out = {}
        for tid, idxs in by_task.items():
            head = self.fm.heads.get(tid)
            ys = [feats[i] for i in idxs] if head is None \
                else self._apply_head(tid, head, feats, idxs)
            for i, y in zip(idxs, ys):
                out[order[i].rid] = y
        # evict verdicts of detached tasks (persistent executor: don't retain
        # dead head closures for the life of the server)
        self._batch_aware = {t: v for t, v in self._batch_aware.items()
                             if t in self.fm.heads}
        self.last_exec_s = time.perf_counter() - t0
        return out
