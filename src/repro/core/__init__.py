from repro.core.bfq import BFQ, FIFOBatch, SCHEDULERS, STFQ
from repro.core.profile import FMProfile, profile_backbone
from repro.core.request import SLO, Batch, Request
from repro.core.server import FMplexServer
from repro.core.vfm import VFM, TaskExtensions
