"""Unified event-loop serving plane: one clock, three kinds of work.

Before this subsystem the server ran two disjoint planes: pooled batches
dispatched synchronously while generative batches drained the decode engine
to completion — a long decode stream starved pooled tasks for its whole
lifetime, and BFQ's virtual time never saw per-token work. The event loop
owns ONE clock: each ``tick`` the scheduler picks the next *unit of work* by
virtual tag —

  * a **pooled sub-batch** (tag = smallest queued pooled start tag), executed
    through the double-buffered ``Executor.execute_async`` path: the co-batch
    for tick N+1 is assembled on the host and dispatched while the device is
    still executing tick N, whose heads/host-sync resolve afterwards;
  * a **prefill admission** (tag = smallest queued generative start tag,
    available while the decode pool can take it): arrivals join the
    ``DecodeEngine`` mid-flight between chunks, charged their TRUE prompt
    length in tokens. Admission is **memory-aware** on a paged pool: the
    loop peeks the would-be-admitted request and only dispatches the prefill
    when the engine's free-page count covers its prompt bucket plus a chunk
    of decode headroom (``DecodeEngine.can_admit``), DEFERRING — the request
    stays queued at its tag, the loop serves other work — otherwise;
  * a **decode chunk** (tag = the most-behind active stream's virtual time):
    every occupied slot advances up to ``chunk`` scan steps; each
    participating task is charged the tokens its streams actually COMMITTED
    (engine charge log — under speculative decoding a high-accept stream
    commits several tokens per step, a zero-accept one exactly one, and
    their tasks pay accordingly; on plain engines this degenerates to the
    old ``chunk × active slots``).

Charges advance task virtual time through ``SchedulerBase.charge_tokens``
(BFQ: ``l(1)·tokens/weight``, the same per-token price arrival tags use), so
weighted max-min sharing holds across both planes at token granularity: a
pooled batch interleaves between decode chunks exactly when its tag falls
below the decode stream's, and vice versa.

``run`` replays an arrival trace against the wall clock; ``step_batch``
preserves the old synchronous one-BFQ-batch contract (``FMplexServer.step``)
on top of the same machinery.

**Failure semantics.** Performance isolation (BFQ, the page gate) is only
half of virtualization's promise — the loop also owns FAILURE isolation, and
every request leaves it with a terminal ``Request.status`` (``core.request``
for the full catalogue). The exit paths and what each one unwinds:

  * ``deadline_shed`` (queued): each tick sheds queued generative requests
    whose deadline is already infeasible — predicted TTFT is the page gate's
    token cost model, ``l(1) ×`` admitted prompt length — BEFORE they cost a
    prefill. The scheduler REFUNDS the arrival tags (``on_cancel`` re-chains
    the task's queue), so shed work never distorts fair shares. Deferred
    admissions that expire inside the engine's pending queue surface here
    too (never charged: admission prompt charges are taken from the
    engine's ``admitted_log`` at ACTUAL admission, not at dispatch).
  * ``deadline_cancelled`` / ``quarantined`` (mid-flight): stamped by the
    engine (deadline sweep / in-graph finite-logits flag) and retired
    through the normal retire path — partial tokens preserved, pages and
    prefix references released, the chunks already charged stand (they were
    real device work).
  * ``cancelled``: client ``cancel(request_id)`` unwinds the request
    wherever it lives — queued (tag refund), deferred/preempted (popped,
    never charged), or live (retired via ``leave``: pages, COW references
    and registry entries released).
  * ``head_failed``: the executor isolates a raising task head to that
    task's requests (bounded retry/backoff first); other tasks in the same
    co-batch resolve normally.
  * ``watchdog_shed`` / ``rejected_stranded``: a loop-level watchdog
    watches for wedged engines (work queued, no progress for
    ``watchdog_stall_s``); on a trip it degrades gracefully — terminally
    rejects stranded deferred joins and sheds the lowest-weight task's
    oldest queued request — and the loop NEVER crashes on an engine wedge
    (the engine's wedge error is caught and converted into terminal
    rejections).

**Durable serving state.** The loop also owns the recovery sequence that
makes every path above stateful rather than best-effort:
``snapshot_state`` quiesces (flushes the double-buffered pooled batch) and
captures the engine snapshot + the scheduler's virtual-time tags + the
in-flight request map; ``restore_state`` rebuilds the engine from it
(every restored page sha256-verified — see ``DecodeEngine.restore``) and
re-applies the tags so fair shares resume where they left off; and
``checkpoint_restart`` chains quiesce → snapshot → teardown → restore →
resume, counting ``resets_survived`` on the loop AND on every in-flight
request. A mid-trace device reset therefore loses zero requests: live
streams resume bit-exactly from their restored pages, pending/preempted
entries keep their queue positions, and a request whose restored page
fails digest verification re-prefills losslessly from its host-side
tokens instead of decoding against poisoned KV.

Non-ok terminations count ``acct.dropped`` (never ``completed``) and feed
``ServeLoop.failures`` — ``serving.metrics.failure_counters`` reports them.
"""
from __future__ import annotations

import collections
import time
from typing import Optional

import numpy as np

from repro.core.bfq import group_sub_batches
from repro.core.request import Batch, Request


def is_generative(r: Request) -> bool:
    return r.max_new_tokens > 0


def is_pooled(r: Request) -> bool:
    return r.max_new_tokens <= 0


class ServeLoop:
    """Event-loop serving plane bound to one (server, physical FM) pair."""

    def __init__(self, server, fm_id: str, *, engine_kwargs: Optional[dict] = None,
                 idle_sleep: float = 2e-4,
                 watchdog_stall_s: Optional[float] = 10.0):
        self.srv = server
        self.fm_id = fm_id
        self.engine_kwargs = engine_kwargs or {}
        self.idle_sleep = idle_sleep
        self._pending = None                    # double-buffered pooled batch
        self._inflight: dict[int, Request] = {}  # rid -> loop-admitted request
        self.served: list[Request] = []
        self.ticks = collections.Counter()      # work-kind -> tick count
        self._tie_last = "decode"               # alternation state (see tick)
        self.page_samples: list[float] = []     # paged-pool occupancy / tick
        self.shared_samples: list[float] = []   # dedup fraction / decode tick
        # TTFT split by admission kind (chunked shared-prefix prefill):
        # rids whose admission mapped a prefix (tail < full prompt) land in
        # the hit series at retire, everything else in the miss series
        self._prefix_hit_rids: set[int] = set()
        self.ttft_hit_samples: list[float] = []
        self.ttft_miss_samples: list[float] = []
        # failure-isolation plane (module docstring): terminal-status tallies
        # plus the stall watchdog. The watchdog only arms while work is
        # queued and fires when no progress event (serve / engine step /
        # admission) lands for watchdog_stall_s — None disables it.
        self.failures = collections.Counter()   # terminal status -> count
        self.watchdog_stall_s = watchdog_stall_s
        # deadline enforcement switch: warmup() turns it off around its run
        # (compiles take arbitrarily long; shedding a warmup request would
        # leave its executable cold for the measured run)
        self.enforce_deadlines = True
        self._progress_mark = None
        self._last_progress_t = time.perf_counter()

    # ---- plumbing ----
    @property
    def sched(self):
        return self.srv.schedulers[self.fm_id]

    def _vfms(self):
        return self.srv.vfms_on(self.fm_id)

    def _executor(self):
        ex = self.srv.executors.get(self.fm_id)
        if ex is None:       # FM deployed profile-only, then attached later
            from repro.core.executor import Executor
            ex = self.srv.executors[self.fm_id] = Executor(
                self.srv.fms[self.fm_id])
        return ex

    def _engine(self, create: bool = False):
        eng = self.srv.engines.get(self.fm_id)
        if eng is None and create:
            eng = self.srv.decode_engine(self.fm_id, **self.engine_kwargs)
        return eng

    def submit(self, req: Request, now: Optional[float] = None):
        self.srv.on_arrival(req, time.perf_counter() if now is None else now)

    # ---- the clock ----
    def tick(self, now: Optional[float] = None) -> str:
        """One scheduling decision: dispatch the smallest-tag unit of work.
        Returns the kind dispatched ('pooled' | 'admit' | 'decode' | 'idle')."""
        now = time.perf_counter() if now is None else now
        sched, vfms = self.sched, self._vfms()
        eng = self._engine()
        self._shed_infeasible(sched, vfms, eng, now)
        if self.watchdog_stall_s is not None:
            # the watchdog watches ENGINE progress specifically: pooled
            # completions must not mask a wedged decode pool (a stuck pooled
            # execute blocks inside the tick and cannot be watchdogged
            # anyway). Armed only while the engine holds work; a trip means
            # streams/pending sat still for watchdog_stall_s.
            has_eng_work = eng is not None and \
                (eng.active_count() or eng.pending_count())
            sig = (eng.steps, eng.admissions) if has_eng_work else None
            if sig is None or sig != self._progress_mark:
                self._progress_mark = sig
                self._last_progress_t = now
            elif now - self._last_progress_t > self.watchdog_stall_s:
                self._watchdog_trip(sched, vfms, eng, now)
                self._last_progress_t = now
        candidates = []
        pooled_tag = sched.peek_tag(vfms, is_pooled)
        if pooled_tag != float("inf"):
            candidates.append((pooled_tag, 0, "pooled"))
        gen_tag = sched.peek_tag(vfms, is_generative)
        if gen_tag != float("inf"):
            admit_ok = eng is None
            if not admit_ok:
                # memory-aware admission: peek the request this admission
                # would serve and ask the engine whether a free slot AND (on
                # a paged pool) enough free pages for its prompt bucket plus
                # a chunk of decode headroom exist — otherwise DEFER: the
                # request keeps its tag and the loop serves other work until
                # retiring streams free pages. The PROMPT rides along so the
                # gate can discount pages a shared prefix would map rather
                # than allocate (a sharer needs only its private tail)
                head = sched.peek_request(vfms, is_generative)
                if head is not None and head.payload is not None:
                    v = vfms.get(head.task_id)
                    aid = v.extensions.adapter_id if v is not None else None
                    prompt = np.asarray(head.payload, np.int32).reshape(-1)
                    admit_ok = eng.can_admit(len(prompt), prompt=prompt,
                                             adapter_id=aid)
                else:
                    admit_ok = eng.can_admit(1)
            if admit_ok:
                # ties: admit before pooled/decode — filling slots lets the
                # next decode chunk amortize over more streams
                candidates.append((gen_tag, -1, "admit"))
        if eng is not None and (eng.active_count() or eng.pending_count()):
            tids = [s.task_id for s in eng.slots if s is not None] \
                + eng.pending_task_ids()
            decode_tag = min(sched.task_vtime(t) for t in tids)
            if not sched.token_accounting:
                # no token clock (STFQ/FIFO): the decode tag is meaningless
                # against real queue tags — force a tie with the best queued
                # tag so admission (tie priority -1) refills free slots
                # mid-flight and the pooled/decode alternation below shares
                # the device between the planes
                queued_tag = min(pooled_tag, gen_tag)
                if queued_tag != float("inf"):
                    decode_tag = queued_tag
            candidates.append((decode_tag, 1, "decode"))
        if not candidates:
            self._flush()
            self.ticks["idle"] += 1
            return "idle"
        best = min(candidates)
        kind = best[2]
        # exact pooled/decode tag ties alternate: without a token clock the
        # planes are forced into a tie above, and a fixed preference would
        # starve one of them under sustained load on the other; under BFQ
        # exact ties are transient and alternation is still fair
        if kind in ("pooled", "decode"):
            other = "decode" if kind == "pooled" else "pooled"
            tie = next((c for c in candidates
                        if c[2] == other and c[0] == best[0]), None)
            if tie is not None and self._tie_last == kind:
                kind = other
            self._tie_last = kind
        if kind == "pooled":
            self._tick_pooled(sched, vfms, now)
        elif kind == "admit":
            self._tick_admit(sched, vfms, now)
        else:
            self._tick_decode(sched, vfms, now)
        self.ticks[kind] += 1
        return kind

    def _tick_pooled(self, sched, vfms, now):
        batch = sched.next_batch(vfms, now, pred=is_pooled)
        if batch is None:
            return
        # dispatch N+1 BEFORE resolving N: the np.stack co-batch assembly in
        # execute_async runs on the host while the device still executes the
        # pending batch (double-buffered host prep)
        new = self._executor().execute_async(batch, vfms)
        self._flush()
        self._pending = new

    def _flush(self):
        """Resolve the in-flight pooled batch: heads + host sync + completion
        bookkeeping (Eq. 3 retro-correction via ``on_complete``)."""
        if self._pending is None:
            return
        out = self._pending.resolve()
        batch = self._pending.batch
        self._pending = None
        # head_failed stamping BEFORE on_complete so its accounting sees the
        # terminal status (failed rows count dropped, not completed)
        self._stamp_head_failures(batch, out)
        self.srv.on_complete(self.fm_id, batch, time.perf_counter())
        for r in batch.requests:
            r.result = out[r.rid]
        self.served += batch.requests

    def _stamp_head_failures(self, batch, out):
        """Map the executor's per-task HeadFailure sentinels (isolated head
        crash past its bounded retries) to terminal request statuses."""
        from repro.core.executor import HeadFailure
        for r in batch.requests:
            res = out.get(r.rid)
            if isinstance(res, HeadFailure):
                r.status = "head_failed"
                r.error = res.error
                out[r.rid] = None
                self.failures["head_failed"] += 1

    def _admit_one(self, eng, vfms, r: Request):
        """Join one generative request into the pool (immediate or deferred —
        the engine's ``admitted_log`` records the charge at ACTUAL
        admission)."""
        ext = vfms[r.task_id].extensions
        prompt = np.asarray(r.payload).reshape(-1)
        eng.join(r.task_id, prompt, adapter_id=ext.adapter_id,
                 max_new_tokens=r.max_new_tokens, rid=r.rid,
                 deadline=r.deadline() if self.enforce_deadlines else None,
                 # enc-dec: encoder input frames ride the request; None is
                 # the engine's zero-frame default (decoder-only unaffected)
                 enc_feats=getattr(r, "enc_feats", None))

    def _charge_admissions(self, sched, vfms, now):
        """Drain the engine's admitted log and charge each loop-admitted
        request the prompt tokens its prefill ACTUALLY computed — the TAIL
        tokens, which a chunked shared-prefix admission keeps below the
        full (post-truncation) prompt length. Charging full prompt length
        would bill a sharer for compute the prefix registry saved it,
        inflating its task's virtual time and handing its fair share to
        competitors. Charging at ACTUAL admission — not at dispatch into
        the engine — means a deferred join that gets shed/cancelled while
        still pending never carried a charge to refund (the BFQ-charge bug
        this replaces: deferred joins were priced at dispatch, so a drop in
        the pending queue left the task's virtual time inflated by a
        prefill that never ran)."""
        eng = self._engine()
        if eng is None:
            return
        charges: dict[str, float] = collections.Counter()
        for rid, tid, toks, tail in eng.take_admitted():
            # step_batch-owned requests were dispatched at FULL arrival
            # price (see _drain_gen) — only loop-admitted rids pay here
            if rid in self._inflight:
                charges[tid] += tail
                if tail < toks:
                    self._prefix_hit_rids.add(rid)
        if charges:
            sched.charge_tokens(vfms, charges, now)

    def _tick_admit(self, sched, vfms, now):
        # the double buffer only spans pooled→pooled ticks: an engine tick
        # syncs the device anyway, so resolve the pending pooled batch first
        # (its requests must not outlive work dispatched after them)
        self._flush()
        eng = self._engine(create=True)
        # paged pools admit ONE request per tick: tick()'s can_admit gate
        # only vetted the head request, so popping more would shove the rest
        # past the page check into the engine's rid-FIFO pending queue —
        # charged early and served out of tag order. The loop re-ticks and
        # admission keeps its tie priority, so a burst still lands back to
        # back, each admission individually vetted.
        free = 1 if eng.paged else len(eng.free_slots())
        # defer_charge: dispatch advances the stream's virtual time only to
        # its start tag; the ACTUAL work is charged at admission via the
        # engine's admitted log and per decode chunk (double-pricing would
        # halve the gen share)
        batch = sched.next_batch(vfms, now, pred=is_generative, limit=free,
                                 defer_charge=True)
        if batch is None:
            return
        for r in batch.requests:
            self._inflight[r.rid] = r       # before join: admitted-log drain
            self._admit_one(eng, vfms, r)   # below must see the rid as ours
        self._charge_admissions(sched, vfms, now)

    def _tick_decode(self, sched, vfms, now):
        self._flush()                 # see _tick_admit: pooled results first
        eng = self._engine()
        # expire deadlines BEFORE counting active slots so an expired stream
        # is not charged for a chunk it no longer decodes (the engine sweeps
        # again inside step_chunk; the sweep is idempotent)
        eng._expire_deadlines(now)
        # decode chunks charge the tokens each task's streams actually
        # COMMITTED (engine's per-task charge log): under speculation a
        # high-accept stream commits several tokens per scan step while a
        # zero-accept co-batched stream commits one — a flat
        # chunk × active_slots split would bill both the same. Engines
        # without the log (stubs) fall back to exactly that flat split.
        active = collections.Counter(
            s.task_id for s in eng.slots if s is not None and not s.done)
        steps0 = eng.steps
        try:
            retired = eng.step_chunk()
        except ValueError:
            # wedged engine (stranded deferred joins, nothing live, nothing
            # can ever fit): the engine raises for direct users, the LOOP
            # degrades — terminally reject the stranded entries and keep
            # serving everything else
            self.failures["wedge_recoveries"] += 1
            eng.shed_stranded()
            self._handle_rejected(eng, vfms, time.perf_counter())
            return
        if eng.paged:
            self.page_samples.append(eng.page_occupancy())
            self.shared_samples.append(
                eng.dedup_saved_pages() / max(eng.logical_page_count(), 1))
        # charge the work the chunk ACTUALLY did (0 when a stalled/faulted
        # engine made no progress — phantom charges would corrupt fair
        # shares for the rest of the run)
        committed = eng.take_decode_charges() \
            if hasattr(eng, "take_decode_charges") else None
        if committed:
            agg: dict[str, float] = collections.Counter()
            for (tid, _rid), n in committed.items():
                agg[tid] += n
            sched.charge_tokens(vfms, agg, now)
        elif committed is None:
            advanced = eng.steps - steps0
            if advanced:
                sched.charge_tokens(
                    vfms, {t: n * advanced for t, n in active.items()}, now)
        # pending joins admitted inside step_chunk (and any terminally
        # rejected along the way) surface through the engine's logs
        self._charge_admissions(sched, vfms, now)
        done_t = time.perf_counter()
        self._handle_rejected(eng, vfms, done_t)
        for s in retired:
            self._retire(s, vfms, done_t)

    def _retire(self, slot, vfms, now):
        """Stamp a loop-admitted stream's request at ITS retire chunk (keeps
        TTFT/TPOT honest for short streams co-batched with long ones)."""
        r = self._inflight.pop(slot.rid, None)
        hit = slot.rid in self._prefix_hit_rids
        self._prefix_hit_rids.discard(slot.rid)
        if r is None:
            return                    # admitted by step_batch; handled there
        r.first_token_time = slot.t_first
        r.finish_time = now
        if r.arrival is not None and slot.t_first is not None:
            (self.ttft_hit_samples if hit else self.ttft_miss_samples
             ).append(slot.t_first - r.arrival)
        r.result = np.asarray(slot.tokens, np.int32)
        v = vfms.get(r.task_id)
        if v is not None:
            if slot.status == "ok":
                v.acct.completed += 1
            else:
                v.acct.dropped += 1
            # token-level service accounting: l(1) per token of device work,
            # prompt (admission prefill) included — mirrors what
            # charge_tokens billed to the task's virtual time. Billed even
            # for quarantined/expired streams: the device did the work.
            v.acct.service_time += self.sched.profile.l(1) * \
                (slot.prompt_tokens + len(slot.tokens))
        if slot.status != "ok":
            r.status = slot.status
            r.error = f"stream {slot.status}"
            self.failures[slot.status] += 1
        self.served.append(r)

    # ---- failure plane (module docstring, failure-semantics section) ----
    def _terminal(self, r: Request, status: str, now, *, tokens=None,
                  t_first=None, vfms=None):
        """Stamp a terminal failure status on a request and account it."""
        r.status = status
        r.error = r.error or status
        r.finish_time = now
        if t_first is not None:
            r.first_token_time = t_first
        r.result = None if tokens is None else np.asarray(tokens, np.int32)
        self.failures[status] += 1
        v = (vfms if vfms is not None else self._vfms()).get(r.task_id)
        if v is not None:
            v.acct.dropped += 1
        self._inflight.pop(r.rid, None)
        self.served.append(r)

    def _handle_rejected(self, eng, vfms, now, *, mine=None, out=None):
        """Drain the engine's terminally rejected pending entries (deadline
        sweep, stranded shed, wedge recovery) into terminal request statuses.
        ``mine``/``out`` route ``step_batch``-owned rids back to its result
        map (its while-loop must see a result for every request or it never
        terminates)."""
        for p in eng.take_rejected():
            toks = p.resume.tokens if p.resume is not None else None
            t_first = p.resume.t_first if p.resume is not None else None
            r = mine.get(p.rid) if mine is not None else None
            if r is not None:
                r.status = p.status
                r.error = f"admission {p.status}"
                r.finish_time = now
                if t_first is not None:
                    r.first_token_time = t_first
                out[p.rid] = None if toks is None else \
                    np.asarray(toks, np.int32)
                self.failures[p.status] += 1
                # no acct here: step_batch's on_complete sees the terminal
                # status and counts dropped for the whole batch
                continue
            r = self._inflight.get(p.rid)
            if r is not None:
                self._terminal(r, p.status, now, tokens=toks,
                               t_first=t_first, vfms=vfms)

    def _shed_infeasible(self, sched, vfms, eng, now):
        """Shed queued generative requests whose deadline is already
        infeasible BEFORE they cost a prefill: predicted TTFT is the page
        gate's token cost model — ``l(1)`` per admitted prompt token. The
        scheduler refunds the arrival tags (``on_cancel`` re-chains the
        queue), so shed work never distorts the task's fair share."""
        if not self.enforce_deadlines:
            return
        l1 = sched.profile.l(1)
        cap = eng.prompt_len if eng is not None else None
        for v in vfms.values():
            for r in [q for q in v.queue if is_generative(q)]:
                dl = r.deadline()
                if dl == float("inf"):
                    continue
                plen = len(np.asarray(r.payload).reshape(-1)) \
                    if r.payload is not None else max(r.tokens, 1.0)
                if cap is not None:
                    plen = min(plen, cap)
                if now + l1 * plen > dl and sched.on_cancel(vfms, r):
                    self._terminal(r, "deadline_shed", now, vfms=vfms)

    def _watchdog_trip(self, sched, vfms, eng, now):
        """No progress for watchdog_stall_s with work queued: degrade
        gracefully. Stranded deferred joins are terminally rejected (they
        are the one way the engine can wedge) and the lowest-weight task's
        oldest queued request is shed — never crash, never hang."""
        self.failures["watchdog_trips"] += 1
        if eng is not None and eng.pending_count():
            eng.shed_stranded()
            self._handle_rejected(eng, vfms, now)
        loaded = [v for v in vfms.values() if v.queue]
        if loaded:
            v = min(loaded, key=lambda x: x.weight)
            r = v.queue[0]
            if sched.on_cancel(vfms, r):
                self._terminal(r, "watchdog_shed", now, vfms=vfms)

    def cancel(self, request_id: int, now: Optional[float] = None) -> bool:
        """Client-initiated cancellation: unwind one request wherever it
        lives. Queued → scheduler tag refund (no device work happened);
        deferred/preempted in the engine's pending queue → popped, never
        charged (admission charges land at actual admission); live slot →
        retired through ``leave`` (pages, COW references and prefix-registry
        entries released), partial tokens preserved, chunk charges already
        billed stand (real device work). Returns True iff the request was
        found live anywhere."""
        now = time.perf_counter() if now is None else now
        sched, vfms = self.sched, self._vfms()
        for v in vfms.values():
            for r in list(v.queue):
                if r.rid == request_id:
                    if sched.on_cancel(vfms, r):
                        self._terminal(r, "cancelled", now, vfms=vfms)
                        return True
        eng = self._engine()
        if eng is None:
            return False
        res = eng.cancel(request_id)
        if res is None:
            return False
        kind, obj = res
        r = self._inflight.get(request_id)
        if r is None:
            return True               # engine-direct stream, not loop-owned
        if kind == "slot":
            self._terminal(r, "cancelled", now, tokens=obj.tokens,
                           t_first=obj.t_first, vfms=vfms)
        else:
            toks = obj.resume.tokens if obj.resume is not None else None
            t_first = obj.resume.t_first if obj.resume is not None else None
            self._terminal(r, "cancelled", now, tokens=toks,
                           t_first=t_first, vfms=vfms)
        return True

    # ---- durable serving state (snapshot / restore / device reset) ----
    def snapshot_state(self) -> dict:
        """Quiesce (resolve the double-buffered pooled batch) and capture
        everything a restore needs: the engine snapshot (page contents,
        tables, refcounts, registry, slot/PRNG/deadline state, pending
        queue), the scheduler's virtual-time tags, and the in-flight
        request map. Host-side objects (requests, spill arena) ride by
        reference — they are exactly the state a device reset cannot
        touch."""
        self._flush()
        eng = self._engine()
        tags = self.sched.snapshot_tags() \
            if hasattr(self.sched, "snapshot_tags") else None
        return {"engine": None if eng is None else eng.snapshot(),
                "sched": tags, "inflight": dict(self._inflight)}

    def restore_state(self, state: dict, *, reuse_jits_from=None):
        """Rebuild the engine from a snapshot (digest-verified; see
        ``DecodeEngine.restore``), swap it into the server, and re-apply
        the scheduler's virtual-time tags so fair shares resume where they
        left off. In-flight requests keep their identities — the retire
        path finds them by rid exactly as before the reset."""
        from repro.core.decode_engine import DecodeEngine
        self._flush()
        snap = state.get("engine")
        if snap is not None:
            eng = DecodeEngine.restore(self.srv.fms[self.fm_id], snap,
                                       reuse_jits_from=reuse_jits_from)
            self.srv.engines[self.fm_id] = eng
        if state.get("sched") is not None \
                and hasattr(self.sched, "restore_tags"):
            self.sched.restore_tags(state["sched"])
        self._inflight.update(state.get("inflight", {}))
        # restored streams must re-arm the watchdog from NOW, not from the
        # pre-reset progress mark
        self._progress_mark = None
        self._last_progress_t = time.perf_counter()

    def checkpoint_restart(self) -> dict:
        """The full recovery sequence: quiesce -> snapshot -> teardown (the
        old engine is dropped from the server; its jit caches are reused —
        executables are code, not device state) -> restore -> resume.
        Returns the snapshot used. ``DeviceResetFault`` drives this with a
        scrambled arena in between to prove restore reads nothing from the
        dead device state."""
        state = self.snapshot_state()
        old = self.srv.engines.pop(self.fm_id, None)
        self.restore_state(state, reuse_jits_from=old)
        self.failures["resets_survived"] += 1
        for r in self._inflight.values():
            r.resets_survived += 1
        return state

    # ---- drivers ----
    def warmup(self, *, pooled_task: Optional[str] = None,
               gen_task: Optional[str] = None, pooled_n: int = 4):
        """Compile every executable the loop can dispatch before measuring:
        one pooled co-batch per batch bucket up to ``pooled_n`` (BFQ can
        form ANY size under load, so every bucket the run could hit must be
        warm — a size-2 sub-batch mid-measurement used to cost a compile),
        one admission prefill per prompt-length bucket, the decode chunk,
        and the pool write. Shared by the benchmarks and examples so the
        warm set can't drift from the jit-key set. Generative warmup is
        skipped only for FMs with no generative head (no vocab head, or a
        pure-representation stack); enc-dec stacks warm through the
        engine's zero-frame ``enc_feats`` default."""
        import numpy as np

        from repro.core.physical import BUCKETS
        fm = self.srv.fms[self.fm_id]
        cfg = fm.cfg
        vfms = self._vfms()
        if not vfms:
            return
        tids = sorted(vfms)
        pooled_task = pooled_task or tids[0]
        gen_task = gen_task or tids[-1]
        rng = np.random.RandomState(0)

        def payload():
            # DISTINCT rows: the executor's head probe defers its verdict on
            # identical rows, which would leave the head jits cold
            return rng.randn(fm.input_len, cfg.d_model).astype(np.float32)

        ex = self._executor()
        for b in (x for x in BUCKETS if x <= max(pooled_n, 1)):
            reqs = [Request(pooled_task, 0.0, payload=payload())
                    for _ in range(b)]
            ex.execute(Batch(reqs, group_sub_batches(reqs, vfms)), vfms)
        trace = [Request(pooled_task, 0.0, payload=payload())
                 for _ in range(pooled_n)]
        if cfg.vocab_size > 0 and not cfg.is_representation:
            # enc-dec included: the engine's zero-frame enc_feats default
            # makes warmup joins well-formed for every generative stack
            eng = self._engine(create=True)
            for plen in eng.prompt_buckets:
                trace.append(Request(
                    gen_task, 0.0,
                    payload=rng.randint(0, cfg.vocab_size,
                                        plen).astype("int32"),
                    tokens=float(plen + 2), max_new_tokens=2))
        # warmup requests inherit task-level SLOs at enqueue, and compiles
        # take arbitrarily long: enforcement would shed the very requests
        # meant to warm the executables
        enforce = self.enforce_deadlines
        self.enforce_deadlines = False
        try:
            self.run(trace)
        finally:
            self.enforce_deadlines = enforce
        # the deadline clamp dispatches shortened chunks from a fixed
        # ladder, and the spill tier gathers/scatters pages with fixed-width
        # jits; compile both now so deadline traffic, spills and restores
        # never recompile in steady state
        eng = self._engine()
        if eng is not None and eng.active_count() == 0:
            if getattr(eng, "deadline_clamp", False):
                eng.warm_decode_ladder()
            # the speculative plane flips between the spec and plain fns
            # adaptively — warm BOTH ladders so accept-rate swings never
            # recompile mid-measurement
            if getattr(eng, "spec_k", 0) > 0:
                if not getattr(eng, "deadline_clamp", False):
                    eng.warm_decode_ladder()
                eng.warm_speculative()
            if getattr(eng, "spill", None) is not None:
                eng.warm_spill()
            # chunked shared-prefix admissions compile per TAIL bucket —
            # warm them so the first sharer join never eats a compile
            if getattr(eng, "chunked_prefill", False):
                eng.warm_chunked()

    def _work_left(self) -> bool:
        eng = self._engine()
        return (self._pending is not None or bool(self._inflight)
                or (eng is not None and (eng.active_count() > 0
                                         or eng.pending_count() > 0))
                or any(v.queue for v in self._vfms().values()))

    def run(self, trace, *, drain: bool = True,
            max_wall: Optional[float] = None, on_tick=None) -> list[Request]:
        """Replay a trace (``Request.arrival`` = offset seconds from start)
        against the wall clock: requests are submitted when their arrival
        time passes (rebased to ``perf_counter`` so latency stats line up)
        and the loop ticks between arrivals. ``on_tick(loop, rel)`` runs
        before every tick — the chaos-injection harness's hook
        (``serving.faults``). Returns the requests served by THIS call
        (``self.served`` accumulates across calls)."""
        trace = sorted(trace, key=lambda r: r.arrival)
        t0 = time.perf_counter()
        n0 = len(self.served)
        i = 0
        while True:
            now = time.perf_counter()
            if max_wall is not None and now - t0 > max_wall:
                break
            rel = now - t0
            if on_tick is not None:
                on_tick(self, rel)
            while i < len(trace) and trace[i].arrival <= rel:
                r = trace[i]
                r.arrival = t0 + r.arrival          # rebase to wall clock
                self.submit(r, now)
                i += 1
            kind = self.tick(now)
            if kind == "idle":
                if i >= len(trace):
                    if not drain or not self._work_left():
                        break
                else:
                    wait = t0 + trace[i].arrival - time.perf_counter()
                    time.sleep(max(0.0, min(self.idle_sleep, wait)))
        self._flush()
        return self.served[n0:]

    # ---- legacy synchronous contract (FMplexServer.step) ----
    def step_batch(self) -> Optional[Batch]:
        """Dispatch + execute ONE mixed BFQ batch synchronously and return it
        (or None). Pooled members run the double-buffered path; generative
        members stream through the decode engine (mid-flight admission into
        free slots, chunked decode, token-level charging) until all of THIS
        batch's streams retire. Loop-admitted streams sharing the pool retire
        normally along the way."""
        # a still-pending pooled batch from a prior tick() must resolve
        # before this path serves anything newer (its requests are already
        # off the queues and executed — leaving them unstamped while step()
        # keeps returning batches would wedge callers polling finish_time)
        self._flush()
        now = time.perf_counter()
        batch = self.srv.next_batch(self.fm_id, now)
        if batch is None:
            return None
        sched, vfms = self.sched, self._vfms()
        pooled = [r for r in batch.requests if is_pooled(r)]
        gen = [r for r in batch.requests if is_generative(r)]
        results: dict[int, object] = {}
        pend = None
        if pooled:
            pb = Batch(pooled, group_sub_batches(pooled, vfms))
            pend = self._executor().execute_async(pb, vfms)
        if gen:
            results.update(self._drain_gen(gen, sched, vfms))
        if pend is not None:
            results.update(pend.resolve())
        self._stamp_head_failures(batch, results)
        self.srv.on_complete(self.fm_id, batch, time.perf_counter())
        for r in batch.requests:
            r.result = results[r.rid]
        return batch

    def _drain_gen(self, reqs, sched, vfms) -> dict[int, object]:
        """Serve this batch's generative requests to completion (the old
        drain-synchronous contract). No token charges here: this path's
        requests were dispatched at their FULL arrival price and are
        retro-corrected by ``on_complete`` in ``step_batch`` — charging
        chunks on top would double-price them."""
        eng = self._engine(create=True)
        pending = collections.deque(reqs)
        mine = {r.rid: r for r in reqs}
        out: dict[int, object] = {}

        def mine_active():
            # paged pools may DEFER a join into the engine's pending queue;
            # those streams are still ours and must be drained to completion
            return any(s is not None and s.rid in mine for s in eng.slots) \
                or any(r in mine for r in eng.pending_rids())

        while pending or mine_active():
            now = time.perf_counter()
            while pending and eng.free_slots():
                self._admit_one(eng, vfms, pending.popleft())
            # loop-admitted streams sharing the pool WERE dispatched at
            # deferred charge — their chunks still bill token-level, at
            # the tokens each stream actually COMMITTED (the rid-keyed
            # charge log filters OUR full-arrival-priced streams out)
            loop_active = collections.Counter(
                s.task_id for s in eng.slots
                if s is not None and not s.done and s.rid in self._inflight)
            retired = eng.step_chunk()
            committed = eng.take_decode_charges() \
                if hasattr(eng, "take_decode_charges") else None
            if committed is not None:
                agg: dict[str, float] = collections.Counter()
                for (tid, rid), n in committed.items():
                    if rid in self._inflight:
                        agg[tid] += n
                if agg:
                    sched.charge_tokens(vfms, agg, now)
            elif loop_active:
                sched.charge_tokens(
                    vfms, {t: n * eng.chunk for t, n in loop_active.items()},
                    now)
            # loop-admitted deferred joins that got in during this chunk
            # still bill their prompt at admission; OURS are skipped inside
            # (full arrival price, see the docstring above)
            self._charge_admissions(sched, vfms, now)
            done_t = time.perf_counter()
            # terminal rejections (deadline sweep inside step_chunk) of OUR
            # requests must land in `out` or the while-loop never ends
            self._handle_rejected(eng, vfms, done_t, mine=mine, out=out)
            for s in retired:
                r = mine.get(s.rid)
                if r is None:         # a loop-admitted stream retired too
                    self._retire(s, vfms, done_t)
                    continue
                r.first_token_time = s.t_first
                # per-request completion: a short request co-batched with a
                # long one finishes at ITS retire chunk (on_complete keeps an
                # already-stamped finish_time)
                r.finish_time = done_t
                if s.status != "ok":
                    r.status = s.status
                    r.error = f"stream {s.status}"
                    self.failures[s.status] += 1
                out[s.rid] = np.asarray(s.tokens, np.int32)
        return out
