"""Physical FM: a loaded backbone + adapter/head stores + bucketed jit cache.

The real-execution plane (CPU-scale configs). A PhysicalFM owns:
  * backbone params (pure pytree) for one ``ModelConfig``;
  * an adapter store — LoRA A/B stacks keyed by adapter id, padded to a
    common rank AND to a slot bucket (4/8/16/...) so adding a task within
    capacity reuses the compiled executable instead of recompiling;
  * a decoder-head store — per-task heads applied after the shared pass;
  * a cache of jitted executables keyed on (batch bucket, adapter slot
    bucket) so TPU-style static shapes never recompile in steady state.

``run_batch`` picks the LoRA serve path per co-batch (``lora_impl="auto"``,
the server default): the measured ``AUTO_LORA_TABLE`` crossover chooses
between the segmented (SGMV) path — the adapter-sorted co-batch is flattened
token-major, permuted into block-padded segments (metadata built ONCE per
batch on the host via ``kernels.segmented_lora.segment_metadata``), and the
q/v deltas dispatch through the Pallas kernel (ref oracle on CPU) — and the
per-request gather-einsum path, which wins where block padding fragments
(e.g. large batches spread over many adapters). Explicit
``lora_impl="gather"``/``"segmented"`` overrides pin one path
(train / dry-run / parity testing / benchmarks).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.profile import FMProfile, profile_backbone
from repro.kernels.segmented_lora import SegmentMetaCache, padded_tokens
from repro.models import lm

BUCKETS = (1, 2, 4, 8, 16, 32)
SLOT_BUCKETS = (4, 8, 16, 32, 64)

# lora_impl="auto" crossover table, measured per (batch bucket, adapter
# count) cell from BENCH_serving.json#pooled (CPU backend): the per-cell
# winner between the gather-einsum path and the segmented SGMV kernel.
# Neither dominates: segmented amortizes when many tokens share an adapter
# (e.g. batch 32 / 1 adapter: 8.6ms vs 18.2ms gather) but its block padding
# loses when a large co-batch fragments across adapters (batch 32 / 4
# adapters: 16.4ms vs 9.8ms gather). Re-measure and update when the kernel
# or the backend changes; explicit lora_impl= overrides skip the table.
NA_BUCKETS = (1, 2, 4, 8, 16)
AUTO_LORA_TABLE = {
    (1, 1): "segmented", (1, 2): "gather", (1, 4): "segmented",
    (1, 8): "gather", (1, 16): "gather",
    (2, 1): "gather", (2, 2): "segmented", (2, 4): "gather",
    (2, 8): "segmented", (2, 16): "segmented",
    (4, 1): "segmented", (4, 2): "segmented", (4, 4): "segmented",
    (4, 8): "segmented", (4, 16): "segmented",
    (8, 1): "gather", (8, 2): "gather", (8, 4): "segmented",
    (8, 8): "gather", (8, 16): "segmented",
    (16, 1): "segmented", (16, 2): "gather", (16, 4): "gather",
    (16, 8): "segmented", (16, 16): "segmented",
    (32, 1): "segmented", (32, 2): "gather", (32, 4): "gather",
    (32, 8): "gather", (32, 16): "gather",
}
# adapter-id sentinel for rows that are padding / free decode slots; beyond
# any real slot index AND any slot bucket, so both LoRA paths zero it out.
# Shared with DecodeEngine so pad rows and free slots segment identically.
PAD_SENTINEL = 10**6


def bucket_for(n: int) -> int:
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


def slot_bucket_for(n: int) -> int:
    for b in SLOT_BUCKETS:
        if n <= b:
            return b
    return SLOT_BUCKETS[-1]


class AdapterStore:
    """Backbone LoRA adapters of one physical FM, stacked for co-batching.

    Each entry is a full per-layer LoRA pytree (``models.lora`` layout, NA=1);
    ``stacked()`` maintains one NA=capacity() stack consumed by
    ``lm.forward(lora=..., adapter_idx=...)``. The stack is padded with
    zero-weight adapters up to the slot bucket, so (a) its shape — and hence
    the jitted executable — is stable while tasks come and go within
    capacity, and (b) the "no adapter" sentinel can never alias a real
    adapter slot: ``index()`` returns ``capacity()``, which both execution
    paths treat as "zero delta", and any stale in-between index lands on a
    zero-B pad slot whose delta is exactly zero anyway.

    The stack is cached incrementally: adding an adapter writes it into the
    next free pad slot of the existing stack (no re-concatenation); only
    removal or a capacity change invalidates the cache.
    """

    def __init__(self, cfg, rank: int = 16):
        from repro.models import lora as lora_mod
        self.cfg = cfg
        self.rank = rank
        self._mod = lora_mod
        self.ids: list[str] = []
        self._trees: list = []
        self._stacked = None
        self._stacked_n = 0        # how many real adapters the cache holds
        self._stacked_cap = 0      # slot capacity the cache was built for

    def __len__(self):
        return len(self.ids)

    def capacity(self) -> int:
        """Current slot-bucket capacity of the stacked representation."""
        return slot_bucket_for(max(1, len(self.ids)))

    def add(self, adapter_id: str, tree):
        if len(self.ids) >= SLOT_BUCKETS[-1]:
            # beyond the top bucket the capacity() sentinel would alias a
            # real slot and incremental writes would clamp out of bounds
            raise ValueError(
                f"adapter slots exhausted ({SLOT_BUCKETS[-1]}) on this FM; "
                "deploy another physical FM instance for more tasks")
        self.ids.append(adapter_id)
        self._trees.append(tree)
        if self._stacked is not None and self._stacked_cap != self.capacity():
            self._stacked = None   # crossed a slot bucket: full rebuild

    def new(self, adapter_id: str, seed: int = 0):
        tree = self._mod.init_single_adapter(
            jax.random.PRNGKey(seed), self.cfg, self.rank)
        self.add(adapter_id, tree)
        return tree

    def remove(self, adapter_id: str):
        """Idempotent: the server frees adapters on unbind, so callers that
        also remove explicitly (tests, manual lifecycle) must not fail."""
        if adapter_id not in self.ids:
            return
        i = self.ids.index(adapter_id)
        del self.ids[i], self._trees[i]
        self._stacked = None       # slots shift: precise full invalidation

    def index(self, adapter_id: Optional[str]) -> int:
        """Sentinel == capacity() (the stack's NA) means 'no adapter'."""
        if adapter_id in self.ids:
            return self.ids.index(adapter_id)
        return self.capacity()

    def _zero_tree(self):
        template = self._trees[0] if self._trees else \
            self._mod.init_single_adapter(jax.random.PRNGKey(0), self.cfg,
                                          self.rank)
        return jax.tree.map(jnp.zeros_like, template)

    def stacked(self):
        cap = self.capacity()
        n = len(self.ids)
        if self._stacked is not None and self._stacked_cap == cap:
            if self._stacked_n < n:
                # incremental: write the new adapters into their pad slots
                st = self._stacked
                for j in range(self._stacked_n, n):
                    tree = self._trees[j]
                    st = jax.tree.map(
                        lambda s, t: s.at[:, j].set(t[:, 0].astype(s.dtype)),
                        st, tree)
                self._stacked = st
                self._stacked_n = n
            return self._stacked
        zero = self._zero_tree()
        trees = self._trees + [zero] * (cap - n)
        self._stacked = self._mod.stack_adapters(trees) if len(trees) > 1 \
            else trees[0]
        self._stacked_n, self._stacked_cap = n, cap
        return self._stacked


class PhysicalFM:
    """One deployed backbone instance."""

    def __init__(self, cfg: ModelConfig, *, seed: int = 0, lora_rank: int = 16,
                 input_len: int = 32, lora_impl: str = "auto",
                 seg_block_t: int = 16):
        self.cfg = cfg
        self.input_len = input_len
        self.lora_impl = lora_impl
        self.seg_block_t = seg_block_t
        t0 = time.perf_counter()
        self.params = lm.init_model(jax.random.PRNGKey(seed), cfg)
        self.adapters = AdapterStore(cfg, lora_rank)
        self.heads: dict[str, Callable] = {}        # task_id -> head fn
        self._jit_cache: dict[tuple[int, int], Callable] = {}
        self.seg_meta_cache = SegmentMetaCache()    # per-composition host sort
        self.load_time_s = time.perf_counter() - t0
        self.profile: Optional[FMProfile] = None

    # ---- stores ----
    def attach_head(self, task_id: str, head_fn: Callable):
        self.heads[task_id] = head_fn

    def detach_task(self, task_id: str):
        self.heads.pop(task_id, None)

    # ---- execution ----
    def compile_count(self) -> int:
        """Total jitted executables across all bucket keys (steady-state
        serving must not grow this when tasks are added within capacity).
        ``_cache_size`` is a private jax accessor; if a jax release drops it,
        degrade to counting cache keys (one trace per key in steady state)."""
        return sum(f._cache_size() if hasattr(f, "_cache_size") else 1
                   for f in self._jit_cache.values())

    def resolve_lora_impl(self, rows: int, num_adapters: Optional[int] = None
                          ) -> str:
        """The LoRA execution path for a ``rows``-request co-batch.

        ``lora_impl="auto"`` consults ``AUTO_LORA_TABLE`` at (batch bucket,
        adapter-count bucket); explicit "gather"/"segmented" pass through.
        ``num_adapters`` defaults to the store's registered count — callers
        with a bucketed jit key (the decode engine) pass their slot bucket
        instead so the resolution can't flip within a compiled key."""
        if self.lora_impl != "auto":
            return self.lora_impl
        na = len(self.adapters) if num_adapters is None else num_adapters
        nb = next((b for b in NA_BUCKETS if max(1, na) <= b), NA_BUCKETS[-1])
        return AUTO_LORA_TABLE[(bucket_for(rows), nb)]

    def _features_fn(self, bucket: int, slots: int, impl: str):
        """Shared backbone forward with per-request backbone LoRA deltas,
        jitted per (batch bucket, adapter slot bucket, lora impl)."""
        key = (bucket, slots, impl)
        if key not in self._jit_cache:
            cfg, bt = self.cfg, self.seg_block_t

            @jax.jit
            def run(params, embeds, lora_stack, adapter_idx, perm, inv, blocks):
                seg = None
                if impl == "segmented":
                    seg = {"perm": perm, "inv": inv, "block_adapter": blocks,
                           "block_t": bt}
                if cfg.is_encoder_decoder:
                    # audio-style backbone: stub frames go to the encoder; the
                    # decoder runs over a BOS-only token stream
                    toks = jnp.zeros(embeds.shape[:2], jnp.int32)
                    feats, _, _ = lm.forward(params, cfg, tokens=toks,
                                             enc_embeds=embeds, lora=lora_stack,
                                             adapter_idx=adapter_idx,
                                             lora_impl=impl, lora_seg=seg)
                else:
                    feats, _, _ = lm.forward(params, cfg, embeds=embeds,
                                             lora=lora_stack,
                                             adapter_idx=adapter_idx,
                                             lora_impl=impl, lora_seg=seg)
                return feats.mean(axis=1)                      # (B, d) pooled

            self._jit_cache[key] = run
        return self._jit_cache[key]

    def segment_meta(self, adapter_idx: np.ndarray, cap: int, seq_len: int):
        """Per-batch SGMV metadata (host side, built once per co-batch
        *composition* — ``seg_meta_cache`` memoizes repeats, so steady-state
        serving and every step of a decode co-batch skip the host sort).

        Shapes depend only on (batch bucket, slot bucket, seq_len, block_t)
        — all static per jit-cache key — so steady state never recompiles."""
        b = len(adapter_idx)
        bt = self.seg_block_t
        # worst case: every distinct adapter plus the two sentinels ("no
        # adapter" == cap and batch padding) opens a block-padded segment
        max_segs = min(b, cap + 2)
        tp = padded_tokens(b * seq_len, max_segs, bt)
        ids = np.repeat(np.asarray(adapter_idx, np.int32), seq_len) \
            if seq_len != 1 else np.asarray(adapter_idx, np.int32)
        return self.seg_meta_cache.get(ids, cap, bt, tp)

    def run_batch_device(self, embeds, adapter_idx: np.ndarray):
        """Device-resident serve forward: like ``run_batch`` but returns the
        pooled features as a jax array (no host pull) so per-task heads can
        run on-device (see ``Executor``)."""
        n = embeds.shape[0]
        if n > BUCKETS[-1]:            # oversize co-batch: serve in chunks
            c = BUCKETS[-1]
            return jnp.concatenate(
                [self.run_batch_device(embeds[i:i + c], adapter_idx[i:i + c])
                 for i in range(0, n, c)])
        b = bucket_for(n)
        pad = b - n
        if pad:
            embeds = np.concatenate([embeds, np.zeros((pad,) + embeds.shape[1:],
                                                      embeds.dtype)])
            adapter_idx = np.concatenate(
                [adapter_idx, np.full((pad,), PAD_SENTINEL, np.int32)])
        stack = self.adapters.stacked()
        cap = self.adapters.capacity()
        impl = self.resolve_lora_impl(b)
        if impl == "segmented":
            perm, inv, blocks = self.segment_meta(
                np.asarray(adapter_idx), cap, embeds.shape[1])
        else:   # gather path never reads the metadata; pass static dummies
            perm = inv = blocks = np.zeros((1,), np.int32)
        out = self._features_fn(b, cap, impl)(
            self.params, jnp.asarray(embeds), stack,
            jnp.asarray(adapter_idx, jnp.int32), jnp.asarray(perm),
            jnp.asarray(inv), jnp.asarray(blocks))
        return out[:n]

    def run_batch(self, embeds: np.ndarray, adapter_idx: np.ndarray):
        """embeds: (n, S, d); adapter_idx: (n,). Returns (n, d) features.
        Pads to the next batch bucket (and the adapter stack to its slot
        bucket) so steady-state serving never recompiles."""
        return np.asarray(self.run_batch_device(embeds, adapter_idx))

    def calibrate(self, sizes=(1, 2, 4, 8, 16)) -> FMProfile:
        d = self.cfg.d_model
        rng = np.random.RandomState(0)

        def run(b):
            e = rng.randn(b, self.input_len, d).astype(np.float32)
            self.run_batch(e, np.zeros((b,), np.int32))

        self.profile = profile_backbone(run, sizes=sizes, name=self.cfg.name)
        self.profile.load_time_s = self.load_time_s
        self.profile.memory_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        return self.profile
