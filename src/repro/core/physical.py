"""Physical FM: a loaded backbone + adapter/head stores + bucketed jit cache.

The real-execution plane (CPU-scale configs). A PhysicalFM owns:
  * backbone params (pure pytree) for one ``ModelConfig``;
  * an adapter store — LoRA A/B stacks keyed by adapter id, padded to a
    common rank so they batch into the segmented-LoRA kernel;
  * a decoder-head store — per-task heads applied after the shared pass;
  * a bucket cache of jitted executables (one per batch bucket) so TPU-style
    static shapes never recompile in steady state.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.profile import FMProfile, profile_backbone
from repro.models import lm

BUCKETS = (1, 2, 4, 8, 16, 32)


def bucket_for(n: int) -> int:
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


class AdapterStore:
    """Backbone LoRA adapters of one physical FM, stacked for co-batching.

    Each entry is a full per-layer LoRA pytree (``models.lora`` layout, NA=1);
    ``stacked()`` concatenates them into one NA=n stack consumed by
    ``lm.forward(lora=..., adapter_idx=...)``.
    """

    def __init__(self, cfg, rank: int = 16):
        from repro.models import lora as lora_mod
        self.cfg = cfg
        self.rank = rank
        self._mod = lora_mod
        self.ids: list[str] = []
        self._trees: list = []
        self._stacked = None

    def add(self, adapter_id: str, tree):
        self.ids.append(adapter_id)
        self._trees.append(tree)
        self._stacked = None

    def new(self, adapter_id: str, seed: int = 0):
        tree = self._mod.init_single_adapter(
            jax.random.PRNGKey(seed), self.cfg, self.rank)
        self.add(adapter_id, tree)
        return tree

    def remove(self, adapter_id: str):
        i = self.ids.index(adapter_id)
        del self.ids[i], self._trees[i]
        self._stacked = None

    def index(self, adapter_id: Optional[str]) -> int:
        """Sentinel == len(ids) means 'no adapter' (base model)."""
        return self.ids.index(adapter_id) if adapter_id in self.ids else len(self.ids)

    def stacked(self):
        if self._stacked is None:
            trees = self._trees or [self._mod.init_single_adapter(
                jax.random.PRNGKey(0), self.cfg, self.rank)]
            self._stacked = self._mod.stack_adapters(trees) if len(trees) > 1 \
                else trees[0]
        return self._stacked


class PhysicalFM:
    """One deployed backbone instance."""

    def __init__(self, cfg: ModelConfig, *, seed: int = 0, lora_rank: int = 16,
                 input_len: int = 32):
        self.cfg = cfg
        self.input_len = input_len
        t0 = time.perf_counter()
        self.params = lm.init_model(jax.random.PRNGKey(seed), cfg)
        self.adapters = AdapterStore(cfg, lora_rank)
        self.heads: dict[str, Callable] = {}        # task_id -> head fn
        self._jit_cache: dict[int, Callable] = {}
        self.load_time_s = time.perf_counter() - t0
        self.profile: Optional[FMProfile] = None

    # ---- stores ----
    def attach_head(self, task_id: str, head_fn: Callable):
        self.heads[task_id] = head_fn

    def detach_task(self, task_id: str):
        self.heads.pop(task_id, None)

    # ---- execution ----
    def _features_fn(self, bucket: int):
        """Shared backbone forward with per-request backbone LoRA deltas."""
        if bucket not in self._jit_cache:
            cfg = self.cfg

            @jax.jit
            def run(params, embeds, lora_stack, adapter_idx):
                if cfg.is_encoder_decoder:
                    # audio-style backbone: stub frames go to the encoder; the
                    # decoder runs over a BOS-only token stream
                    toks = jnp.zeros(embeds.shape[:2], jnp.int32)
                    feats, _, _ = lm.forward(params, cfg, tokens=toks,
                                             enc_embeds=embeds, lora=lora_stack,
                                             adapter_idx=adapter_idx)
                else:
                    feats, _, _ = lm.forward(params, cfg, embeds=embeds,
                                             lora=lora_stack,
                                             adapter_idx=adapter_idx)
                return feats.mean(axis=1)                      # (B, d) pooled

            self._jit_cache[bucket] = run
        return self._jit_cache[bucket]

    def run_batch(self, embeds: np.ndarray, adapter_idx: np.ndarray):
        """embeds: (n, S, d); adapter_idx: (n,). Returns (n, d) features.
        Pads to the next bucket so steady-state serving never recompiles."""
        n = embeds.shape[0]
        b = bucket_for(n)
        pad = b - n
        if pad:
            embeds = np.concatenate([embeds, np.zeros((pad,) + embeds.shape[1:],
                                                      embeds.dtype)])
            adapter_idx = np.concatenate(
                [adapter_idx, np.full((pad,), 10**6, np.int32)])
        out = self._features_fn(b)(self.params, jnp.asarray(embeds),
                                   self.adapters.stacked(),
                                   jnp.asarray(adapter_idx, jnp.int32))
        return np.asarray(out)[:n]

    def calibrate(self, sizes=(1, 2, 4, 8, 16)) -> FMProfile:
        d = self.cfg.d_model
        rng = np.random.RandomState(0)

        def run(b):
            e = rng.randn(b, self.input_len, d).astype(np.float32)
            self.run_batch(e, np.zeros((b,), np.int32))

        self.profile = profile_backbone(run, sizes=sizes, name=self.cfg.name)
        self.profile.load_time_s = self.load_time_s
        self.profile.memory_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        return self.profile
