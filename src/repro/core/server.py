"""Per-server FMplex instance (paper §5/§6).

Maintains the local vFM registry, task queues, scheduler state, and bindings
from vFMs to physical FM instances. The same object serves both planes:

  * real plane  — the event-loop serving plane (``core.serve_loop``): one
    clock per FM under which pooled sub-batches, prefill admissions, and
    decode chunks interleave by BFQ virtual tag (``serve_loop(fm_id)``);
    ``step`` keeps the legacy synchronous one-batch contract on top of it;
  * sim plane   — the discrete-event simulator drives ``on_arrival`` /
    ``next_batch`` / ``on_complete`` with virtual time.
"""
from __future__ import annotations

from typing import Optional

from repro.core.bfq import SCHEDULERS, SchedulerBase
from repro.core.decode_engine import DecodeEngine
from repro.core.executor import Executor
from repro.core.physical import PhysicalFM
from repro.core.profile import FMProfile
from repro.core.request import Batch, Request
from repro.core.serve_loop import ServeLoop
from repro.core.vfm import VFM, TaskExtensions


class FMplexServer:
    def __init__(self, server_id: str = "s0"):
        self.server_id = server_id
        self.fms: dict[str, PhysicalFM] = {}          # physical FM instances
        self.executors: dict[str, Executor] = {}      # persistent, one per FM
        self.engines: dict[str, DecodeEngine] = {}    # persistent decode pools
        self.loops: dict[str, ServeLoop] = {}         # event-loop plane per FM
        self.profiles: dict[str, FMProfile] = {}
        self.schedulers: dict[str, SchedulerBase] = {}
        self.vfms: dict[str, VFM] = {}                # task_id -> vFM
        self.bindings: dict[str, str] = {}            # task_id -> fm instance id

    # ---- deployment control (driven by FMplex-Controller) ----
    def deploy_fm(self, fm_id: str, fm: Optional[PhysicalFM] = None,
                  profile: Optional[FMProfile] = None, scheduler: str = "bfq"):
        if fm is not None:
            self.fms[fm_id] = fm
            self.executors[fm_id] = Executor(fm)
            profile = profile or fm.profile or fm.calibrate()
        assert profile is not None
        self.profiles[fm_id] = profile
        self.schedulers[fm_id] = SCHEDULERS[scheduler](profile)

    def undeploy_fm(self, fm_id: str):
        self.fms.pop(fm_id, None)
        self.executors.pop(fm_id, None)
        self.engines.pop(fm_id, None)
        self.loops.pop(fm_id, None)
        self.profiles.pop(fm_id)
        self.schedulers.pop(fm_id)

    def decode_engine(self, fm_id: str, **kwargs) -> DecodeEngine:
        """The FM's persistent continuous-batching decode pool (created on
        first use; ``kwargs`` configure it then — slots, chunk, max_new...).
        Passing kwargs once the pool exists raises: silently ignoring them
        (e.g. a ``max_new`` larger than the allocated pool, which ``join``
        would quietly clamp to) has bitten before."""
        eng = self.engines.get(fm_id)
        if eng is None:
            eng = self.engines[fm_id] = DecodeEngine(self.fms[fm_id], **kwargs)
        elif kwargs:
            raise ValueError(
                f"decode engine for {fm_id!r} already exists; it cannot be "
                f"reconfigured with {sorted(kwargs)} (undeploy_fm first)")
        return eng

    def serve_loop(self, fm_id: str, **kwargs) -> ServeLoop:
        """The FM's persistent event-loop serving plane (created on first
        use; ``kwargs`` configure it then — e.g. ``engine_kwargs`` for the
        decode pool it admits into). Like ``decode_engine``, kwargs against
        an existing loop raise instead of being silently dropped."""
        loop = self.loops.get(fm_id)
        if loop is None:
            loop = self.loops[fm_id] = ServeLoop(self, fm_id, **kwargs)
        elif kwargs:
            raise ValueError(
                f"serve loop for {fm_id!r} already exists; it cannot be "
                f"reconfigured with {sorted(kwargs)} (undeploy_fm first)")
        return loop

    def bind_task(self, task_id: str, fm_id: str, *, weight: float = 1.0,
                  slo=None, extensions: Optional[TaskExtensions] = None) -> VFM:
        vfm = VFM(task_id, weight=weight, slo=slo, extensions=extensions,
                  backbone=fm_id)
        vfm.bound_fm = fm_id
        self.vfms[task_id] = vfm
        self.bindings[task_id] = fm_id
        fm = self.fms.get(fm_id)
        if fm is not None and extensions is not None:
            if extensions.decoder is not None:
                fm.attach_head(task_id, extensions.decoder)
            if extensions.adapter_id is not None and \
                    extensions.adapter_weights is not None and \
                    extensions.adapter_id not in fm.adapters.ids:
                fm.adapters.add(extensions.adapter_id, extensions.adapter_weights)
        return vfm

    def unbind_task(self, task_id: str) -> Optional[dict]:
        """Detach a task, returning its movable snapshot (elastic adaptation).

        Frees the task's adapter slot when the binding owns the adapter (its
        extensions carry the weights — the symmetric case to bind_task adding
        it) and no other task bound to the same FM shares it: the store has
        finite slot capacity, so lifetime task churn must not accumulate dead
        adapters. The snapshot keeps the weights; rebinding re-adds them.
        Adapters registered out-of-band (``fm.adapters.new``) are left alone.
        """
        vfm = self.vfms.pop(task_id, None)
        if vfm is None:
            return None
        fm_id = self.bindings.pop(task_id)
        fm = self.fms.get(fm_id)
        if fm is not None:
            fm.detach_task(task_id)
            ext = vfm.extensions
            aid = ext.adapter_id if ext is not None else None
            if aid is not None and ext.adapter_weights is not None and not any(
                    v.extensions is not None
                    and v.extensions.adapter_id == aid
                    and self.bindings.get(t) == fm_id
                    for t, v in self.vfms.items()):
                fm.adapters.remove(aid)
        return vfm.snapshot()

    def rebind_snapshot(self, snap: dict, fm_id: str) -> VFM:
        vfm = VFM.restore(snap, backbone=fm_id)
        vfm.bound_fm = fm_id
        self.vfms[vfm.task_id] = vfm
        self.bindings[vfm.task_id] = fm_id
        fm = self.fms.get(fm_id)
        ext = vfm.extensions
        if fm is not None and ext is not None:
            if ext.decoder is not None:
                fm.attach_head(vfm.task_id, ext.decoder)
            if ext.adapter_id is not None and ext.adapter_weights is not None \
                    and ext.adapter_id not in fm.adapters.ids:
                fm.adapters.add(ext.adapter_id, ext.adapter_weights)
        return vfm

    # ---- scheduler-facing (both planes) ----
    def vfms_on(self, fm_id: str) -> dict[str, VFM]:
        return {t: v for t, v in self.vfms.items() if self.bindings[t] == fm_id}

    def on_arrival(self, req: Request, now: float):
        vfm = self.vfms[req.task_id]
        self.schedulers[self.bindings[req.task_id]].on_arrival(vfm, req, now)

    def next_batch(self, fm_id: str, now: float) -> Optional[Batch]:
        return self.schedulers[fm_id].next_batch(self.vfms_on(fm_id), now)

    def on_complete(self, fm_id: str, batch: Batch, now: float):
        sched = self.schedulers[fm_id]
        for r in batch.requests:
            if r.finish_time is None:     # decode path stamps per-request
                r.finish_time = now       # completion at its retire chunk
            v = self.vfms.get(r.task_id)
            if v is not None:
                # terminal failures (head_failed, quarantined, ...) count
                # dropped; service is billed either way — the device ran
                if r.ok:
                    v.acct.completed += 1
                else:
                    v.acct.dropped += 1
                v.acct.service_time += \
                    sched.profile.effective_per_request(batch.size)
        sched.on_complete(batch, self.vfms_on(fm_id), now)

    # ---- real-plane serving (event-loop plane) ----
    def step(self, fm_id: str) -> Optional[Batch]:
        """Dispatch + execute one batch synchronously; returns it (or None).

        Legacy contract kept on top of the event-loop plane: one mixed BFQ
        batch — pooled members through the double-buffered executor path,
        generative members through the FM's persistent ``DecodeEngine`` with
        mid-flight admission and token-level fair-share charging. For
        interleaved serving (pooled batches BETWEEN decode chunks), drive
        ``serve_loop(fm_id)`` directly instead."""
        return self.serve_loop(fm_id).step_batch()
