"""Per-server FMplex instance (paper §5/§6).

Maintains the local vFM registry, task queues, scheduler state, and bindings
from vFMs to physical FM instances. The same object serves both planes:

  * real plane  — ``serve_forever``/``step`` execute batches on a PhysicalFM
    via the Executor (tiny configs on CPU);
  * sim plane   — the discrete-event simulator drives ``on_arrival`` /
    ``next_batch`` / ``on_complete`` with virtual time.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.bfq import SCHEDULERS, SchedulerBase, group_sub_batches
from repro.core.decode_engine import DecodeEngine
from repro.core.executor import Executor
from repro.core.physical import PhysicalFM
from repro.core.profile import FMProfile
from repro.core.request import Batch, Request
from repro.core.vfm import VFM, TaskExtensions


class FMplexServer:
    def __init__(self, server_id: str = "s0"):
        self.server_id = server_id
        self.fms: dict[str, PhysicalFM] = {}          # physical FM instances
        self.executors: dict[str, Executor] = {}      # persistent, one per FM
        self.engines: dict[str, DecodeEngine] = {}    # persistent decode pools
        self.profiles: dict[str, FMProfile] = {}
        self.schedulers: dict[str, SchedulerBase] = {}
        self.vfms: dict[str, VFM] = {}                # task_id -> vFM
        self.bindings: dict[str, str] = {}            # task_id -> fm instance id

    # ---- deployment control (driven by FMplex-Controller) ----
    def deploy_fm(self, fm_id: str, fm: Optional[PhysicalFM] = None,
                  profile: Optional[FMProfile] = None, scheduler: str = "bfq"):
        if fm is not None:
            self.fms[fm_id] = fm
            self.executors[fm_id] = Executor(fm)
            profile = profile or fm.profile or fm.calibrate()
        assert profile is not None
        self.profiles[fm_id] = profile
        self.schedulers[fm_id] = SCHEDULERS[scheduler](profile)

    def undeploy_fm(self, fm_id: str):
        self.fms.pop(fm_id, None)
        self.executors.pop(fm_id, None)
        self.engines.pop(fm_id, None)
        self.profiles.pop(fm_id)
        self.schedulers.pop(fm_id)

    def decode_engine(self, fm_id: str, **kwargs) -> DecodeEngine:
        """The FM's persistent continuous-batching decode pool (created on
        first use; ``kwargs`` configure it then — slots, chunk, max_new...)."""
        eng = self.engines.get(fm_id)
        if eng is None:
            eng = self.engines[fm_id] = DecodeEngine(self.fms[fm_id], **kwargs)
        return eng

    def bind_task(self, task_id: str, fm_id: str, *, weight: float = 1.0,
                  slo=None, extensions: Optional[TaskExtensions] = None) -> VFM:
        vfm = VFM(task_id, weight=weight, slo=slo, extensions=extensions,
                  backbone=fm_id)
        vfm.bound_fm = fm_id
        self.vfms[task_id] = vfm
        self.bindings[task_id] = fm_id
        fm = self.fms.get(fm_id)
        if fm is not None and extensions is not None:
            if extensions.decoder is not None:
                fm.attach_head(task_id, extensions.decoder)
            if extensions.adapter_id is not None and \
                    extensions.adapter_weights is not None and \
                    extensions.adapter_id not in fm.adapters.ids:
                fm.adapters.add(extensions.adapter_id, extensions.adapter_weights)
        return vfm

    def unbind_task(self, task_id: str) -> Optional[dict]:
        """Detach a task, returning its movable snapshot (elastic adaptation).

        Frees the task's adapter slot when the binding owns the adapter (its
        extensions carry the weights — the symmetric case to bind_task adding
        it) and no other task bound to the same FM shares it: the store has
        finite slot capacity, so lifetime task churn must not accumulate dead
        adapters. The snapshot keeps the weights; rebinding re-adds them.
        Adapters registered out-of-band (``fm.adapters.new``) are left alone.
        """
        vfm = self.vfms.pop(task_id, None)
        if vfm is None:
            return None
        fm_id = self.bindings.pop(task_id)
        fm = self.fms.get(fm_id)
        if fm is not None:
            fm.detach_task(task_id)
            ext = vfm.extensions
            aid = ext.adapter_id if ext is not None else None
            if aid is not None and ext.adapter_weights is not None and not any(
                    v.extensions is not None
                    and v.extensions.adapter_id == aid
                    and self.bindings.get(t) == fm_id
                    for t, v in self.vfms.items()):
                fm.adapters.remove(aid)
        return vfm.snapshot()

    def rebind_snapshot(self, snap: dict, fm_id: str) -> VFM:
        vfm = VFM.restore(snap, backbone=fm_id)
        vfm.bound_fm = fm_id
        self.vfms[vfm.task_id] = vfm
        self.bindings[vfm.task_id] = fm_id
        fm = self.fms.get(fm_id)
        ext = vfm.extensions
        if fm is not None and ext is not None:
            if ext.decoder is not None:
                fm.attach_head(vfm.task_id, ext.decoder)
            if ext.adapter_id is not None and ext.adapter_weights is not None \
                    and ext.adapter_id not in fm.adapters.ids:
                fm.adapters.add(ext.adapter_id, ext.adapter_weights)
        return vfm

    # ---- scheduler-facing (both planes) ----
    def vfms_on(self, fm_id: str) -> dict[str, VFM]:
        return {t: v for t, v in self.vfms.items() if self.bindings[t] == fm_id}

    def on_arrival(self, req: Request, now: float):
        vfm = self.vfms[req.task_id]
        self.schedulers[self.bindings[req.task_id]].on_arrival(vfm, req, now)

    def next_batch(self, fm_id: str, now: float) -> Optional[Batch]:
        return self.schedulers[fm_id].next_batch(self.vfms_on(fm_id), now)

    def on_complete(self, fm_id: str, batch: Batch, now: float):
        sched = self.schedulers[fm_id]
        for r in batch.requests:
            if r.finish_time is None:     # decode path stamps per-request
                r.finish_time = now       # completion at its retire chunk
            v = self.vfms.get(r.task_id)
            if v is not None:
                v.acct.completed += 1
                v.acct.service_time += \
                    sched.profile.effective_per_request(batch.size)
        sched.on_complete(batch, self.vfms_on(fm_id), now)

    # ---- real-plane serving loop ----
    def step(self, fm_id: str) -> Optional[Batch]:
        """Dispatch + execute one batch synchronously; returns it (or None).

        Pooled-feature requests run the shared forward (``Executor.execute``);
        generative requests (``max_new_tokens > 0``) stream through the FM's
        persistent ``DecodeEngine`` (admission prefill + chunked int8-KV
        decode with continuous batching). One BFQ batch may carry both."""
        now = time.perf_counter()
        batch = self.next_batch(fm_id, now)
        if batch is None:
            return None
        ex = self.executors.get(fm_id)
        if ex is None:       # FM deployed profile-only, then attached later
            ex = self.executors[fm_id] = Executor(self.fms[fm_id])
        gen = [r for r in batch.requests if r.max_new_tokens > 0]
        pooled = [r for r in batch.requests if r.max_new_tokens <= 0]
        results = {}
        if pooled:
            pb = Batch(pooled, group_sub_batches(pooled, self.vfms))
            results.update(ex.execute(pb, self.vfms))
        if gen:
            gb = Batch(gen, group_sub_batches(gen, self.vfms))
            results.update(ex.execute_generate(gb, self.vfms,
                                               self.decode_engine(fm_id)))
        self.on_complete(fm_id, batch, time.perf_counter())
        for r in batch.requests:
            r.result = results[r.rid]
        return batch
