"""Request/SLO/batch data model shared by the scheduler, executor & simulator."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

_ids = itertools.count()

# Terminal request statuses (the failure-semantics layer). "ok" is the only
# success; everything else is a terminal error the serving plane stamped:
#   deadline_shed      - shed BEFORE admission: predicted TTFT (page-gate cost
#                        model) could not meet the deadline, or the deferred
#                        admission expired in the engine's pending queue.
#   deadline_cancelled - cancelled MID-FLIGHT: the stream (live slot or
#                        preempted resume entry) ran past its deadline.
#   cancelled          - client cancel() unwound the request.
#   quarantined        - the stream produced non-finite logits (NaN/Inf
#                        adapter or activations) and was retired to protect
#                        co-batched streams.
#   head_failed        - the task's decoder head raised past the executor's
#                        bounded retries; only this task's requests fail.
#   rejected_stranded  - a deferred join whose shared-prefix discount was
#                        released could never fit again and its deadline
#                        passed (or the loop recovered a wedged engine).
#   watchdog_shed      - the loop watchdog shed queued work of the lowest-
#                        weight task to degrade gracefully under an engine
#                        stall.
# Durability note: surviving a device reset is NOT a status — a request that
# rides through ``ServeLoop.checkpoint_restart`` keeps whatever terminal
# status it ends with (usually "ok", token-for-token identical to a fault-
# free run) and counts the reset in ``resets_survived`` instead.
STATUS_OK = "ok"
FAILURE_STATUSES = ("deadline_shed", "deadline_cancelled", "cancelled",
                    "quarantined", "head_failed", "rejected_stranded",
                    "watchdog_shed")


@dataclasses.dataclass
class SLO:
    deadline_s: Optional[float] = None     # max acceptable latency (None = none)


@dataclasses.dataclass
class Request:
    task_id: str
    arrival: float
    payload: Any = None                    # model input (real plane) or size hint
    tokens: float = 1.0                    # token-based FMs: work units (§4.2)
    # generative serving: > 0 routes the request through the continuous-
    # batching DecodeEngine (payload = prompt token ids); the budget counts
    # the prefill-produced first token
    max_new_tokens: int = 0
    slo: SLO = dataclasses.field(default_factory=SLO)
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    # BFQ tags (assigned at enqueue)
    start_tag: float = 0.0
    finish_tag: float = 0.0
    v_at_arrival: float = 0.0
    # lifecycle timestamps
    dispatch_time: Optional[float] = None
    first_token_time: Optional[float] = None   # decode path: TTFT endpoint
    finish_time: Optional[float] = None
    result: Any = None
    # terminal status: STATUS_OK or one of FAILURE_STATUSES (module header);
    # error carries the human-readable cause for non-ok terminations
    status: str = STATUS_OK
    error: Optional[str] = None
    # engine restores this request lived through while in flight (stamped by
    # ServeLoop.checkpoint_restart; 0 for the overwhelming common case)
    resets_survived: int = 0

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def deadline(self) -> float:
        if self.slo.deadline_s is None:
            return float("inf")
        return self.arrival + self.slo.deadline_s

    def met_deadline(self) -> bool:
        """Finished successfully within its deadline (goodput numerator)."""
        return (self.status == STATUS_OK and self.finish_time is not None
                and self.finish_time <= self.deadline())


@dataclasses.dataclass
class Batch:
    requests: list[Request]
    # requests grouped into adapter-compatible sub-batches (paper Fig. 5c):
    # list of (adapter_id | None, [requests])
    sub_batches: list[tuple[Optional[str], list[Request]]]

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def tasks(self) -> set[str]:
        return {r.task_id for r in self.requests}

    @property
    def num_adapters(self) -> int:
        return sum(1 for a, _ in self.sub_batches if a is not None)
