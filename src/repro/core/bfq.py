"""Batch-aware Fair Queueing (BFQ) — paper §4.2 — plus scheduler baselines.

BFQ extends start-time fair queueing (STFQ) from per-request ordering to batch
formation:

  arrival:    S_i^j = max(F_i^{j-1}, v),  F_i^j = S_i^j + l / w_i        (1, 2)
  v         = max_i F_i^last over each task's most recently dispatched request
  formation:  take requests in start-tag order; stop at B_max (profiled
              throughput knee) or when admitting one more would push ANY
              selected request past its SLO deadline.
  adapters:   requests sharing the backbone co-batch; adapter-incompatible
              requests execute as sequential compatible sub-batches (Fig. 5c).
  correction: after a batch of size b executes, retro-correct tags of the
              dispatched requests and every queued request of participating
              tasks with the batch-dependent service time
              F_i^j = S_i^j + l_i(b) / w_i                                (3)

Token-level plane (event-loop serving, ``core.serve_loop``): generative
streams consume service in decode chunks, not whole requests, so the loop
charges each participating task ``charge_tokens`` work units per unit of
device time — a decode chunk charges the tokens the task's streams actually
COMMITTED that chunk (the engine's rid-keyed charge log: under speculative
decoding a high-accept stream commits up to ``spec_k + 1`` tokens per scan
step while a zero-accept co-batched stream commits one, and their tasks are
billed accordingly; on engines without the log this degenerates to the old
``chunk × active_slots(task)`` flat split), a prefill admission charges the
true prompt length. Charges advance the
task's virtual finish time by ``l(1) · tokens / w_i`` (the same per-token
price arrival tags use), so weighted max-min sharing holds across the pooled
and generative planes at token granularity: the loop dispatches whichever
unit of work — pooled sub-batch, admission, or decode chunk — carries the
smallest virtual tag.

All schedulers are event-driven and time-source-agnostic: the same code runs
under the discrete-event simulator and the real-execution server.
"""
from __future__ import annotations

import collections
from typing import Optional

from repro.core.profile import FMProfile
from repro.core.request import Batch, Request
from repro.core.vfm import VFM


def group_sub_batches(requests: list[Request], vfms: dict[str, VFM]):
    """Adapter-compatibility grouping: one backbone co-batch, sequential
    adapter sub-batches; base-model requests (no adapter) need no sub-batch."""
    by_adapter: dict[Optional[str], list[Request]] = collections.defaultdict(list)
    for r in requests:
        aid = vfms[r.task_id].extensions.adapter_id
        by_adapter[aid].append(r)
    return [(aid, rs) for aid, rs in by_adapter.items()]


class SchedulerBase:
    name = "base"
    # True when charge_tokens/task_vtime maintain a real token-level virtual
    # clock; schedulers without one (STFQ, FIFO) need the event loop to
    # alternate planes instead of comparing their meaningless decode tags
    token_accounting = False

    def __init__(self, profile: FMProfile):
        self.profile = profile

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        vfm.enqueue(req)

    def next_batch(self, vfms: dict[str, VFM], now: float, *, pred=None,
                   limit: Optional[int] = None,
                   defer_charge: bool = False) -> Optional[Batch]:
        raise NotImplementedError

    def exec_time(self, batch: Batch) -> float:
        sizes = [len(rs) for aid, rs in batch.sub_batches if aid is not None]
        return self.profile.exec_time(batch.size, sizes)

    def on_complete(self, batch: Batch, vfms: dict[str, VFM], now: float):
        pass

    # ---- token-level plane (event-loop serving) ----
    def charge_tokens(self, vfms: dict[str, VFM],
                      tokens_by_task: dict[str, float], now: float):
        """Charge mid-request service (decode chunks, prefill admissions) to
        each task's virtual time. No-op for schedulers without virtual time —
        the event loop then degrades to its tie-break order."""

    def task_vtime(self, task_id: str) -> float:
        """Virtual start tag of the task's NEXT unit of in-flight work (its
        decode stream's next chunk). 0.0 when the scheduler has no notion."""
        return 0.0

    def peek_tag(self, vfms: dict[str, VFM], pred=None) -> float:
        """Smallest start tag among queued requests matching ``pred``
        (inf when none) — what the event loop compares plane tags against."""
        tags = [r.start_tag for v in vfms.values() for r in v.queue
                if pred is None or pred(r)]
        return min(tags) if tags else float("inf")

    def peek_request(self, vfms: dict[str, VFM], pred=None):
        """The queued request the next dispatch would serve (smallest start
        tag, rid tie-break), WITHOUT popping it — the event loop inspects it
        (e.g. its prompt length) to decide whether the decode pool can admit
        it yet (memory-aware admission)."""
        best = None
        for v in vfms.values():
            for r in v.queue:
                if pred is not None and not pred(r):
                    continue
                if best is None or (r.start_tag, r.rid) < (best.start_tag,
                                                           best.rid):
                    best = r
        return best

    def on_cancel(self, vfms: dict[str, VFM], req: Request) -> bool:
        """Remove a still-QUEUED request (client cancel / deadline shed)
        before it is ever dispatched. Returns False when the request is not
        queued (already dispatched — nothing to unwind here). Baselines
        without tag chains need nothing more; BFQ refunds the arrival tags."""
        v = vfms.get(req.task_id)
        if v is None or req not in v.queue:
            return False
        v.queue.remove(req)
        return True

    def snapshot_tags(self) -> Optional[dict]:
        """Virtual-time state for an engine snapshot (durability layer).
        Baselines carry no cross-request tag state — nothing to capture."""
        return None

    def restore_tags(self, tags: Optional[dict]):
        return None

    @staticmethod
    def _pop(vfms, selected):
        for r in selected:
            vfms[r.task_id].queue.remove(r)


class BFQ(SchedulerBase):
    """Batch-aware fair queueing (work-conserving, weighted)."""
    name = "bfq"
    token_accounting = True

    def __init__(self, profile: FMProfile):
        super().__init__(profile)
        self.v = 0.0                          # global virtual tag
        self._tail: dict[str, float] = {}     # F of task's last ENQUEUED request
        self._last_dispatched: dict[str, float] = {}  # F of last DISPATCHED

    def snapshot_tags(self) -> dict:
        """Capture the virtual-time state (global tag + per-task finish-tag
        chains) for the durability layer: a restored engine resumes with the
        SAME fair-share history, so a reset cannot reset anyone's share."""
        return {"v": self.v, "tail": dict(self._tail),
                "last_dispatched": dict(self._last_dispatched)}

    def restore_tags(self, tags: Optional[dict]):
        if not tags:
            return
        self.v = float(tags["v"])
        self._tail = dict(tags["tail"])
        self._last_dispatched = dict(tags["last_dispatched"])

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        """Eqs. 1-2. Token-based FMs (paper §4.2): the expected service time
        scales with the request's token count, so heavier requests advance the
        task's finish tags proportionally — same accounting principle across
        request-level and token-level runtimes, no separate token policy."""
        prev_f = self._tail.get(vfm.task_id, 0.0)
        req.v_at_arrival = self.v
        req.start_tag = max(prev_f, self.v)
        l1 = self.profile.l(1) * max(req.tokens, 1e-9)
        req.finish_tag = req.start_tag + l1 / vfm.weight
        self._tail[vfm.task_id] = req.finish_tag
        vfm.enqueue(req)

    def next_batch(self, vfms: dict[str, VFM], now: float, *, pred=None,
                   limit: Optional[int] = None,
                   defer_charge: bool = False) -> Optional[Batch]:
        """Form one batch in start-tag order. ``pred`` restricts formation to
        matching requests (the event loop separates pooled and generative
        work units); ``limit`` caps the batch below B_max (e.g. at the decode
        pool's free slot count).

        ``defer_charge``: dispatch bookkeeping advances the task's virtual
        time only to the request's START tag, not its finish tag — for
        streams whose service is charged incrementally via ``charge_tokens``
        (admission prefill + per-chunk). Without this the stream would be
        double-priced: once by the arrival finish tag's full prompt+budget
        estimate, again by the actual per-token charges."""
        queued = [r for v in vfms.values() for r in v.queue
                  if pred is None or pred(r)]
        if not queued:
            return None
        queued.sort(key=lambda r: (r.start_tag, r.rid))
        b_cap = self.profile.b_max if limit is None \
            else min(self.profile.b_max, limit)
        selected: list[Request] = []
        # incremental formation state (O(B_max) per dispatch instead of
        # O(B_max^2)): adapter-size counter and the tightest deadline among
        # still-satisfiable candidates are both maintained as requests join
        sizes: collections.Counter = collections.Counter()
        l1 = self.profile.l(1)
        min_deadline = float("inf")
        for r in queued:
            if len(selected) >= b_cap:
                break
            aid = vfms[r.task_id].extensions.adapter_id
            sizes[aid] += 1
            a_sizes = [n for a, n in sizes.items() if a is not None]
            done = now + self.profile.exec_time(len(selected) + 1, a_sizes)
            cand_deadline = min(
                min_deadline,
                r.deadline() if r.deadline() >= now + l1 else float("inf"))
            # stop extending if it would push a STILL-SATISFIABLE request past
            # its deadline (already-expired requests are served best-effort —
            # they cannot be "pushed past" anything)
            if selected and done > cand_deadline:
                sizes[aid] -= 1
                break
            selected.append(r)
            min_deadline = cand_deadline
        self._pop(vfms, selected)
        batch = Batch(selected, group_sub_batches(selected, vfms))
        # dispatch bookkeeping: v = max_i F_i^last over dispatched requests
        for r in selected:
            tag = r.start_tag if defer_charge else r.finish_tag
            self._last_dispatched[r.task_id] = max(
                self._last_dispatched.get(r.task_id, 0.0), tag)
            r.dispatch_time = now
        self.v = max([self.v] + list(self._last_dispatched.values()))
        return batch

    def on_complete(self, batch: Batch, vfms: dict[str, VFM], now: float):
        """Eq. 3 retro-correction with the realized batch size."""
        b = batch.size
        lb = self.profile.effective_per_request(b)
        per_task = collections.Counter(r.task_id for r in batch.requests)
        for tid in per_task:
            vfm = vfms[tid]
            # correct the dispatched requests' finish tags
            f_last = self._last_dispatched.get(tid, 0.0)
            for r in batch.requests:
                if r.task_id != tid:
                    continue
                r.finish_tag = r.start_tag + lb * max(r.tokens, 1e-9) / vfm.weight
                f_last = max(f_last, r.finish_tag)
            self._last_dispatched[tid] = f_last
            # re-chain the queued requests of this task (Eq. 3)
            prev = f_last
            for r in vfm.queue:
                r.start_tag = max(prev, r.v_at_arrival)
                r.finish_tag = r.start_tag + lb * max(r.tokens, 1e-9) / vfm.weight
                prev = r.finish_tag
            self._tail[tid] = prev if vfm.queue else f_last
        self.v = max([self.v] + list(self._last_dispatched.values()))

    def charge_tokens(self, vfms: dict[str, VFM],
                      tokens_by_task: dict[str, float], now: float):
        """Token-level virtual-time accounting (event-loop plane).

        Each charged task's virtual finish advances by ``l(1)·tokens/w``
        chained TASK-LOCALLY from its last finish — the same way a backlogged
        task's queued requests chain off its tail — so a lighter-weight
        stream falls behind proportionally and weighted shares hold at token
        granularity (chaining from the global ``v`` instead would reset the
        stream's lag every chunk and collapse sharing to 1:1). A stream
        cannot bank credit by idling: its slots only exist between an
        admission (whose arrival tag was clamped to ``v``) and its retire.
        The task's QUEUED requests are re-chained off the new finish (Eq. 3
        style): without this, requests enqueued before a long decode chunk
        would keep stale, too-early tags and jump the fair order at their
        next admission."""
        l1 = self.profile.l(1)
        for tid, toks in tokens_by_task.items():
            if toks <= 0:
                continue
            vfm = vfms.get(tid)
            w = vfm.weight if vfm is not None else 1.0
            start = self._last_dispatched.get(tid, self.v)
            f = start + l1 * toks / w
            self._last_dispatched[tid] = f
            if vfm is not None:
                prev = f
                for r in vfm.queue:
                    r.start_tag = max(prev, r.v_at_arrival)
                    r.finish_tag = r.start_tag + \
                        l1 * max(r.tokens, 1e-9) / w
                    prev = r.finish_tag
                self._tail[tid] = prev if vfm.queue else f
        self.v = max([self.v] + list(self._last_dispatched.values()))

    def on_cancel(self, vfms: dict[str, VFM], req: Request) -> bool:
        """Cancel refund: a queued request's arrival advanced the task's
        enqueue tail (Eqs. 1-2), so every request queued BEHIND it chains off
        an l(1)·tokens/w slice of service the task will now never receive —
        a shed/cancelled request would permanently distort the task's fair
        share. Removing it re-chains the remaining queue off the task's last
        DISPATCHED finish (exactly the Eq. 3 re-chain ``on_complete`` and
        ``charge_tokens`` perform), restoring the tags to what they would
        have been had the request never arrived."""
        if not super().on_cancel(vfms, req):
            return False
        tid = req.task_id
        vfm = vfms[tid]
        l1 = self.profile.l(1)
        prev = self._last_dispatched.get(tid, 0.0)
        for r in vfm.queue:
            r.start_tag = max(prev, r.v_at_arrival)
            r.finish_tag = r.start_tag + l1 * max(r.tokens, 1e-9) / vfm.weight
            prev = r.finish_tag
        self._tail[tid] = prev
        return True

    def task_vtime(self, task_id: str) -> float:
        return self._last_dispatched.get(task_id, 0.0)


class STFQ(SchedulerBase):
    """Classical start-time fair queueing (S-STFQ baseline): fair tags, but
    per-request service — batching disabled."""
    name = "stfq"

    def __init__(self, profile: FMProfile):
        super().__init__(profile)
        self.v = 0.0
        self._tail: dict[str, float] = {}

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        prev_f = self._tail.get(vfm.task_id, 0.0)
        req.start_tag = max(prev_f, self.v)
        req.finish_tag = req.start_tag + self.profile.l(1) / vfm.weight
        self._tail[vfm.task_id] = req.finish_tag
        vfm.enqueue(req)

    def next_batch(self, vfms, now, *, pred=None, limit=None,
                   defer_charge=False):
        queued = [r for v in vfms.values() for r in v.queue
                  if pred is None or pred(r)]
        if not queued:
            return None
        r = min(queued, key=lambda r: (r.start_tag, r.rid))
        self._pop(vfms, [r])
        r.dispatch_time = now
        self.v = max(self.v, r.start_tag)
        return Batch([r], group_sub_batches([r], vfms))


class FIFOBatch(SchedulerBase):
    """S-BE baseline: arrival-order batching up to B_max, no fairness."""
    name = "s-be"

    def next_batch(self, vfms, now, *, pred=None, limit=None,
                   defer_charge=False):
        queued = [r for v in vfms.values() for r in v.queue
                  if pred is None or pred(r)]
        if not queued:
            return None
        queued.sort(key=lambda r: (r.arrival, r.rid))
        b_cap = self.profile.b_max if limit is None \
            else min(self.profile.b_max, limit)
        selected = queued[: b_cap]
        self._pop(vfms, selected)
        for r in selected:
            r.dispatch_time = now
        return Batch(selected, group_sub_batches(selected, vfms))


SCHEDULERS = {"bfq": BFQ, "stfq": STFQ, "s-be": FIFOBatch}
