"""Batch-aware Fair Queueing (BFQ) — paper §4.2 — plus scheduler baselines.

BFQ extends start-time fair queueing (STFQ) from per-request ordering to batch
formation:

  arrival:    S_i^j = max(F_i^{j-1}, v),  F_i^j = S_i^j + l / w_i        (1, 2)
  v         = max_i F_i^last over each task's most recently dispatched request
  formation:  take requests in start-tag order; stop at B_max (profiled
              throughput knee) or when admitting one more would push ANY
              selected request past its SLO deadline.
  adapters:   requests sharing the backbone co-batch; adapter-incompatible
              requests execute as sequential compatible sub-batches (Fig. 5c).
  correction: after a batch of size b executes, retro-correct tags of the
              dispatched requests and every queued request of participating
              tasks with the batch-dependent service time
              F_i^j = S_i^j + l_i(b) / w_i                                (3)

All schedulers are event-driven and time-source-agnostic: the same code runs
under the discrete-event simulator and the real-execution server.
"""
from __future__ import annotations

import collections
from typing import Optional

from repro.core.profile import FMProfile
from repro.core.request import Batch, Request
from repro.core.vfm import VFM


def group_sub_batches(requests: list[Request], vfms: dict[str, VFM]):
    """Adapter-compatibility grouping: one backbone co-batch, sequential
    adapter sub-batches; base-model requests (no adapter) need no sub-batch."""
    by_adapter: dict[Optional[str], list[Request]] = collections.defaultdict(list)
    for r in requests:
        aid = vfms[r.task_id].extensions.adapter_id
        by_adapter[aid].append(r)
    return [(aid, rs) for aid, rs in by_adapter.items()]


class SchedulerBase:
    name = "base"

    def __init__(self, profile: FMProfile):
        self.profile = profile

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        vfm.enqueue(req)

    def next_batch(self, vfms: dict[str, VFM], now: float) -> Optional[Batch]:
        raise NotImplementedError

    def exec_time(self, batch: Batch) -> float:
        sizes = [len(rs) for aid, rs in batch.sub_batches if aid is not None]
        return self.profile.exec_time(batch.size, sizes)

    def on_complete(self, batch: Batch, vfms: dict[str, VFM], now: float):
        pass

    @staticmethod
    def _pop(vfms, selected):
        for r in selected:
            vfms[r.task_id].queue.remove(r)


class BFQ(SchedulerBase):
    """Batch-aware fair queueing (work-conserving, weighted)."""
    name = "bfq"

    def __init__(self, profile: FMProfile):
        super().__init__(profile)
        self.v = 0.0                          # global virtual tag
        self._tail: dict[str, float] = {}     # F of task's last ENQUEUED request
        self._last_dispatched: dict[str, float] = {}  # F of last DISPATCHED

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        """Eqs. 1-2. Token-based FMs (paper §4.2): the expected service time
        scales with the request's token count, so heavier requests advance the
        task's finish tags proportionally — same accounting principle across
        request-level and token-level runtimes, no separate token policy."""
        prev_f = self._tail.get(vfm.task_id, 0.0)
        req.v_at_arrival = self.v
        req.start_tag = max(prev_f, self.v)
        l1 = self.profile.l(1) * max(req.tokens, 1e-9)
        req.finish_tag = req.start_tag + l1 / vfm.weight
        self._tail[vfm.task_id] = req.finish_tag
        vfm.enqueue(req)

    def next_batch(self, vfms: dict[str, VFM], now: float) -> Optional[Batch]:
        queued = [r for v in vfms.values() for r in v.queue]
        if not queued:
            return None
        queued.sort(key=lambda r: (r.start_tag, r.rid))
        selected: list[Request] = []
        # incremental formation state (O(B_max) per dispatch instead of
        # O(B_max^2)): adapter-size counter and the tightest deadline among
        # still-satisfiable candidates are both maintained as requests join
        sizes: collections.Counter = collections.Counter()
        l1 = self.profile.l(1)
        min_deadline = float("inf")
        for r in queued:
            if len(selected) >= self.profile.b_max:
                break
            aid = vfms[r.task_id].extensions.adapter_id
            sizes[aid] += 1
            a_sizes = [n for a, n in sizes.items() if a is not None]
            done = now + self.profile.exec_time(len(selected) + 1, a_sizes)
            cand_deadline = min(
                min_deadline,
                r.deadline() if r.deadline() >= now + l1 else float("inf"))
            # stop extending if it would push a STILL-SATISFIABLE request past
            # its deadline (already-expired requests are served best-effort —
            # they cannot be "pushed past" anything)
            if selected and done > cand_deadline:
                sizes[aid] -= 1
                break
            selected.append(r)
            min_deadline = cand_deadline
        self._pop(vfms, selected)
        batch = Batch(selected, group_sub_batches(selected, vfms))
        # dispatch bookkeeping: v = max_i F_i^last over dispatched requests
        for r in selected:
            self._last_dispatched[r.task_id] = max(
                self._last_dispatched.get(r.task_id, 0.0), r.finish_tag)
            r.dispatch_time = now
        self.v = max([self.v] + list(self._last_dispatched.values()))
        return batch

    def on_complete(self, batch: Batch, vfms: dict[str, VFM], now: float):
        """Eq. 3 retro-correction with the realized batch size."""
        b = batch.size
        lb = self.profile.effective_per_request(b)
        per_task = collections.Counter(r.task_id for r in batch.requests)
        for tid in per_task:
            vfm = vfms[tid]
            # correct the dispatched requests' finish tags
            f_last = self._last_dispatched.get(tid, 0.0)
            for r in batch.requests:
                if r.task_id != tid:
                    continue
                r.finish_tag = r.start_tag + lb * max(r.tokens, 1e-9) / vfm.weight
                f_last = max(f_last, r.finish_tag)
            self._last_dispatched[tid] = f_last
            # re-chain the queued requests of this task (Eq. 3)
            prev = f_last
            for r in vfm.queue:
                r.start_tag = max(prev, r.v_at_arrival)
                r.finish_tag = r.start_tag + lb * max(r.tokens, 1e-9) / vfm.weight
                prev = r.finish_tag
            self._tail[tid] = prev if vfm.queue else f_last
        self.v = max([self.v] + list(self._last_dispatched.values()))


class STFQ(SchedulerBase):
    """Classical start-time fair queueing (S-STFQ baseline): fair tags, but
    per-request service — batching disabled."""
    name = "stfq"

    def __init__(self, profile: FMProfile):
        super().__init__(profile)
        self.v = 0.0
        self._tail: dict[str, float] = {}

    def on_arrival(self, vfm: VFM, req: Request, now: float):
        prev_f = self._tail.get(vfm.task_id, 0.0)
        req.start_tag = max(prev_f, self.v)
        req.finish_tag = req.start_tag + self.profile.l(1) / vfm.weight
        self._tail[vfm.task_id] = req.finish_tag
        vfm.enqueue(req)

    def next_batch(self, vfms, now):
        queued = [r for v in vfms.values() for r in v.queue]
        if not queued:
            return None
        r = min(queued, key=lambda r: (r.start_tag, r.rid))
        self._pop(vfms, [r])
        r.dispatch_time = now
        self.v = max(self.v, r.start_tag)
        return Batch([r], group_sub_batches([r], vfms))


class FIFOBatch(SchedulerBase):
    """S-BE baseline: arrival-order batching up to B_max, no fairness."""
    name = "s-be"

    def next_batch(self, vfms, now):
        queued = [r for v in vfms.values() for r in v.queue]
        if not queued:
            return None
        queued.sort(key=lambda r: (r.arrival, r.rid))
        selected = queued[: self.profile.b_max]
        self._pop(vfms, selected)
        for r in selected:
            r.dispatch_time = now
        return Batch(selected, group_sub_batches(selected, vfms))


SCHEDULERS = {"bfq": BFQ, "stfq": STFQ, "s-be": FIFOBatch}
