"""Durable serving state: the host-RAM KV spill tier and engine snapshots.

Every recovery path the serving plane had before this module DESTROYS state:
a preempted stream frees its pages and pays a full re-prefill (re-quantizing
its K/V — lossy at the int8 level), a shared prefix dies with its last
sharer, and an engine rebuild would drop every live stream. This module owns
the two host-side containers that make those paths stateful:

  * ``HostSpillArena`` — a bounded (byte-budgeted, LRU) host-RAM store of
    spilled int8 KV pages. Two entry kinds share the budget:

      - *stream* entries (keyed by rid): a preemption victim's full KV state
        — its pages, per-page scales, slot running scales, drift trackers,
        last token and PRNG key — captured D2H at preemption. Resume
        restores by H2D copy into freshly allocated pages: no re-prefill,
        no re-quantization, exact token AND sampling-stream parity with a
        never-preempted run.
      - *prefix* entries (keyed by the registry's chained sha256 digest):
        a registered prompt page whose last sharer released it. A later
        join whose prompt chain reaches the digest restores the page by
        DMA instead of holding only recomputed content, and re-registers
        it so the following wave of sharers deduplicates again — a shared
        system prompt now survives idle gaps between request waves.

    Every entry carries a sha256 digest over its array bytes, verified at
    restore: a corrupted entry is dropped (``digest_failures`` counted) and
    the engine falls back to recompute — the spill tier can only ever be as
    wrong as having no spill tier. Budget pressure evicts LRU entries the
    same way: recompute is always the fallback, never an error.

  * ``EngineSnapshot`` — the full logical state of a paged ``DecodeEngine``
    captured between chunks: used-page contents (D2H) with per-page sha256
    digests, page tables, refcounts, the chained-digest prefix registry,
    per-slot sampling/PRNG/deadline state, the pending (deferred/preempted/
    stranded) queue, counters, and the constructor config needed to rebuild.
    ``DecodeEngine.restore`` rebuilds a fresh engine and arena from one,
    verifying every restored page's digest (corrupt pages requeue their
    streams through the lossless fold-and-re-prefill path instead of
    serving poisoned KV). ``ServeLoop.checkpoint_restart`` drives the full
    quiesce → snapshot → teardown → restore → resume sequence, and
    ``checkpoint.ckpt.save_snapshot`` persists one to disk (the spill arena
    itself is RAM-resident and not serialized: a cross-process restore
    simply falls back to recompute on its first resumes).

Digests are cheap relative to the D2H copy they protect and they convert
"silent wrong tokens after recovery" — the worst failure mode a serving
plane can have — into a counted, recomputed non-event.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any, Optional

import numpy as np


def _blob_bytes(blob) -> int:
    return sum(a.nbytes for d in blob for a in d.values())


def _blob_digest(blob) -> bytes:
    """sha256 over every array's bytes in deterministic (sub, key) order."""
    h = hashlib.sha256()
    for d in blob:
        for k in sorted(d):
            a = np.ascontiguousarray(d[k])
            h.update(k.encode())
            h.update(a.tobytes())
    return h.digest()


@dataclasses.dataclass
class SpillEntry:
    """One spilled unit: ``blob`` is a list (one dict per attention sublayer
    group) of named host arrays; ``meta`` carries the scalars a restore
    needs (page count, true length, last token, PRNG key...)."""
    blob: list
    meta: dict
    digest: bytes
    nbytes: int

    def verify(self) -> bool:
        return _blob_digest(self.blob) == self.digest


class HostSpillArena:
    """Bounded LRU host-RAM arena for spilled KV state.

    ``put`` inserts (evicting LRU entries until the budget holds — an entry
    larger than the whole budget is skipped, not stored), ``get`` returns an
    entry and marks it most-recently-used, ``pop`` consumes one. All entries
    are digest-stamped at insert; callers verify at restore and treat a
    mismatch as a miss. The arena is deliberately engine-agnostic — it
    stores named host arrays, nothing device- or layout-specific — so one
    arena can back several engines and survives any engine teardown."""

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self._entries: "collections.OrderedDict[Any, SpillEntry]" = \
            collections.OrderedDict()
        self.bytes_in_use = 0
        self.spills = 0          # entries accepted
        self.skips = 0           # entries larger than the whole budget
        self.evictions = 0       # LRU evictions under budget pressure
        self.hits = 0            # get() found a live entry
        self.misses = 0          # get() found nothing (never stored/evicted)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def put(self, key, blob: list, meta: Optional[dict] = None) -> bool:
        """Insert (replacing any same-key entry); returns False when the
        entry alone exceeds the budget and was skipped."""
        nbytes = _blob_bytes(blob)
        if nbytes > self.budget_bytes:
            self.skips += 1
            self.pop(key)        # a stale smaller entry must not linger
            return False
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes_in_use -= old.nbytes
        while self._entries and self.bytes_in_use + nbytes > self.budget_bytes:
            _, ev = self._entries.popitem(last=False)
            self.bytes_in_use -= ev.nbytes
            self.evictions += 1
        self._entries[key] = SpillEntry(blob=blob, meta=dict(meta or {}),
                                        digest=_blob_digest(blob),
                                        nbytes=nbytes)
        self.bytes_in_use += nbytes
        self.spills += 1
        return True

    def peek(self, key) -> Optional[SpillEntry]:
        """Like ``get`` but counts nothing and leaves the LRU order alone —
        for sizing/viability queries that are not themselves a restore."""
        return self._entries.get(key)

    def get(self, key) -> Optional[SpillEntry]:
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e

    def pop(self, key) -> Optional[SpillEntry]:
        e = self._entries.pop(key, None)
        if e is not None:
            self.bytes_in_use -= e.nbytes
        return e


# ---------------- engine snapshots ----------------

@dataclasses.dataclass
class EngineSnapshot:
    """Full logical state of a paged ``DecodeEngine`` between chunks.

    ``pages`` holds ONLY the used (refcount > 0) pages' contents, stacked in
    ``used_pages`` order, one dict of host arrays per attention sublayer
    group; ``page_digests`` maps each used page id to the sha256 over its
    content across groups — ``DecodeEngine.restore`` recomputes and compares
    before any restored stream can decode against the page. Slots, pending
    entries and the rejected list are deep copies (mutating the live engine
    after ``snapshot()`` cannot corrupt the capture). ``spill`` carries the
    host arena BY REFERENCE — it is host RAM, the thing a device reset
    cannot touch — and is excluded from disk serialization."""
    config: dict                       # DecodeEngine ctor kwargs to rebuild
    used_pages: np.ndarray             # (n_used,) arena page ids captured
    pages: list                        # per-sub {k,v,k_scale,v_scale} stacks
    page_digests: dict                 # page id -> sha256 bytes
    slot_state: list                   # per-sub {slot_k_scale,...,k_max,...}
    ptab: np.ndarray
    held: np.ndarray
    lens: np.ndarray
    page_refs: np.ndarray
    slot_adapters: np.ndarray
    tokens: np.ndarray                 # (num_slots,) last token per slot
    keys: np.ndarray                   # (num_slots, 2) PRNG key per slot
    slots: list                        # deep-copied DecodeSlot | None
    pending: list                      # deep-copied _PendingJoin entries
    rejected: list                     # deep-copied terminal rejections
    registry: dict                     # chained digest -> page id
    page_key: dict                     # page id -> chained digest
    counters: dict                     # steps/admissions/... continue
    sched_tags: Optional[dict] = None  # BFQ virtual-time tags (loop-level)
    spill: Optional[HostSpillArena] = None
    # fixed-size per-slot dense state (recurrent conv/SSM/LSTM state, cross
    # K/V sidecars), one dict (or None) per pool sub — captured by
    # ``cache_manager.capture_dense_state`` for hybrid / enc-dec stacks;
    # None on attention-only engines
    dense_state: Optional[list] = None

    def page_digest(self, idx: int) -> bytes:
        """sha256 of captured page ``used_pages[idx]`` across sub groups."""
        h = hashlib.sha256()
        for sub in self.pages:
            for k in ("k", "v", "k_scale", "v_scale"):
                h.update(np.ascontiguousarray(sub[k][:, idx]).tobytes())
        return h.digest()

    # ---- disk round trip (checkpoint.ckpt.save_snapshot/load_snapshot) ----
    def to_host_payload(self):
        """(arrays, meta): flat named host arrays + a JSON-able meta dict.
        The spill arena and scheduler tags' non-JSON keys are the only state
        excluded; everything a fresh process needs to rebuild the engine and
        its streams is here."""
        arrays = {
            "used_pages": np.asarray(self.used_pages, np.int32),
            "ptab": self.ptab, "held": self.held, "lens": self.lens,
            "page_refs": self.page_refs, "slot_adapters": self.slot_adapters,
            "tokens": self.tokens, "keys": self.keys,
        }
        for j, sub in enumerate(self.pages):
            for k, a in sub.items():
                arrays[f"page{j}/{k}"] = a
        for j, sub in enumerate(self.slot_state):
            for k, a in sub.items():
                arrays[f"slot{j}/{k}"] = a
        dense_keys = None
        if self.dense_state is not None:
            dense_keys = []
            for j, sub in enumerate(self.dense_state):
                dense_keys.append(sorted(sub) if sub else None)
                for k, a in (sub or {}).items():
                    arrays[f"dense{j}/{k}"] = a
        meta = {
            "config": _jsonable(self.config),
            "n_subs": len(self.pages),
            "page_digests": {str(p): d.hex()
                             for p, d in self.page_digests.items()},
            "registry": {k.hex(): int(p) for k, p in self.registry.items()},
            "page_key": {str(p): k.hex() for p, k in self.page_key.items()},
            "slots": [_slot_to_json(s) for s in self.slots],
            "pending": [_pending_to_json(p) for p in self.pending],
            "rejected": [_pending_to_json(p) for p in self.rejected],
            "counters": _jsonable(self.counters),
            "sched_tags": _jsonable(self.sched_tags),
            "dense_keys": dense_keys,
        }
        return arrays, meta

    @classmethod
    def from_host_payload(cls, arrays, meta) -> "EngineSnapshot":
        n = int(meta["n_subs"])
        pages = [{k: np.asarray(arrays[f"page{j}/{k}"])
                  for k in ("k", "v", "k_scale", "v_scale")}
                 for j in range(n)]
        slot_state = [{k: np.asarray(arrays[f"slot{j}/{k}"])
                       for k in ("slot_k_scale", "slot_v_scale",
                                 "k_max", "v_max")}
                      for j in range(n)]
        return cls(
            config=dict(meta["config"]),
            used_pages=np.asarray(arrays["used_pages"], np.int32),
            pages=pages,
            page_digests={int(p): bytes.fromhex(d)
                          for p, d in meta["page_digests"].items()},
            slot_state=slot_state,
            ptab=np.asarray(arrays["ptab"]),
            held=np.asarray(arrays["held"]),
            lens=np.asarray(arrays["lens"]),
            page_refs=np.asarray(arrays["page_refs"]),
            slot_adapters=np.asarray(arrays["slot_adapters"]),
            tokens=np.asarray(arrays["tokens"]),
            keys=np.asarray(arrays["keys"]),
            slots=[_slot_from_json(s) for s in meta["slots"]],
            pending=[_pending_from_json(p) for p in meta["pending"]],
            rejected=[_pending_from_json(p) for p in meta["rejected"]],
            registry={bytes.fromhex(k): int(p)
                      for k, p in meta["registry"].items()},
            page_key={int(p): bytes.fromhex(k)
                      for p, k in meta["page_key"].items()},
            counters=dict(meta["counters"]),
            sched_tags=meta.get("sched_tags"),
            dense_state=None if meta.get("dense_keys") is None else [
                None if keys is None else
                {k: np.asarray(arrays[f"dense{j}/{k}"]) for k in keys}
                for j, keys in enumerate(meta["dense_keys"])],
        )


def _jsonable(x):
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


def _slot_to_json(s) -> Optional[dict]:
    if s is None:
        return None
    return {
        "rid": int(s.rid), "task_id": s.task_id,
        "adapter_slot": int(s.adapter_slot), "max_new": int(s.max_new),
        "eos_id": None if s.eos_id is None else int(s.eos_id),
        "tokens": [int(t) for t in s.tokens],
        "t_join": float(s.t_join), "t_first": float(s.t_first),
        "prompt_tokens": int(s.prompt_tokens), "done": bool(s.done),
        "prompt": None if s.prompt is None
        else [int(t) for t in np.asarray(s.prompt).reshape(-1)],
        "adapter_id": s.adapter_id, "deadline": float(s.deadline),
        "status": s.status,
        "enc_feats": None if getattr(s, "enc_feats", None) is None
        else np.asarray(s.enc_feats, np.float32).tolist(),
    }


def _slot_from_json(d):
    if d is None:
        return None
    from repro.core.decode_engine import DecodeSlot
    return DecodeSlot(
        rid=d["rid"], task_id=d["task_id"], adapter_slot=d["adapter_slot"],
        max_new=d["max_new"], eos_id=d["eos_id"], tokens=list(d["tokens"]),
        t_join=d["t_join"], t_first=d["t_first"],
        prompt_tokens=d["prompt_tokens"], done=d["done"],
        prompt=None if d["prompt"] is None
        else np.asarray(d["prompt"], np.int32),
        adapter_id=d["adapter_id"], deadline=d["deadline"],
        status=d["status"],
        enc_feats=None if d.get("enc_feats") is None
        else np.asarray(d["enc_feats"], np.float32))


def _pending_to_json(p) -> dict:
    return {
        "task_id": p.task_id,
        "prompt": [int(t) for t in np.asarray(p.prompt).reshape(-1)],
        "adapter_id": p.adapter_id, "max_new_tokens": int(p.max_new_tokens),
        "rid": int(p.rid),
        "eos_id": None if p.eos_id is None else int(p.eos_id),
        "resume": _slot_to_json(p.resume), "deadline": float(p.deadline),
        "status": p.status,
        "enc_feats": None if getattr(p, "enc_feats", None) is None
        else np.asarray(p.enc_feats, np.float32).tolist(),
    }


def _pending_from_json(d):
    from repro.core.decode_engine import _PendingJoin
    return _PendingJoin(
        task_id=d["task_id"], prompt=np.asarray(d["prompt"], np.int32),
        adapter_id=d["adapter_id"], max_new_tokens=d["max_new_tokens"],
        rid=d["rid"], eos_id=d["eos_id"], resume=_slot_from_json(d["resume"]),
        deadline=d["deadline"], status=d["status"],
        enc_feats=None if d.get("enc_feats") is None
        else np.asarray(d["enc_feats"], np.float32))
