"""The virtual foundation model (vFM) — FMplex's core abstraction (§4.1).

A vFM gives each task the illusion of a private FM. Three facets:
  * virtual queue — invocations are intercepted and queued per task;
  * task extensions — encoder / decoder head / PEFT adapter references that
    customize the shared backbone for this task only;
  * state & accounting — SLO, fair-share weight, and a named accounting
    identity tracking usage (drives admission, fair sharing, SLO enforcement).

vFMs are bound to a physical FM at deployment time and can be rebound at
runtime (Controller elastic adaptation) by moving only this object's state.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional

from repro.core.request import SLO, Request


@dataclasses.dataclass
class TaskExtensions:
    encoder: Any = None          # input-side adaptation module (or None)
    decoder: Any = None          # task head module (or None)
    adapter_id: Optional[str] = None   # PEFT adapter identity (batching key)
    adapter_weights: Any = None


@dataclasses.dataclass
class Accounting:
    """Named per-vFM accounting identity."""
    admitted: int = 0
    completed: int = 0
    dropped: int = 0
    service_time: float = 0.0    # backbone seconds consumed (amortized)
    last_finish_tag: float = 0.0


class VFM:
    """A logically-private FM instance backed by a shared physical FM."""

    def __init__(self, task_id: str, *, weight: float = 1.0,
                 slo: Optional[SLO] = None,
                 extensions: Optional[TaskExtensions] = None,
                 backbone: str = ""):
        self.task_id = task_id
        self.weight = float(weight)
        self.slo = slo or SLO()
        self.extensions = extensions or TaskExtensions()
        self.backbone = backbone
        self.queue: collections.deque[Request] = collections.deque()
        self.acct = Accounting()
        self.bound_fm: Optional[str] = None    # physical FM instance id

    # ---- virtual queue ----
    def enqueue(self, req: Request):
        req.slo = req.slo if req.slo.deadline_s is not None else self.slo
        self.queue.append(req)
        self.acct.admitted += 1

    def __len__(self):
        return len(self.queue)

    # ---- lifecycle (elastic adaptation moves exactly this state) ----
    def snapshot(self) -> dict:
        """Task-local state moved on rebinding (queue metadata, extensions,
        scheduler state) — NOT the backbone."""
        return {
            "task_id": self.task_id,
            "weight": self.weight,
            "slo": self.slo,
            "extensions": self.extensions,
            "queue": list(self.queue),
            "acct": self.acct,
        }

    @classmethod
    def restore(cls, snap: dict, backbone: str = "") -> "VFM":
        v = cls(snap["task_id"], weight=snap["weight"], slo=snap["slo"],
                extensions=snap["extensions"], backbone=backbone)
        v.queue.extend(snap["queue"])
        v.acct = snap["acct"]
        return v
