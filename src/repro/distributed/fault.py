"""Fault tolerance for the training loop: failure detection, straggler
mitigation, and restart bookkeeping.

On a real multi-pod deployment the failure signal comes from the coordinator
(jax.distributed heartbeats); here the same policy objects are driven either
by wall-clock measurements (real plane) or injected events (tests/benches).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class InjectedFailure(RuntimeError):
    """Raised by the failure injector to simulate a node loss mid-run."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    fired: bool = False

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise InjectedFailure(f"injected node failure at step {step}")


class StragglerDetector:
    """Flags steps whose duration exceeds ``threshold`` x rolling median.

    On TPU pods a persistent straggler means a degraded host: the mitigation
    hook (e.g. Controller rebind / mesh shrink) is invoked after ``patience``
    consecutive slow steps.
    """

    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 on_straggler: Optional[Callable[[int], None]] = None):
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.durations: list[float] = []
        self.slow_streak = 0
        self.events: list[int] = []

    def record(self, step: int, duration_s: float) -> bool:
        self.durations.append(duration_s)
        hist = sorted(self.durations[-50:])
        med = hist[len(hist) // 2]
        slow = len(self.durations) > 5 and duration_s > self.threshold * med
        self.slow_streak = self.slow_streak + 1 if slow else 0
        if self.slow_streak >= self.patience:
            self.events.append(step)
            self.slow_streak = 0
            if self.on_straggler:
                self.on_straggler(step)
            return True
        return False


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.duration = time.perf_counter() - self.t0
