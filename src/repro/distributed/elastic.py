"""Elastic scaling for training: rebuild the mesh at a different size and
reshard state from the last checkpoint (the train-side analogue of the
Controller's serving-side elasticity).

Workflow on node loss / cluster resize:
  1. coordinator detects the new healthy device set;
  2. ``shrink_plan`` picks the largest usable mesh (data axis shrinks first —
     model-parallel groups must stay intact);
  3. restore the last checkpoint with the new mesh's shardings
     (``repro.checkpoint.ckpt.restore`` reshards on load);
  4. training resumes; global batch is preserved by raising grad-accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


@dataclasses.dataclass
class MeshPlan:
    pods: int
    data: int
    model: int
    grad_accum: int          # multiplier to preserve the global batch

    @property
    def devices(self) -> int:
        return self.pods * self.data * self.model


def shrink_plan(healthy_devices: int, *, model_parallel: int,
                old_data: int, old_pods: int = 1) -> Optional[MeshPlan]:
    """Largest mesh with the same model axis that fits the healthy devices."""
    if healthy_devices < model_parallel:
        return None
    pods = old_pods
    while pods >= 1:
        avail = healthy_devices // (pods * model_parallel)
        data = 1
        while data * 2 <= min(avail, old_data):
            data *= 2
        if avail >= 1:
            accum = max(1, (old_data * old_pods) // (data * pods))
            return MeshPlan(pods, data, model_parallel, accum)
        pods -= 1
    return None


def rebuild_mesh(plan: MeshPlan):
    from repro.launch.mesh import make_mesh_for
    return make_mesh_for(plan.devices, model_parallel=plan.model,
                         pods=plan.pods)


def reshard_state(ckpt_dir, state_like, mesh, shardings, step=None):
    """Restore the latest checkpoint resharded onto ``mesh``."""
    from repro.checkpoint import ckpt
    return ckpt.restore(ckpt_dir, state_like, step=step, shardings=shardings)
