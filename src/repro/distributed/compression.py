"""Gradient compression for cross-replica reduction (distributed-optimization
trick; used by the shard_map data-parallel trainer).

int8 quantized all-reduce: per-tensor symmetric scale -> int8 payload ->
ring all-reduce in int32 (exact sum of quantized values) -> dequantize.
Cuts gradient-sync bytes 4x vs f32 / 2x vs bf16 at <1e-2 relative error,
validated against exact psum in tests/test_distributed.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """Drop-in psum replacement for use INSIDE shard_map: int8 payload.

    The scale itself is max-reduced first (tiny payload) so every replica
    quantizes onto a common grid and the int32 sum is exact.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def compressed_pmean(x, axis_name: str):
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return compressed_psum(x, axis_name) / n


def tree_compressed_pmean(tree, axis_name: str):
    return jax.tree.map(lambda g: compressed_pmean(g, axis_name), tree)
