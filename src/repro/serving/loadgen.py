"""Workload generators (paper §7.1.3): Poisson sweeps, noisy-neighbor bursts,
and an Azure-Functions-like trace (lognormal per-task rates in low/moderate/
high load bands, with bursty on/off periods)."""
from __future__ import annotations

import numpy as np

from repro.core.request import SLO, Request


def poisson_trace(task_id: str, rps: float, horizon: float, *, seed: int = 0,
                  slo_s: float | None = None, start: float = 0.0) -> list[Request]:
    rng = np.random.RandomState(seed)
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        out.append(Request(task_id, t, slo=SLO(slo_s)))
    return out


def token_trace(task_id: str, rps: float, horizon: float, *, prompt_len: int,
                vocab: int, max_new: int = 8, seed: int = 0,
                slo_s: float | None = None, start: float = 0.0,
                min_prompt_len: int | None = None,
                infeasible_frac: float = 0.0,
                infeasible_slo_s: float = 1e-4) -> list[Request]:
    """Generative (prefill+decode) Poisson trace for the DecodeEngine path.

    Each request carries a random prompt (``payload``: int32 token ids) and a
    sampled decode budget (``max_new_tokens`` uniform in [1, max_new] —
    variable output lengths are what make continuous batching bite).
    ``min_prompt_len`` < ``prompt_len`` samples VARIABLE prompt lengths
    uniformly in [min, max] (exercising the engine's bucketed variable-length
    admission); by default all prompts are ``prompt_len`` long.
    ``Request.tokens`` carries prompt + output work units so BFQ's
    token-based accounting (§4.2) prices heavy requests proportionally.
    ``infeasible_frac`` marks that fraction of requests with a deadline no
    admission could meet (``infeasible_slo_s``, default 0.1 ms) — the chaos
    harness's fodder for the loop's pre-admission deadline shedding."""
    rng = np.random.RandomState(seed)
    lo = prompt_len if min_prompt_len is None else max(1, min_prompt_len)
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        new = int(rng.randint(1, max_new + 1))
        plen = int(rng.randint(lo, prompt_len + 1))
        slo = SLO(infeasible_slo_s) if rng.rand() < infeasible_frac \
            else SLO(slo_s)
        out.append(Request(
            task_id, t, payload=rng.randint(0, vocab, plen).astype("int32"),
            tokens=float(plen + new), max_new_tokens=new, slo=slo))
    return out


def long_tail_token_trace(task_id: str, rps: float, horizon: float, *,
                          prompt_len: int, vocab: int, new_lo: int = 8,
                          new_hi: int = 512, seed: int = 0,
                          slo_s: float | None = None, start: float = 0.0,
                          min_prompt_len: int | None = None) -> list[Request]:
    """Generative trace with a LONG-TAIL decode-length mix: ``max_new_tokens``
    sampled log-uniformly in [new_lo, new_hi] (default 8-512), so most
    streams are short while a heavy tail runs 10-60x longer. This is the
    workload shape that makes dense per-slot KV reservations waste memory —
    and therefore what exercises page recycling and memory-aware admission
    on the paged pool: short streams retire and return pages while the tail
    keeps decoding. Prompt lengths are uniform in
    [min_prompt_len or prompt_len, prompt_len] like ``token_trace``."""
    rng = np.random.RandomState(seed)
    lo = prompt_len if min_prompt_len is None else max(1, min_prompt_len)
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        new = int(round(np.exp(rng.uniform(np.log(new_lo),
                                           np.log(new_hi + 1)))))
        new = max(new_lo, min(new, new_hi))
        plen = int(rng.randint(lo, prompt_len + 1))
        out.append(Request(
            task_id, t, payload=rng.randint(0, vocab, plen).astype("int32"),
            tokens=float(plen + new), max_new_tokens=new, slo=SLO(slo_s)))
    return out


def shared_prefix_token_trace(task_id: str, rps: float, horizon: float, *,
                              prefix_len: int, prompt_len: int, vocab: int,
                              shared_frac: float = 0.8, n_prefixes: int = 1,
                              max_new: int = 8, seed: int = 0,
                              slo_s: float | None = None,
                              start: float = 0.0) -> list[Request]:
    """Generative trace for the COW prefix-sharing path: ``shared_frac`` of
    the requests carry one of ``n_prefixes`` fixed ``prefix_len``-token
    system/few-shot prefixes followed by a short unique user suffix (total
    length uniform in (prefix_len, prompt_len]); the rest carry fully random
    prompts up to ``prompt_len``. This is the multi-task serving shape the
    paper's memory argument targets — N co-resident streams repeating the
    same system prompt — where an unshared paged pool stores the prefix N
    times and a refcounted COW pool stores it once. ``max_new_tokens`` is
    uniform in [1, max_new] like ``token_trace``."""
    assert 0 < prefix_len < prompt_len
    rng = np.random.RandomState(seed)
    prefixes = [rng.randint(0, vocab, prefix_len).astype("int32")
                for _ in range(max(1, n_prefixes))]
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        new = int(rng.randint(1, max_new + 1))
        if rng.rand() < shared_frac:
            suffix = rng.randint(0, vocab, int(
                rng.randint(1, prompt_len - prefix_len + 1))).astype("int32")
            prompt = np.concatenate(
                [prefixes[rng.randint(len(prefixes))], suffix])
        else:
            prompt = rng.randint(0, vocab, int(
                rng.randint(1, prompt_len + 1))).astype("int32")
        out.append(Request(
            task_id, t, payload=prompt, tokens=float(len(prompt) + new),
            max_new_tokens=new, slo=SLO(slo_s)))
    return out


def agentic_token_trace(task_id: str, rps: float, horizon: float, *,
                        prompt_len: int, vocab: int, overlap: float = 0.7,
                        motif_len: int = 8, n_motifs: int = 4,
                        max_new: int = 16, min_new: int | None = None,
                        seed: int = 0, slo_s: float | None = None,
                        start: float = 0.0) -> list[Request]:
    """Agentic tool-call-loop trace: the workload shape self-speculative
    decoding feeds on. An agent loop re-feeds its own context every round —
    tool-call scaffolding, echoed tool output, restated plans — so a large
    fraction of each prompt RECURS within itself and the stream's n-gram
    self-overlap is high (the prompt-lookup drafter finds matches, and a
    model continuing such a context keeps emitting spans it already
    emitted).

    Each prompt interleaves segments drawn from a small per-trace motif
    pool (the recurring scaffolding) with fresh random segments; a segment
    is a motif with probability ``overlap``, so ``overlap`` IS the tunable
    self-overlap fraction. ``overlap=0.0`` degenerates to fully-random
    prompts — the low-overlap ADVERSARIAL variant (see
    ``adversarial_token_trace``) where drafts never match and a speculative
    engine must fall back to plain decoding. ``max_new_tokens`` is uniform
    in [min_new or 1, max_new] like ``token_trace``."""
    assert 0.0 <= overlap <= 1.0
    rng = np.random.RandomState(seed)
    motifs = [rng.randint(0, vocab, motif_len).astype("int32")
              for _ in range(max(1, n_motifs))]
    lo_new = max(1, min_new) if min_new is not None else 1
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        plen = int(rng.randint(max(motif_len, prompt_len // 2),
                               prompt_len + 1))
        parts, n = [], 0
        while n < plen:
            seg = motifs[rng.randint(len(motifs))] if rng.rand() < overlap \
                else rng.randint(0, vocab, motif_len).astype("int32")
            parts.append(seg)
            n += len(seg)
        prompt = np.concatenate(parts)[:plen].astype("int32")
        new = int(rng.randint(lo_new, max_new + 1))
        out.append(Request(
            task_id, t, payload=prompt, tokens=float(plen + new),
            max_new_tokens=new, slo=SLO(slo_s)))
    return out


def adversarial_token_trace(task_id: str, rps: float, horizon: float, *,
                            prompt_len: int, vocab: int, max_new: int = 16,
                            min_new: int | None = None, seed: int = 0,
                            slo_s: float | None = None,
                            start: float = 0.0) -> list[Request]:
    """Zero-self-overlap adversarial trace for the speculative plane:
    ``agentic_token_trace`` at ``overlap=0.0`` — fully random prompts with
    no recurring structure, so every draft window misses and a speculative
    engine's adaptive demotion is what stands between it and paying the
    verify overhead for nothing. The bench's regression bound (speculation
    on vs off on THIS trace) is the cost of that machinery."""
    return agentic_token_trace(
        task_id, rps, horizon, prompt_len=prompt_len, vocab=vocab,
        overlap=0.0, max_new=max_new, min_new=min_new, seed=seed,
        slo_s=slo_s, start=start)


def feature_trace(task_id: str, rps: float, horizon: float, *, input_len: int,
                  d_model: int, seed: int = 0, slo_s: float | None = None,
                  start: float = 0.0) -> list[Request]:
    """Pooled-feature Poisson trace: each request carries a random
    ``(input_len, d_model)`` feature payload for the shared-forward path
    (distinct rows, so executor head probing can discriminate batched from
    reducing heads). Combine with ``token_trace`` via ``merge`` for the
    mixed pooled + generative workloads the event-loop plane serves."""
    rng = np.random.RandomState(seed)
    t, out = start, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t >= start + horizon:
            break
        out.append(Request(
            task_id, t,
            payload=rng.randn(input_len, d_model).astype("float32"),
            slo=SLO(slo_s)))
    return out


def burst_trace(task_id: str, base_rps: float, burst_rps: float,
                burst_start: float, burst_len: float, horizon: float,
                *, seed: int = 0, slo_s: float | None = None) -> list[Request]:
    """Steady -> spike -> steady (noisy-neighbor pattern, paper Fig. 13)."""
    a = poisson_trace(task_id, base_rps, burst_start, seed=seed, slo_s=slo_s)
    b = poisson_trace(task_id, burst_rps, burst_len, seed=seed + 1,
                      slo_s=slo_s, start=burst_start)
    c = poisson_trace(task_id, base_rps, horizon - burst_start - burst_len,
                      seed=seed + 2, slo_s=slo_s, start=burst_start + burst_len)
    return a + b + c


# Azure-Functions-like load bands, requests-per-MINUTE (paper §7.1.3)
LOAD_BANDS = {"low": (6, 60), "moderate": (60, 600), "high": (600, 1800)}


def azure_like_tasks(n_tasks: int, band: str, horizon: float, *, seed: int = 0,
                     slo_s: float | None = None):
    """Sample per-task mean rates log-uniformly within the band; each task is
    bursty: on/off periods with 3x rate multiplier when 'hot'."""
    lo, hi = LOAD_BANDS[band]
    rng = np.random.RandomState(seed)
    traces = {}
    for i in range(n_tasks):
        tid = f"task{i}"
        rpm = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        rps = rpm / 60.0
        reqs, t = [], 0.0
        hot = rng.rand() < 0.3
        while t < horizon:
            period = rng.exponential(20.0)
            rate = rps * (3.0 if hot else 0.7)
            reqs += poisson_trace(tid, max(rate, 1e-3), min(period, horizon - t),
                                  seed=rng.randint(1 << 30), slo_s=slo_s, start=t)
            t += period
            hot = not hot
        traces[tid] = sorted(reqs, key=lambda r: r.arrival)
    return traces


def merge(traces) -> list[Request]:
    out = [r for t in traces for r in (t if isinstance(t, list) else traces[t])]
    return sorted(out, key=lambda r: r.arrival)
