from repro.serving import faults, loadgen, metrics, simulator  # noqa
