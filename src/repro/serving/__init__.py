from repro.serving import loadgen, metrics, simulator  # noqa
