"""Discrete-event cluster simulator (the paper's testbed, virtualized).

Executes the REAL scheduler code (``repro.core.bfq``) under virtual time; only
the accelerator is modeled, via per-backbone profiles (l(b) curves calibrated
on the real plane or taken from Table-3-style constants).

Deployment modes map to the paper's baselines through two knobs:
  * instance placement — shared backbone (FMplex/S-*) vs replica-per-task
    (ST/BE/SP);
  * GPU sharing discipline — "exclusive" (one instance), "ps" (best-effort
    processor sharing, i.e. CUDA time-slicing), "partition" (static spatial
    partition: each instance runs at a fixed fraction — the TPU analogue of
    TPC masking).

Supports mid-run speed changes (straggler injection) and GPU failure events
(fault-tolerance benches); the Controller reacts by rebinding vFM snapshots.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional

from repro.core.bfq import SCHEDULERS
from repro.core.profile import FMProfile
from repro.core.request import Batch, Request
from repro.core.vfm import VFM, TaskExtensions

_seq = itertools.count()


@dataclasses.dataclass
class Execution:
    batch: Batch
    remaining: float           # dedicated-GPU seconds of work left
    total: float


class SimInstance:
    """One deployed physical backbone on a GPU."""

    def __init__(self, fm_id: str, profile: FMProfile, scheduler: str = "bfq"):
        self.fm_id = fm_id
        self.profile = profile
        self.scheduler = SCHEDULERS[scheduler](profile)
        self.vfms: dict[str, VFM] = {}
        self.exec: Optional[Execution] = None
        self.loading_until: float = 0.0    # cold-load completion time

    def bind(self, task_id: str, *, weight=1.0, slo=None, adapter_id=None):
        v = VFM(task_id, weight=weight, slo=slo,
                extensions=TaskExtensions(adapter_id=adapter_id),
                backbone=self.fm_id)
        v.bound_fm = self.fm_id
        self.vfms[task_id] = v
        return v

    @property
    def busy(self) -> bool:
        return self.exec is not None

    def memory(self) -> int:
        return self.profile.memory_bytes + self.profile.instance_overhead_bytes \
            + len(self.vfms) * self.profile.task_memory_bytes


class SimGPU:
    def __init__(self, gpu_id: str, mem_bytes: float = 16e9,
                 sharing: str = "exclusive", speed: float = 1.0):
        self.gpu_id = gpu_id
        self.mem_bytes = mem_bytes
        self.sharing = sharing          # exclusive | ps | partition
        self.speed = speed
        self.alive = True
        self.instances: list[SimInstance] = []

    def rate_for(self, inst: SimInstance) -> float:
        if not self.alive:
            return 0.0
        if self.sharing == "partition":
            return self.speed / max(len(self.instances), 1)
        if self.sharing == "ps":
            busy = sum(1 for i in self.instances if i.busy)
            return self.speed / max(busy, 1)
        return self.speed

    def mem_used(self) -> float:
        return sum(i.memory() for i in self.instances)

    def fits(self, extra_bytes: float) -> bool:
        return self.mem_used() + extra_bytes <= self.mem_bytes


class Simulator:
    def __init__(self, gpus: list[SimGPU]):
        self.gpus = gpus
        self.routing: dict[str, tuple[SimGPU, SimInstance]] = {}
        self.now = 0.0
        self.finished: list[Request] = []
        self.timed_hooks: list[tuple[float, Callable]] = []  # (t, fn(sim))

    # ---- topology ----
    def route(self, task_id: str, gpu: SimGPU, inst: SimInstance,
              frac: float = 1.0):
        """Weighted routing: a task may be replicated across deployments."""
        self.routing.setdefault(task_id, []).append((gpu, inst, frac))

    def _pick_route(self, req: Request):
        routes = self.routing[req.task_id]
        if len(routes) == 1:
            return routes[0][:2]
        total = sum(f for _, _, f in routes)
        x = (req.rid * 2654435761 % 2 ** 20) / 2 ** 20 * total   # hash spread
        acc = 0.0
        for g, i, f in routes:
            acc += f
            if x <= acc:
                return g, i
        return routes[-1][:2]

    def instance_of(self, task_id: str) -> SimInstance:
        return self.routing[task_id][0][1]

    def add_hook(self, t: float, fn: Callable):
        self.timed_hooks.append((t, fn))
        self.timed_hooks.sort(key=lambda x: x[0])

    # ---- engine ----
    def _advance(self, dt: float):
        if dt <= 0:
            return
        for g in self.gpus:
            for inst in g.instances:
                if inst.busy:
                    inst.exec.remaining -= dt * g.rate_for(inst)
        self.now += dt

    def _next_completion(self) -> float:
        t = float("inf")
        for g in self.gpus:
            for inst in g.instances:
                if inst.busy:
                    r = g.rate_for(inst)
                    if r > 0:
                        t = min(t, self.now + inst.exec.remaining / r)
        return t

    def _try_dispatch(self, inst: SimInstance):
        if inst.busy or self.now < inst.loading_until:
            return
        batch = inst.scheduler.next_batch(inst.vfms, self.now)
        if batch is None:
            return
        work = inst.scheduler.exec_time(batch)
        inst.exec = Execution(batch, work, work)

    def run(self, arrivals: list[Request], horizon: float):
        heap = [(r.arrival, next(_seq), r) for r in arrivals]
        heapq.heapify(heap)
        hooks = list(self.timed_hooks)
        while True:
            t_arr = heap[0][0] if heap else float("inf")
            t_done = self._next_completion()
            t_hook = hooks[0][0] if hooks else float("inf")
            t_next = min(t_arr, t_done, t_hook, horizon)
            if t_next >= horizon and t_done == float("inf"):
                self._advance(horizon - self.now)
                break
            self._advance(t_next - self.now)

            if t_next == t_hook and hooks:
                _, fn = hooks.pop(0)
                fn(self)
                for g in self.gpus:
                    for inst in g.instances:
                        self._try_dispatch(inst)
                continue

            # completions first (free capacity before new work at same t)
            progressed = False
            for g in self.gpus:
                for inst in g.instances:
                    if inst.busy and inst.exec.remaining <= 1e-12:
                        batch = inst.exec.batch
                        inst.exec = None
                        for r in batch.requests:
                            r.finish_time = self.now
                            v = inst.vfms.get(r.task_id)
                            if v is not None:
                                v.acct.completed += 1
                                v.acct.service_time += \
                                    inst.profile.effective_per_request(batch.size)
                        inst.scheduler.on_complete(batch, inst.vfms, self.now)
                        self.finished.extend(batch.requests)
                        self._try_dispatch(inst)
                        progressed = True
            if progressed:
                continue

            if heap and heap[0][0] <= self.now + 1e-12:
                _, _, req = heapq.heappop(heap)
                gpu, inst = self._pick_route(req)
                vfm = inst.vfms[req.task_id]
                inst.scheduler.on_arrival(vfm, req, self.now)
                self._try_dispatch(inst)
                continue

            if self.now >= horizon:
                break
        return self.finished


# ---------------- cluster builders (deployment modes) ----------------

def build_single_gpu(mode: str, tasks: list[dict], profile: FMProfile,
                     mem_bytes: float = 16e9):
    """One GPU, one backbone family, N tasks. mode: fmplex | s-be | s-stfq |
    be | sp | st. Returns (sim, ok) — ok False if the deployment OOMs."""
    sched = {"fmplex": "bfq", "s-be": "s-be", "s-stfq": "stfq"}.get(mode)
    if sched is not None:  # shared backbone: ONE instance, many vFMs
        gpu = SimGPU("g0", mem_bytes, sharing="exclusive")
        inst = SimInstance(profile.name, profile, scheduler=sched)
        gpu.instances.append(inst)
        sim = Simulator([gpu])
        ok = gpu.fits(0)
        for t in tasks:
            inst.bind(t["task_id"], weight=t.get("weight", 1.0),
                      slo=t.get("slo"), adapter_id=t.get("adapter_id"))
            sim.route(t["task_id"], gpu, inst)
        ok = ok and gpu.mem_used() <= mem_bytes
        return sim, ok
    if mode in ("be", "sp"):  # replica per task on one GPU
        gpu = SimGPU("g0", mem_bytes,
                     sharing=("ps" if mode == "be" else "partition"))
        sim = Simulator([gpu])
        for t in tasks:
            inst = SimInstance(f"{profile.name}/{t['task_id']}", profile,
                               scheduler="s-be")
            gpu.instances.append(inst)
            inst.bind(t["task_id"], weight=t.get("weight", 1.0),
                      slo=t.get("slo"), adapter_id=t.get("adapter_id"))
            sim.route(t["task_id"], gpu, inst)
        return sim, gpu.mem_used() <= mem_bytes
    if mode == "st":          # dedicated GPU per task
        gpus, sim = [], None
        gpus = [SimGPU(f"g{i}", mem_bytes) for i in range(len(tasks))]
        sim = Simulator(gpus)
        for g, t in zip(gpus, tasks):
            inst = SimInstance(f"{profile.name}/{t['task_id']}", profile,
                               scheduler="s-be")
            g.instances.append(inst)
            inst.bind(t["task_id"], weight=t.get("weight", 1.0),
                      slo=t.get("slo"), adapter_id=t.get("adapter_id"))
            sim.route(t["task_id"], g, inst)
        return sim, True
    raise ValueError(mode)
