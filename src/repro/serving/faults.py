"""Chaos-injection harness for the serving plane (robustness counterpart of
``distributed.fault``, which owns the TRAINING loop's failure machinery —
``InjectedFailure`` is shared so both planes raise the same marker type).

Deterministic fault injection against a live ``ServeLoop``: each
``ChaosEvent`` arms a fault at a fixed offset into ``ServeLoop.run`` (driven
from the loop's ``on_tick`` hook, so injection lands between scheduling
decisions — never mid-jit) and optionally restores it after a fixed duration.
Determinism matters more than realism here: the chaos bench asserts EXACT
token parity for clean streams against a fault-free run, which requires the
fault schedule to be a pure function of the trace clock.

Faults and the isolation layer each one exercises:

  * ``NaNAdapterFault``    — poisons one task's LoRA adapter with NaNs in the
    FM's ``AdapterStore`` (stack rebuilt, same shapes: no new jit keys). The
    engine's in-graph finite-logits flag quarantines ONLY that task's
    streams; co-batched streams keep exact token parity.
  * ``RaisingHeadFault``   — swaps one task's decoder head for one that
    raises ``InjectedFailure``. The executor's per-task isolation fails only
    that task's rows (``HeadFailure`` → ``status == "head_failed"``) after
    bounded retries; restore puts the original head back and the executor
    re-probes it from scratch.
  * ``PagePressureFault``  — steals a fraction of the paged KV arena's free
    pages, forcing deferrals/preemptions through the memory-aware admission
    gate; restore returns them. Never wedges: stolen pages only shrink the
    FREE list, not ``total_pages``, so viability checks still hold.
  * ``StallFault``         — replaces ``step_chunk`` with a no-op for the
    duration: the engine stops making progress while work stays queued,
    which is exactly the signature the loop watchdog fires on.
  * ``DeviceResetFault``   — kills the device arena mid-trace: snapshots the
    loop's state, SCRAMBLES every pool leaf of the old engine (proving the
    restore path reads nothing from dead device state), then drives
    ``ServeLoop.checkpoint_restart``'s restore half. Every restored page is
    sha256-verified; surviving streams resume token-for-token.
  * ``SpillCorruptionFault`` — flips bits in host-RAM spill arena entries.
    The engine detects the corruption at restore time (digest mismatch →
    ``digest_failures``), drops the entry and falls back to lossless
    re-prefill — corrupted spill can never surface as wrong tokens.

``ChaosInjector`` is the scheduler: pass ``inj.on_tick`` to
``ServeLoop.run(on_tick=...)``; call ``restore_all`` after the run so
one-shot experiments cannot leak a poisoned store into later runs."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.distributed.fault import InjectedFailure


class Fault:
    """One injectable fault: ``inject`` arms it against the loop's serving
    state, ``restore`` undoes it completely (same object identity where the
    executor caches by identity, so restored components re-probe)."""

    name = "fault"

    def inject(self, loop):       # pragma: no cover - interface
        raise NotImplementedError

    def restore(self, loop):
        pass


class NaNAdapterFault(Fault):
    def __init__(self, adapter_id: str):
        self.adapter_id = adapter_id
        self.name = f"nan_adapter:{adapter_id}"
        self._orig = None

    def inject(self, loop):
        import jax
        import jax.numpy as jnp
        store = loop.srv.fms[loop.fm_id].adapters
        if self.adapter_id not in store.ids:
            return
        i = store.ids.index(self.adapter_id)
        self._orig = store._trees[i]
        store._trees[i] = jax.tree.map(
            lambda x: jnp.full_like(x, jnp.nan), self._orig)
        # drop the incremental stack cache: same shapes (no new jit keys),
        # next stacked() rebuild carries the poison
        store._stacked = None

    def restore(self, loop):
        if self._orig is None:
            return
        store = loop.srv.fms[loop.fm_id].adapters
        if self.adapter_id in store.ids:
            store._trees[store.ids.index(self.adapter_id)] = self._orig
            store._stacked = None
        self._orig = None


class RaisingHeadFault(Fault):
    def __init__(self, task_id: str):
        self.task_id = task_id
        self.name = f"raising_head:{task_id}"
        self._orig = None

    def inject(self, loop):
        fm = loop.srv.fms[loop.fm_id]
        if self.task_id not in fm.heads:
            return
        self._orig = fm.heads[self.task_id]
        tid = self.task_id

        def raising_head(x):
            raise InjectedFailure(f"injected head crash for task {tid}")

        fm.heads[tid] = raising_head

    def restore(self, loop):
        if self._orig is None:
            return
        fm = loop.srv.fms[loop.fm_id]
        if self.task_id in fm.heads:
            fm.heads[self.task_id] = self._orig
        self._orig = None


class PagePressureFault(Fault):
    def __init__(self, frac: float = 0.5):
        self.frac = float(frac)
        self.name = f"page_pressure:{frac}"
        self._stolen: list[int] = []

    def inject(self, loop):
        eng = loop._engine()
        if eng is None or not getattr(eng, "paged", False):
            return
        n = int(len(eng._free_pages) * self.frac)
        self._stolen = [eng._free_pages.pop() for _ in range(n)]

    def restore(self, loop):
        if not self._stolen:
            return
        eng = loop._engine()
        if eng is not None:
            eng._free_pages.extend(self._stolen)
        self._stolen = []


class StallFault(Fault):
    name = "stall"

    def __init__(self):
        self._orig = None

    def inject(self, loop):
        eng = loop._engine()
        if eng is None or self._orig is not None:
            return
        self._orig = eng.step_chunk
        eng.step_chunk = lambda: []     # work queued, zero progress

    def restore(self, loop):
        if self._orig is None:
            return
        eng = loop._engine()
        if eng is not None:
            eng.step_chunk = self._orig
        self._orig = None


class DeviceResetFault(Fault):
    """Simulated accelerator reset: the durability layer's headline fault.

    Inject = quiesce + snapshot the loop's full serving state, scramble the
    OLD engine's device arena (int8 codes to a constant, scales/page tables
    to zero — any restore path that still read the dead device state would
    produce garbage tokens and fail the bench's parity assert), drop the
    engine from the server and restore from the snapshot. The restored
    engine's pages are rebuilt from the snapshot's host copies, each one
    verified against its sha256 digest. Zero requests are lost: live slots,
    pending/preempted entries and scheduler tags all ride the snapshot."""

    name = "device_reset"

    def __init__(self):
        self.resets = 0

    def inject(self, loop):
        import jax.numpy as jnp
        eng = loop._engine()
        if eng is None or not getattr(eng, "paged", False):
            return
        state = loop.snapshot_state()
        old = loop.srv.engines.pop(loop.fm_id)
        for sub in old.pool:
            if isinstance(sub, dict) and "page_table" in sub:
                sub["k"] = jnp.full_like(sub["k"], 77)
                sub["v"] = jnp.full_like(sub["v"], -77)
                sub["k_scale"] = jnp.zeros_like(sub["k_scale"])
                sub["v_scale"] = jnp.zeros_like(sub["v_scale"])
                sub["page_table"] = jnp.zeros_like(sub["page_table"])
        loop.restore_state(state, reuse_jits_from=old)
        loop.failures["resets_survived"] += 1
        for r in loop._inflight.values():
            r.resets_survived += 1
        self.resets += 1


class SpillCorruptionFault(Fault):
    """Flip bits in a fraction of the host spill arena's entries (stream and
    prefix alike). Deterministic: entries are corrupted in insertion order.
    The engine's digest verification turns each corrupted entry into a
    counted miss + recompute fallback — never into wrong tokens."""

    def __init__(self, frac: float = 1.0):
        self.frac = float(frac)
        self.name = f"spill_corruption:{frac}"
        self.corrupted = 0

    def inject(self, loop):
        eng = loop._engine()
        spill = getattr(eng, "spill", None) if eng is not None else None
        if spill is None or not len(spill):
            return
        keys = list(spill._entries)
        for key in keys[:max(1, int(len(keys) * self.frac))]:
            d = spill._entries[key].blob[0]
            name = next(iter(d))
            # spilled arrays can be non-contiguous device_get slices, where
            # an in-place view XOR would silently mutate a reshape COPY —
            # corrupt a contiguous copy and swap it in
            a = np.ascontiguousarray(d[name])
            a.view(np.uint8).reshape(-1)[::7] ^= 0xFF
            d[name] = a
            self.corrupted += 1


@dataclasses.dataclass
class ChaosEvent:
    """Arm ``fault`` ``at`` seconds into the run; restore it after
    ``duration`` seconds (None = never, the fault stays for the run —
    ``restore_all`` still cleans it up afterwards)."""
    at: float
    fault: Fault
    duration: Optional[float] = None
    armed: bool = False
    restored: bool = False


class ChaosInjector:
    """Deterministic fault scheduler driven by ``ServeLoop.run``'s
    ``on_tick(loop, rel)`` hook. ``log`` records (rel, fault name, action)
    for every transition — the chaos bench embeds it in its report."""

    def __init__(self, events):
        self.events = sorted(events, key=lambda e: e.at)
        self.log: list[tuple[float, str, str]] = []

    def on_tick(self, loop, rel: float):
        for ev in self.events:
            if not ev.armed and rel >= ev.at:
                ev.fault.inject(loop)
                ev.armed = True
                self.log.append((round(rel, 4), ev.fault.name, "inject"))
            if ev.armed and not ev.restored and ev.duration is not None \
                    and rel >= ev.at + ev.duration:
                ev.fault.restore(loop)
                ev.restored = True
                self.log.append((round(rel, 4), ev.fault.name, "restore"))

    def restore_all(self, loop):
        """Undo every still-armed fault (end-of-run cleanup — a poisoned
        adapter must not leak into the next experiment)."""
        for ev in self.events:
            if ev.armed and not ev.restored:
                ev.fault.restore(loop)
                ev.restored = True
                self.log.append((-1.0, ev.fault.name, "restore_all"))
