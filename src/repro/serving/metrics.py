"""Latency / throughput / fairness metrics (paper §7.1.4)."""
from __future__ import annotations

import numpy as np


def percentile(xs, p):
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, float), p))


def latency_stats(requests) -> dict:
    lats = [r.latency for r in requests if r.latency is not None]
    if not lats:
        return {"n": 0}
    return {
        "n": len(lats),
        "mean_ms": 1e3 * float(np.mean(lats)),
        "p50_ms": 1e3 * percentile(lats, 50),
        "p99_ms": 1e3 * percentile(lats, 99),
        "max_ms": 1e3 * float(np.max(lats)),
    }


def speculation_stats(engine) -> dict:
    """Speculative-decoding gauges of a ``DecodeEngine``: draft volume,
    accept rate, committed tokens per dispatch (the multi-token-step payoff)
    and the adaptive plane's demotion count, plus cumulative per-task accept
    rates — the signal for spotting a co-batched task whose output never
    matches its own history (it decodes fine, it just never speculates
    usefully)."""
    proposed = int(getattr(engine, "draft_proposed", 0))
    accepted = int(getattr(engine, "draft_accepted", 0))
    disp = int(getattr(engine, "spec_dispatches", 0))
    out = {
        "spec_k": int(getattr(engine, "spec_k", 0)),
        "draft_proposed": proposed,
        "draft_accepted": accepted,
        "accept_rate": round(accepted / proposed, 4) if proposed else 0.0,
        "spec_dispatches": disp,
        "spec_fallbacks": int(getattr(engine, "spec_fallbacks", 0)),
        "tokens_per_dispatch": round(
            int(getattr(engine, "spec_commits", 0)) / disp, 3)
        if disp else 0.0,
    }
    rates = getattr(engine, "spec_task_accept_rates", None)
    if callable(rates):
        out["task_accept_rates"] = {t: round(v, 4)
                                    for t, v in sorted(rates().items())}
    return out


def decode_stats(requests, *, engine=None) -> dict:
    """Token-level serving metrics for generative (prefill+decode) requests:
    TTFT (arrival -> first generated token), TPOT (per-token decode interval
    after the first token), and aggregate generated-token throughput.
    Latency/throughput aggregates cover SUCCESSFUL requests only — a shed or
    quarantined stream's zero-token "completion" would otherwise deflate
    TTFT and inflate throughput; failed terminations are counted separately
    (``n_failed``) and goodput (tokens of requests that finished ok WITHIN
    their deadline, per second) reports what the SLO-carrying client actually
    received. ``engine`` (a speculative ``DecodeEngine``) adds the
    ``speculation`` section (``speculation_stats``)."""
    done = [r for r in requests
            if r.finish_time is not None and r.max_new_tokens > 0]
    ok = [r for r in done if getattr(r, "status", "ok") == "ok"]
    spec = speculation_stats(engine) \
        if engine is not None and getattr(engine, "spec_k", 0) > 0 else None
    if not ok:
        out = {"n": 0, "n_failed": len(done)}
        if spec is not None:
            out["speculation"] = spec
        return out
    ttft = [r.first_token_time - r.arrival for r in ok
            if r.first_token_time is not None]
    tpot = []
    total_tokens = 0
    good_tokens = 0
    for r in ok:
        n = len(r.result) if r.result is not None else r.max_new_tokens
        total_tokens += n
        if r.met_deadline():
            good_tokens += n
        if r.first_token_time is not None and n > 1:
            tpot.append((r.finish_time - r.first_token_time) / (n - 1))
    span = (max(r.finish_time for r in ok)
            - min(r.arrival for r in ok)) or 1e-9
    out = {
        "n": len(ok),
        "n_failed": len(done) - len(ok),
        "tokens_out": total_tokens,
        "tokens_per_s": total_tokens / span,
        "goodput_tokens_per_s": good_tokens / span,
        "ttft_p50_ms": 1e3 * percentile(ttft, 50),
        "ttft_p99_ms": 1e3 * percentile(ttft, 99),
        "tpot_p50_ms": 1e3 * percentile(tpot, 50),
        "tpot_p99_ms": 1e3 * percentile(tpot, 99),
    }
    if spec is not None:
        out["speculation"] = spec
    return out


def page_gauges(engine) -> dict:
    """Free/used KV-page gauges of a paged decode pool (zeros for dense) —
    the numbers an operator watches to size ``total_pages``: free and used
    counts, deferred/preempted admissions, current occupancy, and the
    prefix-sharing dedup state (physical pages mapped by several streams,
    pages saved right now, logical mappings, cumulative prefix hits)."""
    out = {
        "paged": bool(getattr(engine, "paged", False)),
        "free_pages": engine.free_page_count(),
        "used_pages": engine.used_page_count(),
        "total_pages": getattr(engine, "total_pages", 0),
        "occupancy": round(engine.page_occupancy(), 4),
        "deferrals": getattr(engine, "deferrals", 0),
        "preemptions": getattr(engine, "preemptions", 0),
        "shared_pages": engine.shared_page_count(),
        "dedup_saved_pages": engine.dedup_saved_pages(),
        "logical_pages": engine.logical_page_count(),
        "prefix_hits": getattr(engine, "prefix_hits", 0),
        # chunked shared-prefix prefill: prompt tokens the engine actually
        # prefilled vs tokens it skipped by mapping already-resident pages
        "tail_tokens_computed": getattr(engine, "tail_tokens_computed", 0),
        "prefill_tokens_saved": getattr(engine, "prefill_tokens_saved", 0),
        "hol_bypasses": getattr(engine, "hol_bypasses", 0),
        "scale_refreshes": getattr(engine, "scale_refreshes", 0),
        "spilled_pages": getattr(engine, "spilled_pages", 0),
        "restored_pages": getattr(engine, "restored_pages", 0),
        "spill_bytes_in_use": getattr(
            getattr(engine, "spill", None), "bytes_in_use", 0),
        "spill_entries": len(getattr(engine, "spill", None) or ()),
    }
    sp = getattr(engine, "state_pool", None)
    if sp is not None:
        # hybrid / enc-dec stacks: fixed-size state-slot occupancy beside
        # the page gauges (in use, peak, deferrals on slot pressure)
        out.update(sp.gauges())
    return out


def failure_counters(requests=(), *, loop=None, engine=None,
                     executor=None) -> dict:
    """Failure-plane counters: terminal statuses tallied over ``requests``
    plus the serving components' own tallies — the loop's watchdog trips and
    wedge recoveries, the engine's quarantine/deadline/cancel counts, the
    executor's head failures and retry attempts. Everything here is a count
    of a FAULT HANDLED gracefully; a crash would have produced none of them."""
    from repro.core.request import FAILURE_STATUSES
    out = {s: 0 for s in FAILURE_STATUSES}
    for r in requests:
        s = getattr(r, "status", "ok")
        if s != "ok":
            out[s] = out.get(s, 0) + 1
    if loop is not None:
        out["watchdog_trips"] = int(loop.failures.get("watchdog_trips", 0))
        out["wedge_recoveries"] = int(
            loop.failures.get("wedge_recoveries", 0))
        out["resets_survived"] = int(
            loop.failures.get("resets_survived", 0))
    if engine is not None:
        out["engine_quarantines"] = int(getattr(engine, "quarantines", 0))
        out["engine_deadline_cancels"] = int(
            getattr(engine, "deadline_cancels", 0))
        out["engine_deadline_sheds"] = int(
            getattr(engine, "deadline_sheds", 0))
        out["engine_stranded_rejections"] = int(
            getattr(engine, "stranded_rejections", 0))
        out["engine_cancels"] = int(getattr(engine, "cancels", 0))
        # durability plane: host-spill traffic and the digest-verification
        # contract's violation count (corrupted spill/snapshot pages dropped)
        out["spilled_pages"] = int(getattr(engine, "spilled_pages", 0))
        out["restored_pages"] = int(getattr(engine, "restored_pages", 0))
        out["digest_failures"] = int(getattr(engine, "digest_failures", 0))
        out["spill_resumes"] = int(getattr(engine, "spill_resumes", 0))
        out["deadline_clamps"] = int(getattr(engine, "deadline_clamps", 0))
        # speculative plane: dispatches demoted to the plain decode fn by
        # the accept-rate EMA (speculation disabled, not a fault per se —
        # but a run that is ALL fallbacks is a misconfigured spec_k)
        out["spec_fallbacks"] = int(getattr(engine, "spec_fallbacks", 0))
    if executor is not None:
        out["head_failures"] = int(
            sum(getattr(executor, "head_failures", {}).values()))
        out["head_retries"] = int(getattr(executor, "retries", 0))
    return out


def mixed_stats(requests, page_samples=None, shared_samples=None,
                failures=None, ttft_split=None, engine=None) -> dict:
    """Split per-plane report for mixed pooled + generative serving (the
    event-loop plane): request-level latency for the pooled side, token-level
    TTFT/TPOT/throughput for the generative side. ``page_samples`` (the
    per-decode-tick KV-page occupancy fractions a ``ServeLoop`` collects on a
    paged pool) adds an occupancy p50/p95/max section — how full the arena
    actually ran, the signal for sizing ``total_pages``. ``shared_samples``
    (per-decode-tick dedup fractions: pages saved by prefix sharing over
    logical page mappings) adds a sharing section — how much effective
    capacity COW prefix sharing is buying on this workload. ``failures`` (a
    ``failure_counters`` dict) adds the failure-plane section.
    ``ttft_split`` ({"hit": [...], "miss": [...]} TTFT seconds, the
    ``ServeLoop.ttft_hit_samples``/``ttft_miss_samples`` series) adds a
    prefix-hit vs miss TTFT section — what chunked shared-prefix prefill is
    buying sharer joins on this workload."""
    pooled = [r for r in requests if r.max_new_tokens <= 0]
    gen = [r for r in requests if r.max_new_tokens > 0]
    out = {"pooled": latency_stats(pooled),
           "decode": decode_stats(gen, engine=engine)}
    sp = getattr(engine, "state_pool", None) if engine is not None else None
    if sp is not None:
        out["state_slots"] = sp.gauges()
    if failures:
        out["failures"] = failures
    if ttft_split and (ttft_split.get("hit") or ttft_split.get("miss")):
        hit = ttft_split.get("hit") or []
        miss = ttft_split.get("miss") or []
        out["ttft_split"] = {
            "prefix_hit_n": len(hit),
            "prefix_miss_n": len(miss),
            "prefix_hit_p50_ms": 1e3 * percentile(hit, 50),
            "prefix_miss_p50_ms": 1e3 * percentile(miss, 50),
            "prefix_hit_p99_ms": 1e3 * percentile(hit, 99),
            "prefix_miss_p99_ms": 1e3 * percentile(miss, 99),
        }
    if page_samples:
        out["kv_pages"] = {
            "samples": len(page_samples),
            "occupancy_p50": round(percentile(page_samples, 50), 4),
            "occupancy_p95": round(percentile(page_samples, 95), 4),
            "occupancy_max": round(float(np.max(page_samples)), 4),
        }
    if shared_samples:
        out["kv_sharing"] = {
            "samples": len(shared_samples),
            "dedup_frac_p50": round(percentile(shared_samples, 50), 4),
            "dedup_frac_p95": round(percentile(shared_samples, 95), 4),
            "dedup_frac_max": round(float(np.max(shared_samples)), 4),
        }
    return out


def jain_fairness(shares: dict[str, float], weights: dict[str, float]) -> float:
    """Jain index over weight-normalized service shares (Elliott [16] style).

    1.0 = every task received service exactly proportional to its weight.
    Tasks with zero share count against fairness.
    """
    xs = np.array([shares.get(t, 0.0) / max(weights[t], 1e-12) for t in weights],
                  float)
    if xs.sum() <= 0:
        return 1.0
    n = len(xs)
    return float(xs.sum() ** 2 / (n * (xs ** 2).sum() + 1e-30))


def throughput_timeline(requests, window: float, horizon: float):
    """Per-task completions/s in consecutive windows -> {task: [rps...]}."""
    import collections
    out = collections.defaultdict(lambda: [0] * max(int(horizon / window), 1))
    for r in requests:
        if r.finish_time is None:
            continue
        w = min(int(r.finish_time / window), len(out[r.task_id]) - 1) \
            if out[r.task_id] else 0
        out[r.task_id][w] += 1
    return {t: [c / window for c in cs] for t, cs in out.items()}


def fairness_timeline(requests, weights: dict[str, float], window: float,
                      horizon: float):
    thr = throughput_timeline(requests, window, horizon)
    nwin = max(int(horizon / window), 1)
    out = []
    for w in range(nwin):
        shares = {t: (thr.get(t, [0] * nwin)[w] if w < len(thr.get(t, [])) else 0)
                  for t in weights}
        # only judge fairness when there is demand in the window
        if sum(shares.values()) > 0:
            out.append(jain_fairness(shares, weights))
    return out
