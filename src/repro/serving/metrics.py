"""Latency / throughput / fairness metrics (paper §7.1.4)."""
from __future__ import annotations

import numpy as np


def percentile(xs, p):
    if not len(xs):
        return float("nan")
    return float(np.percentile(np.asarray(xs, float), p))


def latency_stats(requests) -> dict:
    lats = [r.latency for r in requests if r.latency is not None]
    if not lats:
        return {"n": 0}
    return {
        "n": len(lats),
        "mean_ms": 1e3 * float(np.mean(lats)),
        "p50_ms": 1e3 * percentile(lats, 50),
        "p99_ms": 1e3 * percentile(lats, 99),
        "max_ms": 1e3 * float(np.max(lats)),
    }


def jain_fairness(shares: dict[str, float], weights: dict[str, float]) -> float:
    """Jain index over weight-normalized service shares (Elliott [16] style).

    1.0 = every task received service exactly proportional to its weight.
    Tasks with zero share count against fairness.
    """
    xs = np.array([shares.get(t, 0.0) / max(weights[t], 1e-12) for t in weights],
                  float)
    if xs.sum() <= 0:
        return 1.0
    n = len(xs)
    return float(xs.sum() ** 2 / (n * (xs ** 2).sum() + 1e-30))


def throughput_timeline(requests, window: float, horizon: float):
    """Per-task completions/s in consecutive windows -> {task: [rps...]}."""
    import collections
    out = collections.defaultdict(lambda: [0] * max(int(horizon / window), 1))
    for r in requests:
        if r.finish_time is None:
            continue
        w = min(int(r.finish_time / window), len(out[r.task_id]) - 1) \
            if out[r.task_id] else 0
        out[r.task_id][w] += 1
    return {t: [c / window for c in cs] for t, cs in out.items()}


def fairness_timeline(requests, weights: dict[str, float], window: float,
                      horizon: float):
    thr = throughput_timeline(requests, window, horizon)
    nwin = max(int(horizon / window), 1)
    out = []
    for w in range(nwin):
        shares = {t: (thr.get(t, [0] * nwin)[w] if w < len(thr.get(t, [])) else 0)
                  for t in weights}
        # only judge fairness when there is demand in the window
        if sum(shares.values()) > 0:
            out.append(jain_fairness(shares, weights))
    return out
