"""Version shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params_cls():
    """jax renamed TPUCompilerParams -> CompilerParams across releases; return
    whichever this jax provides (raising clearly if the API moved again)."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; update repro.kernels.compat for this jax")
    return cls


COMPILER_PARAMS = compiler_params_cls()
