"""Backend-dispatching jit wrappers for the Pallas kernels.

``backend="auto"`` uses the Pallas TPU kernels on TPU and falls back to the
pure-jnp oracles elsewhere (this container is CPU-only; kernels are validated
with ``interpret=True``). Layout adapters translate between the model-internal
(B, S, H, hd) convention and the head-major kernel layout.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention_int8 import \
    decode_attention_int8 as _decode_int8_pallas
from repro.kernels.decode_attention_int8 import quantize_kv as _quantize_kv
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.paged_decode_attention import \
    paged_decode_attention as _paged_decode_pallas
from repro.kernels.segmented_lora import segmented_lora as _sgmv_pallas

# module-level default, overridable per call
BACKEND = "auto"


def _resolve(backend: Optional[str]) -> str:
    b = backend or BACKEND
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return b


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    backend: Optional[str] = None, interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    b = _resolve(backend)
    if b == "pallas":
        o = _flash_pallas(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal, window=window,
                          interpret=interpret)
        return o.transpose(0, 2, 1, 3)
    from repro.models.attention import flash_attention as jnp_flash
    return jnp_flash(q, k, v, causal=causal, window=window)


def decode_attention(q, k_cache, v_cache, lengths, *, window: Optional[int] = None,
                     backend: Optional[str] = None, interpret: bool = False):
    """q: (B, H, hd); caches: (B, S, KV, hd); lengths: (B,) -> (B, H, hd)."""
    b = _resolve(backend)
    if b == "pallas":
        return _decode_pallas(q, k_cache.transpose(0, 2, 1, 3),
                              v_cache.transpose(0, 2, 1, 3), lengths,
                              window=window, interpret=interpret)
    from repro.models.attention import decode_attention as jnp_decode
    return jnp_decode(q, k_cache, v_cache, lengths, window=window)


def quantize_kv(k, v):
    """Symmetric per-(batch, kv-head) int8 KV quantization, model layout.

    k, v: (B, S, KV, hd) float -> (k_q, v_q (B, S, KV, hd) int8,
    k_scale, v_scale (B, KV) f32). Thin layout adapter over
    ``kernels.decode_attention_int8.quantize_kv`` (head-major)."""
    kq, vq, ks, vs = _quantize_kv(k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3))
    return kq.transpose(0, 2, 1, 3), vq.transpose(0, 2, 1, 3), ks, vs


def decode_attention_int8(q, k_q, v_q, k_scale, v_scale, lengths, *,
                          window: Optional[int] = None,
                          backend: Optional[str] = None,
                          interpret: bool = False):
    """int8-KV decode attention, model layout.

    q: (B, H, hd); k_q/v_q: (B, S, KV, hd) int8; k_scale/v_scale: (B, KV);
    lengths: (B,) -> (B, H, hd). HBM only ever streams int8 on the Pallas
    path; the CPU oracle dequantizes then reuses the f32 decode reference."""
    b = _resolve(backend)
    kh = k_q.transpose(0, 2, 1, 3)
    vh = v_q.transpose(0, 2, 1, 3)
    if b == "pallas":
        return _decode_int8_pallas(q, kh, vh, k_scale, v_scale, lengths,
                                   window=window, interpret=interpret)
    return ref.decode_attention_int8_ref(q, kh, vh, k_scale, v_scale, lengths,
                                         window=window)


def paged_decode_attention(q, k_pages, v_pages, k_scale, v_scale, page_table,
                           lengths, *, window: Optional[int] = None,
                           backend: Optional[str] = None,
                           interpret: bool = False):
    """Paged int8-KV decode attention, model layout.

    q: (B, H, hd); k_pages/v_pages: (num_pages, ps, KV, hd) int8 arena;
    k_scale/v_scale: (num_pages, KV) per-page scales; page_table:
    (B, max_pages) int32 — rows of DIFFERENT streams may reference the same
    physical page (copy-on-write prefix sharing maps shared prompt pages
    into several tables; the gather is read-only, so no kernel change);
    lengths: (B,) -> (B, H, hd). The Pallas path gathers pages via the
    scalar-prefetched table inside the kernel grid; the CPU oracle gathers
    with jnp then reuses the f32 decode reference."""
    b = _resolve(backend)
    if b == "pallas":
        kh = k_pages.transpose(0, 2, 1, 3)      # (P, KV, ps, hd) head-major
        vh = v_pages.transpose(0, 2, 1, 3)
        return _paged_decode_pallas(q, kh, vh, k_scale, v_scale, page_table,
                                    lengths, window=window,
                                    interpret=interpret)
    # XLA path: gather from the model-layout arena FIRST (the gathered
    # (B, MP, ps, KV, hd) block is per-request-sized), dequant with the
    # per-page scales, then transpose only the gathered block into the
    # head-major layout the f32 decode reference wants — never the whole
    # arena. This keeps the per-step cost over the dense int8 path to one
    # gather + one small transpose (~10% at the serving shapes, see
    # BENCH_serving.json#paged.step_parity).
    B, MP = page_table.shape
    _, ps, KV, hd = k_pages.shape

    def gathered(pages, scale):
        g = pages[page_table].astype(jnp.float32)   # (B, MP, ps, KV, hd)
        g = g * scale[page_table][:, :, None, :, None]
        return g.transpose(0, 3, 1, 2, 4).reshape(B, KV, MP * ps, hd)

    return ref.decode_attention_ref(q, gathered(k_pages, k_scale),
                                    gathered(v_pages, v_scale), lengths,
                                    window=window)


def paged_verify_attention(q, k_pages, v_pages, k_scale, v_scale, page_table,
                           base_len, *, window: Optional[int] = None,
                           backend: Optional[str] = None,
                           interpret: bool = False):
    """Multi-position paged attention for the speculative verify window.

    q: (B, T, H, hd) — the T = k+1 window positions' queries; window position
    j sits at absolute position ``base_len + j`` and therefore attends
    ``base_len + j + 1`` keys. All T positions' K/V must already be written
    into the arena: codes at positions a query must not see are gathered,
    dequantized and then MASKED out by the per-position length — exactly how
    the single-token path treats a fresh page's garbage tail — so position j
    reads bit-identically to a sequential decode step at length
    ``base_len + j + 1``. Returns (B, T, H, hd).

    The XLA path pays the per-request KV gather ONCE and shares it across
    all T window positions — at serving context lengths the gather
    dominates a decode step, so a verify window costs close to one step
    instead of T (this is what buys the speculative plane its speedup; see
    BENCH_serving.json#spec). Per-position masking reproduces the
    single-token math: position j's score row masks keys at or past
    ``base_len + j + 1`` with the same NEG_INF + softmax treatment the
    decode reference uses, so only matmul batching (an invariance the
    chunked-prefill plane already relies on) separates it from T unrolled
    single-token calls. The Pallas backend falls back to T unrolled
    single-token kernel calls — correct everywhere, fused later."""
    T = q.shape[1]
    b = _resolve(backend)
    if b == "pallas":
        outs = [paged_decode_attention(q[:, j], k_pages, v_pages, k_scale,
                                       v_scale, page_table, base_len + j + 1,
                                       window=window, backend=backend,
                                       interpret=interpret)
                for j in range(T)]
        return jnp.stack(outs, axis=1)
    B, MP = page_table.shape
    _, ps, KV, hd = k_pages.shape
    H = q.shape[2]
    G = H // KV
    S = MP * ps

    def gathered(pages, scale):
        g = pages[page_table].astype(jnp.float32)   # (B, MP, ps, KV, hd)
        g = g * scale[page_table][:, :, None, :, None]
        return g.transpose(0, 3, 1, 2, 4).reshape(B, KV, S, hd)

    kf = gathered(k_pages, k_scale)
    vf = gathered(v_pages, v_scale)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgd,bksd->bkgts", qf, kf) * scale
    lens = base_len[:, None] + 1 + jnp.arange(T)[None]        # (B, T)
    pos = jnp.arange(S)
    mask = pos[None, None] < lens[..., None]                  # (B, T, S)
    if window is not None:
        mask &= pos[None, None] >= (lens[..., None] - window)
    s = jnp.where(mask[:, None, None], s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->btkgd", p, vf)
    return o.reshape(B, T, H, hd).astype(q.dtype)


def gather_prefix_kv(k_pages, v_pages, k_scale, v_scale, page_table):
    """Dequantized prefix K/V gather, model layout (chunked prefill).

    k_pages/v_pages: (num_pages, ps, KV, hd) int8 arena; k_scale/v_scale:
    (num_pages, KV) per-page scales; page_table: (B, P) int32 prefix pages
    (positions past a row's true prefix length may point at the trash page —
    the attention mask is responsible for hiding them). Returns float32
    (k, v), each (B, P * ps, KV, hd), ready to feed
    ``models.attention.flash_attention(prefix_k=..., prefix_v=...)``.

    Pure-jnp on every backend: the gathered block is per-request-sized (a
    handful of prefix pages), so there is nothing for a Pallas kernel to win
    here — the arena is never transposed wholesale."""
    B, P = page_table.shape
    _, ps, KV, hd = k_pages.shape

    def gather(pages, scale):
        g = pages[page_table].astype(jnp.float32)    # (B, P, ps, KV, hd)
        g = g * scale[page_table][:, :, None, :, None]
        return g.reshape(B, P * ps, KV, hd)

    return gather(k_pages, k_scale), gather(v_pages, v_scale)


def segmented_lora(x, block_adapter, a_w, b_w, *, block_t: int = 128,
                   backend: Optional[str] = None, interpret: bool = False):
    """x: (T, d) adapter-sorted; b_w: (NA, r, out) -> LoRA delta (T, out)."""
    b = _resolve(backend)
    if b == "pallas":
        return _sgmv_pallas(x, block_adapter, a_w, b_w, block_t=block_t,
                            interpret=interpret)
    return ref.segmented_lora_ref(x, block_adapter, a_w, b_w, block_t)
