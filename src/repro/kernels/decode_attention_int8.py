"""int8-KV flash-decode Pallas kernel (§Perf iteration on the serving cell).

Decode is HBM-bandwidth-bound: the step time is dominated by streaming the KV
cache. Storing K/V as int8 with a per-(batch, kv-head) symmetric scale halves
cache traffic; dequantization happens in-register inside the kernel (free on
the VPU), so the HBM side only ever sees int8. Same grid/online-softmax
structure as ``decode_attention``.

Quantization error is bounded by scale/2 per element (|k| <= 127.5*scale);
tests sweep shapes and assert closeness to the f32 oracle on quantized inputs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def quantize_kv(k, v):
    """k, v: (B, KV, S, hd) float -> (k_q, v_q int8, k_scale, v_scale (B, KV))."""
    def q(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=(2, 3)), 1e-8) / 127.0
        xq = jnp.clip(jnp.round(x / scale[:, :, None, None]), -127, 127)
        return xq.astype(jnp.int8), scale.astype(jnp.float32)
    kq, ks = q(k.astype(jnp.float32))
    vq, vs = q(v.astype(jnp.float32))
    return kq, vq, ks, vs


def _kernel(len_ref, scale_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: Optional[int],
            bs: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    k_s = scale_ref[b, h, 0]
    v_s = scale_ref[b, h, 1]
    pos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = pos < length
    if window is not None:
        mask &= pos >= (length - window)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
        # in-register dequantization — HBM only ever streams int8
        k = k_ref[0, 0].astype(jnp.float32) * k_s           # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32) * v_s
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(js == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention_int8(q, k_q, v_q, k_scale, v_scale, lengths, *,
                          window: Optional[int] = None, block_s: int = 512,
                          interpret: bool = False):
    """q: (B, H, hd) float; k_q/v_q: (B, KV, S, hd) int8;
    k_scale/v_scale: (B, KV); lengths: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    _, KV, S, _ = k_q.shape
    G = H // KV
    bs = min(block_s, S)
    assert S % bs == 0
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(B, KV, G, hd)
    scales = jnp.stack([k_scale, v_scale], axis=-1)          # (B, KV, 2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                               # lengths, scales
        grid=(B, KV, S // bs),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, bs=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, scales, qg, k_q, v_q)
    return out.reshape(B, H, hd)
