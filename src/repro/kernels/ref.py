"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Layouts here match the KERNEL layouts (head-major), not the model-internal
layouts — ``ops.py`` adapts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd). Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32)) * scale
    q_pos = (Sk - Sq) + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, window: Optional[int] = None):
    """q: (B, H, hd); k, v: (B, KV, S, hd); lengths: (B,). -> (B, H, hd)."""
    B, H, hd = q.shape
    _, KV, S, _ = k.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def decode_attention_int8_ref(q, k_q, v_q, k_scale, v_scale, lengths, *,
                              window: Optional[int] = None):
    """int8-KV decode oracle: dequantize then run the f32 decode reference.

    q: (B, H, hd); k_q/v_q: (B, KV, S, hd) int8; k_scale/v_scale: (B, KV);
    lengths: (B,). -> (B, H, hd)."""
    k = k_q.astype(jnp.float32) * k_scale[:, :, None, None]
    v = v_q.astype(jnp.float32) * v_scale[:, :, None, None]
    return decode_attention_ref(q, k, v, lengths, window=window)


def paged_decode_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                               page_table, lengths, *,
                               window: Optional[int] = None):
    """Paged int8-KV decode oracle: gather pages through the table, dequant
    with the per-page scales, run the f32 decode reference.

    q: (B, H, hd); k_pages/v_pages: (num_pages, KV, ps, hd) int8;
    k_scale/v_scale: (num_pages, KV); page_table: (B, max_pages) int32;
    lengths: (B,). -> (B, H, hd)."""
    B = q.shape[0]
    _, KV, ps, hd = k_pages.shape
    MP = page_table.shape[1]

    def gather(pages, scale):
        g = pages[page_table].astype(jnp.float32)        # (B, MP, KV, ps, hd)
        g = g * scale[page_table][..., None, None]       # per-page dequant
        return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, MP * ps, hd)

    return decode_attention_ref(q, gather(k_pages, k_scale),
                                gather(v_pages, v_scale), lengths,
                                window=window)


def paged_verify_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                               page_table, base_len, *,
                               window: Optional[int] = None):
    """Speculative verify-window oracle (kernel layout, head-major).

    q: (B, T, H, hd); k_pages/v_pages: (num_pages, KV, ps, hd) int8;
    k_scale/v_scale: (num_pages, KV); page_table: (B, max_pages) int32;
    base_len: (B,) — window position j attends ``base_len + j + 1`` keys.
    Returns (B, T, H, hd): T independent single-token paged decode reads at
    successive lengths."""
    T = q.shape[1]
    outs = [paged_decode_attention_ref(q[:, j], k_pages, v_pages, k_scale,
                                       v_scale, page_table, base_len + j + 1,
                                       window=window)
            for j in range(T)]
    return jnp.stack(outs, axis=1)


def gather_prefix_kv_ref(k_pages, v_pages, k_scale, v_scale, page_table):
    """Dequantized prefix K/V gather (kernel layout, head-major).

    k_pages/v_pages: (num_pages, KV, ps, hd) int8; k_scale/v_scale:
    (num_pages, KV); page_table: (B, P) int32. Returns float32
    (k, v), each (B, KV, P * ps, hd) — the chunked-prefill oracle for
    attending a private tail against already-mapped int8 prefix pages.
    """
    B = page_table.shape[0]
    _, KV, ps, hd = k_pages.shape
    P = page_table.shape[1]

    def gather(pages, scale):
        g = pages[page_table].astype(jnp.float32)        # (B, P, KV, ps, hd)
        g = g * scale[page_table][..., None, None]       # per-page dequant
        return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)

    return gather(k_pages, k_scale), gather(v_pages, v_scale)


def segmented_lora_ref(x, block_adapter, a_w, b_w, block_size: int):
    """Multi-adapter LoRA delta on an adapter-sorted batch.

    x: (T, d) rows sorted/padded so each ``block_size`` block belongs to ONE
    adapter; block_adapter: (T // block_size,) adapter id per block (may repeat;
    id == num_adapters means "no adapter" -> zero delta);
    a_w: (NA, d, r); b_w: (NA, r, out). Returns the LoRA delta (T, out).
    """
    T, d = x.shape
    na = a_w.shape[0]
    out_dim = b_w.shape[-1]
    nb = T // block_size
    xb = x.reshape(nb, block_size, d)

    def one(blk, aid):
        valid = aid < na
        aid_c = jnp.minimum(aid, na - 1)
        h = blk.astype(jnp.float32) @ a_w[aid_c].astype(jnp.float32)
        y = h @ b_w[aid_c].astype(jnp.float32)
        return jnp.where(valid, y, 0.0)

    out = jax.vmap(one)(xb, block_adapter)
    return out.reshape(T, out_dim).astype(x.dtype)
