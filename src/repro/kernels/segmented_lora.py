"""Segmented multi-adapter LoRA (SGMV) Pallas TPU kernel — FMplex's hot spot.

The vFM executor co-batches requests from many tasks over one shared backbone
pass, then applies per-task LoRA deltas: y[t] += x[t] @ A[a(t)] @ B[a(t)].
GPU systems (Punica/S-LoRA) do this with warp-level gathers; the TPU-native
formulation sorts the batch by adapter id and pads each adapter segment to a
block multiple, so every (block_t × d) tile touches exactly ONE adapter. The
adapter id per block arrives via scalar prefetch and drives the A/B BlockSpec
index_maps — the MXU sees dense (block_t, d) @ (d, r) @ (r, d) tiles with the
right adapter weights DMA'd into VMEM per block.

Sentinel id == num_adapters means "no adapter" (base-model request): the block
is skipped and contributes a zero delta (paper Fig. 5c semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _kernel(seg_ref, x_ref, a_ref, b_ref, o_ref, *, na: int):
    it = pl.program_id(0)
    aid = seg_ref[it]

    @pl.when(aid < na)
    def _apply():
        x = x_ref[...].astype(jnp.float32)                # (bt, d)
        a = a_ref[0].astype(jnp.float32)                  # (d, r)
        b = b_ref[0].astype(jnp.float32)                  # (r, d)
        h = jax.lax.dot(x, a, preferred_element_type=jnp.float32)
        o_ref[...] = jax.lax.dot(h, b,
                                 preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(aid >= na)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def segmented_lora(x, block_adapter, a_w, b_w, *, block_t: int = 128,
                   interpret: bool = False):
    """LoRA delta for an adapter-sorted, block-padded batch.

    x: (T, d) with T % block_t == 0, rows grouped so each block has one
    adapter; block_adapter: (T // block_t,) int32 adapter id per block
    (== num_adapters -> no adapter); a_w: (NA, d, r); b_w: (NA, r, out).
    Returns (T, out) delta (out == d for square projections; the serve path
    also uses out = H*hd / KV*hd for the q / v deltas).
    """
    T, d = x.shape
    na, _, r = a_w.shape
    out = b_w.shape[-1]
    assert T % block_t == 0, (T, block_t)
    nt = T // block_t

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, seg: (i, 0)),
            pl.BlockSpec((1, d, r), lambda i, seg: (jnp.minimum(seg[i], na - 1), 0, 0)),
            pl.BlockSpec((1, r, out), lambda i, seg: (jnp.minimum(seg[i], na - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, out), lambda i, seg: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, na=na),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, out), x.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_adapter, x, a_w, b_w)


def sort_by_adapter(adapter_ids, num_adapters: int, block_t: int = 128,
                    max_tokens: int | None = None):
    """Host-side helper: build (permutation, block_adapter, padded_T) so each
    ``block_t`` block maps to one adapter. Returns numpy arrays (executor use).

    Fully vectorized (one stable argsort + one ``np.unique`` with counts) —
    no O(segments × B) Python loop, so token-level co-batches with thousands
    of rows stay cheap on the host hot path.
    """
    import numpy as np

    adapter_ids = np.asarray(adapter_ids)
    n = len(adapter_ids)
    order = np.argsort(adapter_ids, kind="stable")
    uniq, counts = np.unique(adapter_ids, return_counts=True)
    padded = -(-counts // block_t) * block_t           # per-segment block pad
    blocks = np.repeat(uniq, padded // block_t)
    total = int(padded.sum())
    # destination of each sorted row: its segment's start + rank within it
    seg_starts = np.concatenate([[0], np.cumsum(padded)[:-1]])
    src_starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    perm = np.full(total, -1, np.int64)
    perm[np.repeat(seg_starts - src_starts, counts) + np.arange(n)] = order
    if max_tokens is not None:
        assert total <= max_tokens, (total, max_tokens)
        blocks = np.concatenate(
            [blocks, np.full((max_tokens - total) // block_t, num_adapters)])
        perm = np.concatenate([perm, np.full(max_tokens - total, -1)])
        total = max_tokens
    return (perm.astype(np.int32), blocks.astype(np.int32), total)


def segment_metadata(adapter_ids, num_adapters: int, block_t: int = 128,
                     max_tokens: int | None = None):
    """Host-side serve-path metadata, built ONCE per co-batch and reused by
    every attention sublayer: ``(perm, inv, block_adapter)`` numpy arrays.

    ``perm`` (Tp,) gathers the flattened token stream into adapter-sorted,
    block-padded order (pad rows clamped to 0 — their garbage deltas live in
    single-adapter blocks and are dropped by the inverse gather); ``inv`` (T,)
    scatters the (Tp, out) kernel output back to the original token order as a
    pure gather, which keeps the jitted forward free of dynamic scatters.
    """
    import numpy as np

    raw_perm, blocks, total = sort_by_adapter(
        adapter_ids, num_adapters, block_t=block_t, max_tokens=max_tokens)
    real = raw_perm >= 0
    inv = np.zeros(len(adapter_ids), np.int32)
    inv[raw_perm[real]] = np.nonzero(real)[0].astype(np.int32)
    perm = np.where(real, raw_perm, 0).astype(np.int32)
    return perm, inv, blocks


class SegmentMetaCache:
    """Memoizes ``segment_metadata`` per batch *composition*.

    Steady-state serving (and every step of a decode co-batch) re-presents the
    same adapter-id vector; the host-side sort only needs to run again when
    slot occupancy or adapter assignment actually changes. Keyed on the raw id
    bytes plus the static shape inputs; FIFO-evicted so a long-lived server
    can't grow it unboundedly. ``builds`` counts cache misses — tests assert
    it stays flat across steady-state decode."""

    def __init__(self, maxsize: int = 128):
        self._cache: dict = {}
        self.maxsize = maxsize
        self.builds = 0

    def get(self, adapter_ids, num_adapters: int, block_t: int,
            max_tokens: int | None):
        import numpy as np

        ids = np.ascontiguousarray(np.asarray(adapter_ids, np.int32))
        key = (ids.tobytes(), num_adapters, block_t, max_tokens)
        hit = self._cache.get(key)
        if hit is None:
            self.builds += 1
            hit = segment_metadata(ids, num_adapters, block_t=block_t,
                                   max_tokens=max_tokens)
            if len(self._cache) >= self.maxsize:
                self._cache.pop(next(iter(self._cache)))
            self._cache[key] = hit
        return hit


def padded_tokens(n_tokens: int, max_segments: int, block_t: int) -> int:
    """Static upper bound on the sorted/padded token count: every one of up to
    ``max_segments`` adapter segments pads to a block multiple. Keyed only on
    bucketed quantities so jitted serve shapes are stable across batches.

    The bound is TIGHT: with ``s`` non-empty segments over ``n`` tokens, each
    segment holds >= 1 token, so ``sum ceil(c_i/bt)*bt`` is maximized when
    ``s - 1`` segments hold exactly one token each — giving
    ``((n - s)//bt + s) * bt`` — not the looser ``ceil(n/bt)*bt + s*bt`` that
    double-counts a full block of slack per segment. At decode shapes
    (``block_t`` ~ batch) the difference is roughly ``max_segments`` whole
    blocks of wasted kernel grid per co-batch."""
    s = min(max_segments, max(n_tokens, 1))
    return (max(0, n_tokens - s) // block_t + s) * block_t
