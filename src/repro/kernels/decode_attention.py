"""GQA flash-decode Pallas TPU kernel (serve_step hot loop).

One new token attends to a long KV cache: the workload is HBM-bandwidth-bound
(stream S × hd keys/values through VMEM once). Grid: (B, KV, num_s_blocks),
s innermost/sequential; all G query heads of a kv group ride along in one
(G, hd) VMEM tile so each K/V block is read exactly once per group — the TPU
analogue of GPU flash-decode's warp-per-group layout.

Valid-length masking uses the per-request ``lengths`` vector, delivered via
scalar prefetch (SMEM) so block index maps stay static.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, window: Optional[int], bs: int):
    b = pl.program_id(0)
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    pos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = pos < length
    if window is not None:
        mask &= pos >= (length - window)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None, :], s, NEG_INF)          # (G, bs)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(js == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention(q, k, v, lengths, *, window: Optional[int] = None,
                     block_s: int = 512, interpret: bool = False):
    """q: (B, H, hd); k, v: (B, KV, S, hd); lengths: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    _, KV, S, _ = k.shape
    G = H // KV
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(B, KV, G, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S // bs),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, lens: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, lens: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, scale=scale, window=window, bs=bs)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, hd)
