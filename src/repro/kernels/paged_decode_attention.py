"""Paged int8-KV flash-decode Pallas kernel (the paged serving pool).

The dense int8 decode kernel streams one contiguous ``(B, KV, S, hd)`` cache
row per request — which forces the pool to RESERVE ``s_max`` tokens per slot
whether the stream uses them or not. The paged pool instead keeps one global
arena of fixed-size pages (``(num_pages, KV, page_size, hd)`` int8, plus a
per-(page, kv-head) scale pair) and a per-request page table; a stream holds
exactly the pages its tokens occupy, so colocation is bounded by tokens in
flight, not by ``num_slots × s_max``.

The kernel gathers K/V **through the page table inside the grid**: the block
index maps read the scalar-prefetched ``page_table`` (SMEM), so grid step
``(b, h, j)`` DMAs arena page ``page_table[b, j]`` into VMEM — the gather is
part of the pipelined HBM→VMEM streaming, never a materialized dense copy.
Same online-softmax accumulator as ``decode_attention_int8``; dequantization
stays in-register (per-page scales ride along via the same index map), so HBM
only ever sees int8.

Page-table entries past a stream's last page must point at SOME valid page
(callers keep them 0): their blocks are DMA'd but fully masked by the length
check, exactly like the dense kernel's tail blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _kernel(len_ref, ptab_ref, q_ref, k_ref, v_ref, scale_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: Optional[int],
            ps: int):
    b = pl.program_id(0)
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    # token positions this PAGE covers in the stream (page js of request b)
    pos = js * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)[0]
    mask = pos < length
    if window is not None:
        mask &= pos >= (length - window)

    @pl.when(jnp.any(mask))
    def _compute():
        k_s = scale_ref[0, 0, 0]
        v_s = scale_ref[0, 0, 1]
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
        # in-register dequantization — HBM only ever streams int8 pages
        k = k_ref[0, 0].astype(jnp.float32) * k_s           # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32) * v_s
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(js == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, k_scale, v_scale, page_table,
                           lengths, *, window: Optional[int] = None,
                           interpret: bool = False):
    """q: (B, H, hd) float; k_pages/v_pages: (num_pages, KV, ps, hd) int8;
    k_scale/v_scale: (num_pages, KV) f32 per-page dequant scales;
    page_table: (B, max_pages) int32 arena page ids (entries past a stream's
    length must still be valid indices — keep them 0); lengths: (B,)
    -> (B, H, hd)."""
    B, H, hd = q.shape
    _, KV, ps, _ = k_pages.shape
    MP = page_table.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(B, KV, G, hd)
    scales = jnp.stack([k_scale, v_scale], axis=-1)          # (P, KV, 2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                               # lengths, page_table
        grid=(B, KV, MP),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens, pt: (b, h, 0, 0)),
            # the paged gather: block (b, h, j) pulls arena page pt[b, j]
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, lens, pt: (pt[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, lens, pt: (pt[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, 2),
                         lambda b, h, j, lens, pt: (pt[b, j], h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, lens, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, ps=ps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, page_table, qg, k_pages, v_pages, scales)
    return out.reshape(B, H, hd)
