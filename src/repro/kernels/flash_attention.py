"""Flash attention (prefill) Pallas TPU kernel.

Grid: (B, H, num_q_blocks, num_kv_blocks) with the kv axis innermost and
"arbitrary" (sequential) — the online-softmax state (m, l, acc) lives in VMEM
scratch and persists across kv iterations of one (b, h, q) cell.

BlockSpecs tile Q/K/V/O into MXU-aligned (block, head_dim) tiles resident in
VMEM; GQA is expressed in the K/V index_map (h -> h // group). Causal and
sliding-window masking is applied from absolute block offsets; fully-masked
blocks are skipped via @pl.when.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, q_off: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_off + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = 1.0 / (hd ** 0.5)
    grid = (B, H, Sq // bq, Sk // bk)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, q_off=Sk - Sq)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
