"""Analytic MODEL_FLOPS per cell (the 6·N·D convention) for the useful-compute
ratio in §Roofline. N excludes the embedding table; MoE uses active params."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import lm
from repro.models.common import param_count


def _counts(cfg: ModelConfig) -> tuple[int, int]:
    """(N_total, N_active) excluding the token-embedding table."""
    spec = lm.model_spec(cfg)
    n = param_count(spec)
    if "embed" in spec:
        n -= cfg.vocab_size * cfg.d_model
    n_active = n
    if cfg.uses_moe:
        moe_layers = sum(1 for i in range(cfg.num_layers) if cfg._layer_has_moe(i))
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_active = n - moe_layers * (cfg.num_experts - cfg.experts_per_token) * per_expert
    return n, n_active


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global MODEL_FLOPS for one step of this cell."""
    _, n_active = _counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
