"""HLO post-processing: roofline terms derived from the compiled dry-run.

Why not just ``compiled.cost_analysis()``? XLA's cost analysis counts a
``while`` body ONCE, not × trip-count — our models scan over layer periods and
attention chunks, so raw cost_analysis undercounts FLOPs by 10–30×
(verified empirically; see EXPERIMENTS.md §Dry-run). This module parses the
SPMD-partitioned HLO (``compiled.as_text()`` — all shapes are per-device
shards), builds the computation call graph (fusions, calls, while bodies),
extracts while trip counts from their condition computations, and accumulates:

  * dot FLOPs        — 2 · prod(result dims) · prod(contracting dims), exact
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
  * HBM traffic      — documented model: Σ dot (lhs+rhs+result bytes) +
                       2 × collective operand bytes (+ reported argument/output
                       sizes are recorded separately by the dry-run).

All quantities are per-device; loop bodies are multiplied by trip count.
Validated against cost_analysis on loop-free programs (tests/test_hlo.py).
"""
from __future__ import annotations

import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_instr(line: str):
    """-> (name, result_type, op, args_str, tail) or None.

    Handles tuple result types (nested parens) and the /*index=N*/ comments
    HLO inserts inside long tuples — a plain regex chokes on both.
    """
    line = _COMMENT_RE.sub("", line)
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):           # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype, rest = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, rest = rest[:sp], rest[sp:]
    m2 = _OP_RE.match(rest)
    if not m2:
        return None
    op = m2.group(1)
    # args up to matching close paren
    depth, args = 1, []
    i = m2.end()
    while i < len(rest) and depth:
        ch = rest[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args.append(ch)
        i += 1
    return name, rtype, op, "".join(args), rest[i:]
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(shape_str: str):
    """All (dtype, dims) tensor shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class _Comp:
    __slots__ = ("flops", "coll", "coll_by_kind", "coll_counts", "dot_bytes",
                 "children", "trip_const")

    def __init__(self):
        self.flops = 0.0
        self.coll = 0.0
        self.coll_by_kind = {k: 0.0 for k in COLLECTIVES}
        self.coll_counts = {k: 0 for k in COLLECTIVES}
        self.dot_bytes = 0.0
        self.children = []          # (callee_name, multiplier_kind)
        self.trip_const = 0         # max int constant seen (trip-count candidate)


def _dot_flops(args: str, tail: str, result_type: str, shapes: dict) -> tuple[float, float]:
    """FLOPs + operand/result bytes for a dot instruction."""
    res = _shape_dims(result_type)
    if not res:
        return 0.0, 0.0
    _, rdims = res[0]
    n_out = 1
    for d in rdims:
        n_out *= d
    # contracting dims from lhs shape + lhs_contracting_dims
    opnds = re.findall(r"%([\w.\-]+)", args)
    lhs_type = shapes.get(opnds[0], "") if opnds else ""
    lhs = _shape_dims(lhs_type)
    contr = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", tail)
    k = 1
    if lhs and contr and contr.group(1):
        _, ldims = lhs[0]
        for ci in contr.group(1).split(","):
            ci = int(ci)
            if ci < len(ldims):
                k *= ldims[ci]
    flops = 2.0 * n_out * k
    obytes = sum(_shape_bytes(shapes.get(o, "")) for o in opnds[:2])
    obytes += _shape_bytes(result_type)
    return flops, obytes


def analyze(hlo_text: str) -> dict:
    """Trip-count-aware per-device FLOPs / collective bytes / dot HBM traffic."""
    # ---- pass 1: split into computations; collect instruction result types
    comps: dict[str, _Comp] = {}
    shapes: dict[str, str] = {}
    cur = None
    entry = None
    lines = hlo_text.splitlines()
    comp_of_line = []
    for line in lines:
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = _Comp()
            if mc.group(1):
                entry = cur
        comp_of_line.append(cur)
        pi = _parse_instr(line)
        if pi:
            shapes[pi[0]] = pi[1]

    # ---- pass 2: per-computation costs + call graph
    for line, cname in zip(lines, comp_of_line):
        if cname is None:
            continue
        comp = comps[cname]
        pi = _parse_instr(line)
        if not pi:
            continue
        name, rtype, op, args, tail = pi
        if op == "dot":
            f, b = _dot_flops(args, tail, rtype, shapes)
            comp.flops += f
            comp.dot_bytes += b
        elif op == "constant" and re.match(r"^s(32|64)\b", rtype):
            m = re.match(r"(\d+)$", args)
            if m:
                comp.trip_const = max(comp.trip_const, int(m.group(1)))
        else:
            kind = next((k for k in COLLECTIVES if op.startswith(k)), None)
            if kind:
                opnds = re.findall(r"%([\w.\-]+)", args)
                ob = sum(_shape_bytes(shapes.get(o, "")) for o in opnds) or \
                    _shape_bytes(rtype)
                comp.coll += ob
                comp.coll_by_kind[kind] += ob
                comp.coll_counts[kind] += 1
        # call edges
        if op == "fusion" or op == "call":
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", tail)
            if m:
                comp.children.append((m.group(1), 1))
        elif op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", tail)
            mt = _TRIP_RE.search(tail)
            mcn = re.search(r"condition=%?([\w.\-]+)", tail)
            if mb:
                if mt:
                    trips = int(mt.group(1))
                else:  # fall back: max int constant in the condition comp
                    trips = comps.get(mcn.group(1), _Comp()).trip_const if mcn else 1
                comp.children.append((mb.group(1), max(trips, 1)))
        elif op == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations)=\{?%?([\w.\-,% ]+)", tail):
                for nm in re.findall(r"[\w.\-]+", m.group(1)):
                    comp.children.append((nm, 1))

    # ---- pass 3: DFS from ENTRY with multipliers (memoized totals)
    if entry is None:
        # fall back: the computation containing most flops
        entry = max(comps, key=lambda c: comps[c].flops, default=None)

    memo: dict[str, tuple] = {}

    def total(cname, depth=0):
        if cname in memo:
            return memo[cname]
        if cname not in comps or depth > 64:
            return (0.0, 0.0, {k: 0.0 for k in COLLECTIVES},
                    {k: 0 for k in COLLECTIVES}, 0.0)
        c = comps[cname]
        f, cl, db = c.flops, c.coll, c.dot_bytes
        by_kind = dict(c.coll_by_kind)
        counts = dict(c.coll_counts)
        for child, mult in c.children:
            cf, ccl, cbk, cct, cdb = total(child, depth + 1)
            f += mult * cf
            cl += mult * ccl
            db += mult * cdb
            for k in COLLECTIVES:
                by_kind[k] += mult * cbk[k]
                counts[k] += mult * cct[k]
        memo[cname] = (f, cl, by_kind, counts, db)
        return memo[cname]

    f, cl, by_kind, counts, db = total(entry)

    # ---- TPU dtype normalization --------------------------------------
    # XLA:CPU cannot emit bf16 collectives: every bf16-level psum is promoted
    # to f32 right before the all-reduce (verified with a minimal
    # shard_map(psum(optimization_barrier(bf16))) repro — the convert is
    # inserted unconditionally). At the StableHLO level all large reductions
    # in these models are bf16, and on the TPU target they execute in bf16.
    # We therefore also report bytes with f32 collective operands >= 1 MiB
    # counted at half width; the roofline collective term uses this value and
    # EXPERIMENTS.md §Dry-run documents the rule.
    f32_big = 0.0
    for line, cname in zip(lines, comp_of_line):
        if cname is None:
            continue
        pi = _parse_instr(line)
        if not pi:
            continue
        name, rtype, op, args, tail = pi
        kind = next((k for k in COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        for o in re.findall(r"%([\w.\-]+)", args):
            t = shapes.get(o, "")
            b = _shape_bytes(t)
            if b >= (1 << 20) and re.search(r"\bf32\[", t):
                # weight of this op in the entry total = product of trips on
                # its path; approximate with the per-computation multiplier
                # derived from the memoized totals (exact for our call trees)
                f32_big += b * _trips_of(cname, comps, memo, entry)
    cl_norm = cl - f32_big / 2.0
    return {
        "dot_flops": f,
        "collective_bytes": cl,
        "collective_bytes_norm": cl_norm,
        "collective_by_kind": by_kind,
        "collective_counts": counts,
        "dot_traffic_bytes": db,
        "hbm_traffic_bytes": db + 2 * cl_norm,
    }


def _trips_of(cname: str, comps, memo, entry) -> float:
    """Total trip multiplier of a computation along the call tree (number of
    times its body executes per entry invocation)."""
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for child, m in comps.get(cur, _Comp()).children:
            mult[child] = mult.get(child, 0.0) + mult[cur] * m
            if child not in seen:
                seen.add(child)
                order.append(child)
    return mult.get(cname, 0.0)


def collective_stats(hlo_text: str) -> dict:
    """Back-compat shim over analyze()."""
    a = analyze(hlo_text)
    stats = dict(a["collective_by_kind"])
    stats["total"] = a["collective_bytes"]
    stats["counts"] = a["collective_counts"]
    return stats


# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (task-specified roofline model)


def roofline_terms(flops: Optional[float], bytes_accessed: Optional[float],
                   coll_bytes_per_dev: float, chips: int) -> dict:
    """All three terms in seconds. Inputs are PER-DEVICE (partitioned HLO
    shapes are shards; equivalent to global/(chips·peak))."""
    out = {}
    out["compute_s"] = (flops / PEAK_FLOPS) if flops else None
    out["memory_s"] = (bytes_accessed / HBM_BW) if bytes_accessed else None
    out["collective_s"] = coll_bytes_per_dev / ICI_BW
    terms = {k: v for k, v in out.items() if v}
    out["bottleneck"] = max(terms, key=terms.get) if terms else None
    return out
