import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count on first init. Usage:

  python -m repro.launch.dryrun --cell qwen2-7b:train_4k:pod1      # one cell
  python -m repro.launch.dryrun --all [--resume]                   # full sweep
                                                                   # (subprocess
                                                                   # per cell)

Each cell records memory_analysis / cost_analysis / collective stats to
``results/dryrun.jsonl``; §Roofline and §Perf read from there.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results"


def run_one_cell(arch: str, shape_name: str, mesh_kind: str,
                 overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import SHAPES, applicable, get_config
    from repro.launch import flops as flops_mod
    from repro.launch import hlo
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "ts": time.time()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        cell = build_cell(arch, shape, mesh, overrides=overrides)
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # CPU backend may not implement it
            mem["error"] = str(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            for k in ("flops", "bytes accessed"):
                if ca and k in ca:
                    cost[k] = float(ca[k])
        except Exception as e:
            cost["error"] = str(e)

        text = compiled.as_text()
        a = hlo.analyze(text)

    # static memory model: weights/cache traffic per step (args re-read) is
    # already inside dot_traffic; memory_analysis gives residency for fit-check.
    # collective term uses the TPU-dtype-normalized bytes (see hlo.analyze).
    terms = hlo.roofline_terms(a["dot_flops"], a["hbm_traffic_bytes"],
                               a["collective_bytes_norm"], chips)
    mf = flops_mod.model_flops(cfg, shape)
    rec.update(
        status="ok", chips=chips, lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1), memory=mem,
        cost_analysis_raw=cost,   # XLA numbers (while bodies counted once)
        hlo_flops_per_dev=a["dot_flops"],
        hbm_traffic_per_dev=a["hbm_traffic_bytes"],
        collective_bytes_norm=a["collective_bytes_norm"],
        collectives={**a["collective_by_kind"], "total": a["collective_bytes"]},
        collective_counts=a["collective_counts"],
        model_flops_global=mf,
        model_flops_per_dev=mf / chips,
        useful_ratio=(mf / chips) / a["dot_flops"] if a["dot_flops"] else None,
        roofline=terms, hlo_bytes=len(text))
    return rec


def cell_list(mesh_kinds=("pod1", "pod2")):
    from repro.configs import ASSIGNED, SHAPES
    cells = []
    for arch in ASSIGNED:
        for shape in SHAPES:
            for mk in mesh_kinds:
                cells.append((arch, shape, mk))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:pod1|pod2")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.jsonl"))
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--overrides", default=None,
                    help="JSON rule overrides (perf iterations)")
    args = ap.parse_args()
    RESULTS.mkdir(exist_ok=True)

    if args.cell:
        arch, shape, mk = args.cell.split(":")
        overrides = json.loads(args.overrides) if args.overrides else None
        try:
            rec = run_one_cell(arch, shape, mk, overrides)
        except Exception:
            rec = {"arch": arch, "shape": shape, "mesh": mk, "status": "error",
                   "error": traceback.format_exc()[-2000:]}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(json.dumps({k: rec[k] for k in rec if k not in ("memory", "cost")},
                         indent=None)[:600])
        sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)

    if args.all:
        done = set()
        out = Path(args.out)
        if args.resume and out.exists():
            for line in out.read_text().splitlines():
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
        todo = [c for c in cell_list() if c not in done]
        print(f"{len(todo)} cells to run ({len(done)} already done)")
        for i, (arch, shape, mk) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--cell", f"{arch}:{shape}:{mk}", "--out", args.out]
            print(f"[{i+1}/{len(todo)}] {arch}:{shape}:{mk}", flush=True)
            try:
                subprocess.run(cmd, timeout=args.timeout, check=False)
            except subprocess.TimeoutExpired:
                with open(args.out, "a") as f:
                    f.write(json.dumps({"arch": arch, "shape": shape, "mesh": mk,
                                        "status": "timeout"}) + "\n")
        print("dry-run sweep complete")


if __name__ == "__main__":
    main()
