"""Production mesh construction.

A function (not a module-level constant) so importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    # jax.sharding.AxisType (explicit-auto axis marking) only exists on newer
    # jax; older releases treat every axis as Auto already, so omit the kwarg
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh for CPU smoke tests (same axis names as single-pod)."""
    return make_mesh((1, 1), ("data", "model"))


def make_mesh_for(devices: int, model_parallel: int = 16, pods: int = 1):
    """Elastic-scaling helper: build a mesh for an arbitrary device count."""
    data = devices // (model_parallel * pods)
    assert data >= 1 and data * model_parallel * pods == devices, (devices, model_parallel, pods)
    if pods > 1:
        return make_mesh((pods, data, model_parallel), ("pod", "data", "model"))
    return make_mesh((data, model_parallel), ("data", "model"))
