"""Production mesh construction.

A function (not a module-level constant) so importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh():
    """1-device mesh for CPU smoke tests (same axis names as single-pod)."""
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=_auto(2))


def make_mesh_for(devices: int, model_parallel: int = 16, pods: int = 1):
    """Elastic-scaling helper: build a mesh for an arbitrary device count."""
    data = devices // (model_parallel * pods)
    assert data >= 1 and data * model_parallel * pods == devices, (devices, model_parallel, pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model_parallel), ("data", "model"), axis_types=_auto(2))
