import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Roofline reporting + per-cell profiling for the §Perf hypothesis loop.

  python -m repro.launch.roofline --table [--jsonl results/dryrun.jsonl]
  python -m repro.launch.roofline --detail qwen2-7b:decode_32k:pod1 \
      [--overrides '{"act":{"seq":"model"}}']     # top collectives + dots
"""
import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results"


def print_table(jsonl: str, mesh: str = "pod1"):
    rows = {}
    for line in Path(jsonl).read_text().splitlines():
        r = json.loads(line)
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rows[(r["arch"], r["shape"])] = r
    hdr = (f"{'arch':<16}{'shape':<12}{'compute_s':>11}{'memory_s':>11}"
           f"{'coll_s':>11} {'bottleneck':<12}{'MODEL/HLO':>10}"
           f"{'arg+tmp_GB':>11}")
    print(hdr)
    print("-" * len(hdr))
    for (arch, shape), r in sorted(rows.items()):
        t = r["roofline"]
        m = r.get("memory", {})
        gb = (m.get("argument_size_in_bytes", 0)
              + m.get("temp_size_in_bytes", 0)) / 1e9
        print(f"{arch:<16}{shape:<12}{t['compute_s']:>11.3e}"
              f"{t['memory_s']:>11.3e}{t['collective_s']:>11.3e} "
              f"{t['bottleneck'][:-2]:<12}{(r.get('useful_ratio') or 0):>10.2f}"
              f"{gb:>11.2f}")


def detail(cell: str, overrides=None, top: int = 12):
    import jax  # noqa: F401  (device count env already set above)
    import re
    from repro.configs import SHAPES
    from repro.launch import hlo
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_production_mesh

    arch, shape, mk = cell.split(":")
    mesh = make_production_mesh(multi_pod=(mk == "pod2"))
    with mesh:
        c = build_cell(arch, SHAPES[shape], mesh, overrides=overrides)
        compiled = lower_cell(c).compile()
        text = compiled.as_text()
        a = hlo.analyze(text)
        print(json.dumps({k: v for k, v in a.items() if not isinstance(v, dict)}))
        print("memory:", compiled.memory_analysis())

    # rank individual collective ops and dots by (per-trip) operand bytes
    lines = text.splitlines()
    comps, cur, comp_of_line = {}, None, []
    for line in lines:
        mc = hlo._COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
        comp_of_line.append(cur)
    shapes = {}
    for line in lines:
        pi = hlo._parse_instr(line)
        if pi:
            shapes[pi[0]] = pi[1]
    colls, dots = [], []
    for line, cn in zip(lines, comp_of_line):
        pi = hlo._parse_instr(line)
        if not pi:
            continue
        name, rtype, op, args, tail = pi
        kind = next((k for k in hlo.COLLECTIVES if op.startswith(k)), None)
        if kind:
            ob = sum(hlo._shape_bytes(shapes.get(o, ""))
                     for o in re.findall(r"%([\w.\-]+)", args))
            colls.append((ob, kind, rtype[:48], cn[:40]))
        elif op == "dot":
            f, b = hlo._dot_flops(args, tail, rtype, shapes)
            dots.append((f, rtype[:48], cn[:40]))
    print(f"\ntop collectives (operand bytes per execution, x trips applies):")
    for ob, kind, rt, cn in sorted(colls, reverse=True)[:top]:
        print(f"  {ob/1e6:10.1f} MB  {kind:<20} {rt:<48} in {cn}")
    print(f"\ntop dots (flops per execution):")
    for f, rt, cn in sorted(dots, reverse=True)[:top]:
        print(f"  {f/1e9:10.2f} GF  {rt:<48} in {cn}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--jsonl", default=str(RESULTS / "dryrun.jsonl"))
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--detail", default=None)
    ap.add_argument("--overrides", default=None)
    args = ap.parse_args()
    if args.table:
        print_table(args.jsonl, args.mesh)
    if args.detail:
        detail(args.detail,
               json.loads(args.overrides) if args.overrides else None)


if __name__ == "__main__":
    main()
