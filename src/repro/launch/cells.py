"""Build (step_fn, arg structs, shardings) for every (arch × shape × mesh) cell.

This is the single source of truth used by the multi-pod dry-run, the roofline
analysis, and the perf-iteration harness. No device memory is ever allocated —
all inputs are ``jax.ShapeDtypeStruct``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec, get_config
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.common import ShardCtx, logical_axes, shape_structs
from repro.optim.adamw import AdamW, AdamWState
from repro.sharding import rules as R

SDS = jax.ShapeDtypeStruct

# archs whose weights need 2D (data+model) sharding even at serve time to fit HBM
BIG_SERVE = {"grok-1-314b", "qwen2-vl-72b"}

# gradient-accumulation microbatches for the train_4k cell (activation
# footprint scales 1/n while the global batch is preserved — §Perf)
MICROBATCH = {"grok-1-314b": 4, "qwen2-vl-72b": 4, "jamba-v0.1-52b": 4,
              "xlstm-125m": 2, "whisper-base": 2}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: object                  # python callable (to be jitted)
    args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: object
    donate: tuple
    static: dict


def _rules_for(cfg: ModelConfig, shape: ShapeSpec, overrides: Optional[dict] = None):
    long_ctx = shape.name == "long_500k"
    if shape.kind == "train":
        param_rules = R.LONG_CTX_FSDP if long_ctx else R.FSDP_RULES
    elif cfg.name in BIG_SERVE:
        param_rules = R.LONG_CTX_FSDP if long_ctx else R.FSDP_RULES
    else:
        param_rules = R.LONG_CTX_PARAM if long_ctx else R.TP_RULES
    act_rules = R.LONG_CTX_ACT if long_ctx else R.ACT_RULES
    if shape.kind == "train":
        # §Perf iteration: sequence-parallel residual stream — required for the
        # per-device activation footprint to fit HBM at 4k x 256 batch
        act_rules = dict(act_rules, seq="model")
    if overrides:
        param_rules = dict(param_rules, **overrides.get("param", {}))
        act_rules = dict(act_rules, **overrides.get("act", {}))
    return param_rules, act_rules


def _batch_structs(cfg: ModelConfig, B: int, S: int, kind: str):
    d = cfg.d_model
    s, axes = {}, {}
    if kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            s["enc_embeds"] = SDS((B, S, d), jnp.bfloat16)
            axes["enc_embeds"] = ("batch", None, None)
            s["tokens"] = SDS((B, S), jnp.int32)
            axes["tokens"] = ("batch", None)
        elif cfg.frontend_stub:
            s["embeds"] = SDS((B, S, d), jnp.bfloat16)
            axes["embeds"] = ("batch", None, None)
            if cfg.vocab_size > 0 and kind == "train":
                s["labels"] = SDS((B, S), jnp.int32)
                axes["labels"] = ("batch", None)
            if cfg.mrope_sections:
                s["pos3"] = SDS((B, S, 3), jnp.int32)
                axes["pos3"] = ("batch", None, None)
        else:
            s["tokens"] = SDS((B, S), jnp.int32)
            axes["tokens"] = ("batch", None)
    else:  # decode
        s["tokens"] = SDS((B,), jnp.int32)
        axes["tokens"] = ("batch",)
    return s, axes


def build_cell(arch: str, shape: ShapeSpec, mesh, overrides: Optional[dict] = None) -> Cell:
    from repro.sharding.padding import pad_for_tp
    cfg = pad_for_tp(get_config(arch), mesh.shape.get("model", 1))
    if overrides and "moe_dispatch" in overrides:
        cfg = dataclasses.replace(cfg, moe_dispatch=overrides["moe_dispatch"])
    elif cfg.uses_moe:
        # §Perf: shard_map expert parallelism by default (auto-falls back to
        # gshard when num_experts doesn't divide the model axis, e.g. grok)
        cfg = dataclasses.replace(cfg, moe_dispatch="ep")
    param_rules, act_rules = _rules_for(cfg, shape, overrides)
    shard = ShardCtx(act_rules, mesh)
    B, S = shape.global_batch, shape.seq_len

    mspec = lm.model_spec(cfg)
    p_axes = logical_axes(mspec)

    def psh(rules, struct_tree, axes_tree):
        return R.tree_shardings(rules, axes_tree, mesh, struct_tree)

    if shape.kind == "train":
        p_structs = shape_structs(mspec, dtype=jnp.float32)
        p_sh = psh(param_rules, p_structs, p_axes)
        opt = AdamW(lr=1e-4)
        opt_structs = AdamWState(SDS((), jnp.int32),
                                 jax.tree.map(lambda s: SDS(s.shape, jnp.float32), p_structs),
                                 jax.tree.map(lambda s: SDS(s.shape, jnp.float32), p_structs))
        opt_sh = AdamWState(NamedSharding(mesh, P()), p_sh, p_sh)
        state_structs = {"params": p_structs, "opt": opt_structs}
        state_sh = {"params": p_sh, "opt": opt_sh}
        b_structs, b_axes = _batch_structs(cfg, B, S, "train")
        b_sh = {k: NamedSharding(mesh, R.spec_for(act_rules, b_axes[k], mesh,
                                                  b_structs[k].shape))
                for k in b_structs}

        n_micro = (overrides or {}).get("microbatch",
                                        MICROBATCH.get(arch, 1))

        def train_step(state, batch):
            if n_micro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    lm.loss_fn, has_aux=True)(state["params"], cfg, batch, shard)
            else:
                # gradient accumulation: scan over microbatches; the grads
                # accumulator is params-shaped (FSDP-sharded), activations
                # shrink by 1/n_micro
                micro = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)

                def acc_step(carry, mb):
                    g_acc, l_acc = carry
                    (loss, _), g = jax.value_and_grad(
                        lm.loss_fn, has_aux=True)(state["params"], cfg, mb, shard)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32) / n_micro,
                        g_acc, g)
                    return (g_acc, l_acc + loss / n_micro), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state["params"])
                (grads, loss), _ = jax.lax.scan(
                    acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
                metrics = {"loss": loss}
            new_p, new_opt, om = opt.update(grads, state["opt"], state["params"])
            return {"params": new_p, "opt": new_opt}, {**metrics, **om}

        return Cell(arch, shape, train_step, (state_structs, b_structs),
                    (state_sh, b_sh),
                    ({"params": p_sh, "opt": opt_sh}, NamedSharding(mesh, P())),
                    (0,), {})

    # serving cells: params in bf16
    p_structs = shape_structs(mspec, dtype=jnp.bfloat16)
    p_sh = psh(param_rules, p_structs, p_axes)
    c_spec = lm.cache_spec(cfg, B, S)
    c_structs = shape_structs(c_spec)
    c_sh = psh(param_rules, c_structs, logical_axes(c_spec))

    if shape.kind == "prefill":
        b_structs, b_axes = _batch_structs(cfg, B, S, "prefill")
        b_sh = {k: NamedSharding(mesh, R.spec_for(act_rules, b_axes[k], mesh,
                                                  b_structs[k].shape))
                for k in b_structs}
        logits_sh = NamedSharding(mesh, R.spec_for(
            act_rules, ("batch", "vocab"), mesh,
            (B, max(cfg.vocab_size, cfg.d_model))))

        def prefill_step(params, batch, cache):
            return lm.prefill(params, cfg, cache=cache, shard=shard, **batch)

        return Cell(arch, shape, prefill_step, (p_structs, b_structs, c_structs),
                    (p_sh, b_sh, c_sh), (logits_sh, c_sh), (2,), {})

    # decode
    b_structs, b_axes = _batch_structs(cfg, B, S, "decode")
    b_sh = {k: NamedSharding(mesh, R.spec_for(act_rules, b_axes[k], mesh,
                                              b_structs[k].shape))
            for k in b_structs}
    logits_sh = NamedSharding(mesh, R.spec_for(act_rules, ("batch", "vocab"), mesh,
                                               (B, cfg.vocab_size)))

    lora_cfg = (overrides or {}).get("lora")
    if lora_cfg:
        # FMplex-integrated serving: the co-batch carries per-request adapter
        # ids; the shared backbone applies multi-adapter LoRA deltas (vFM
        # customization at production scale)
        from repro.models import lora as lora_mod
        l_spec = lora_mod.lora_spec(cfg, lora_cfg.get("num_adapters", 32),
                                    lora_cfg.get("rank", 16))
        l_structs = shape_structs(l_spec, dtype=jnp.bfloat16)
        l_sh = psh(param_rules, l_structs, logical_axes(l_spec))
        aidx_struct = SDS((B,), jnp.int32)
        aidx_sh = NamedSharding(mesh, R.spec_for(act_rules, ("batch",), mesh, (B,)))

        def serve_step_lora(params, cache, batch, lora, adapter_idx):
            return lm.decode_step(params, cfg, cache=cache, shard=shard,
                                  lora=lora, adapter_idx=adapter_idx, **batch)

        return Cell(arch, shape, serve_step_lora,
                    (p_structs, c_structs, b_structs, l_structs, aidx_struct),
                    (p_sh, c_sh, b_sh, l_sh, aidx_sh), (logits_sh, c_sh),
                    (1,), {})

    def serve_step(params, cache, batch):
        return lm.decode_step(params, cfg, cache=cache, shard=shard, **batch)

    return Cell(arch, shape, serve_step, (p_structs, c_structs, b_structs),
                (p_sh, c_sh, b_sh), (logits_sh, c_sh), (1,), {})


def lower_cell(cell: Cell):
    fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings, donate_argnums=cell.donate)
    return fn.lower(*cell.args)
