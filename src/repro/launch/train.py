"""Fault-tolerant training driver.

CPU-scale by default (reduced config, local mesh) — the same loop drives the
production mesh when real devices exist. Features exercised by tests/examples:
checkpoint/restart (async sharded saves, atomic publish), failure injection +
automatic resume, straggler detection, and optional elastic restart on a
smaller mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 40 \
      --reduced --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.data.tokens import TokenPipeline
from repro.distributed.fault import (FailureInjector, InjectedFailure,
                                     StepTimer, StragglerDetector)
from repro.models import lm
from repro.models.common import ShardCtx, logical_axes
from repro.optim.adamw import AdamW, cosine_schedule
from repro.sharding import rules as R


def make_train_step(cfg, opt, shard):
    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(state["params"], cfg, batch, shard)
        new_p, new_opt, om = opt.update(grads, state["opt"], state["params"])
        return {"params": new_p, "opt": new_opt}, {**metrics, **om}
    return train_step


class Trainer:
    def __init__(self, cfg, *, batch: int, seq: int, ckpt_dir: str,
                 mesh=None, ckpt_every: int = 20, lr: float = 3e-4,
                 total_steps: int = 1000, async_ckpt: bool = True, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.ckpt_dir = Path(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.async_ckpt = async_ckpt
        self.mesh = mesh
        self.shard = ShardCtx(R.ACT_RULES, mesh) if mesh is not None else ShardCtx()
        self.opt = AdamW(lr=cosine_schedule(lr, 20, total_steps))
        self.data = TokenPipeline(cfg, batch, seq, seed=seed)
        self.straggler = StragglerDetector()
        self._step_fn = jax.jit(make_train_step(cfg, self.opt, self.shard),
                                donate_argnums=0)
        self._pending_save = None

    def init_state(self):
        params = lm.init_model(jax.random.PRNGKey(0), self.cfg)
        return {"params": params, "opt": self.opt.init(params)}

    def state_shardings(self, state):
        if self.mesh is None:
            return None
        p_sh = R.tree_shardings(R.FSDP_RULES, logical_axes(lm.model_spec(self.cfg)),
                                self.mesh, state["params"])
        return {"params": p_sh,
                "opt": type(state["opt"])(None, p_sh, p_sh)}

    def restore_or_init(self):
        state = self.init_state()
        step = ckpt.latest_step(self.ckpt_dir)
        if step is not None:
            state, step = ckpt.restore(self.ckpt_dir, state)
            state = jax.tree.map(jax.numpy.asarray, state)
            print(f"[trainer] restored step {step} from {self.ckpt_dir}")
            return state, step + 1
        return state, 0

    def run(self, steps: int, *, injector: FailureInjector | None = None,
            max_restarts: int = 2) -> list[float]:
        losses, restarts = [], 0
        while True:
            try:
                state, start = self.restore_or_init()
                for step in range(start, steps):
                    if injector:
                        injector.check(step)
                    batch = {k: jax.numpy.asarray(v)
                             for k, v in next(self.data).items()}
                    with StepTimer() as t:
                        state, metrics = self._step_fn(state, batch)
                        loss = float(metrics["loss"])
                    self.straggler.record(step, t.duration)
                    losses.append(loss)
                    if step % self.ckpt_every == 0 or step == steps - 1:
                        if self._pending_save is not None:
                            self._pending_save.join()
                        self._pending_save = ckpt.save(
                            self.ckpt_dir, step, state,
                            blocking=not self.async_ckpt)
                if self._pending_save is not None:
                    self._pending_save.join()
                return losses
            except InjectedFailure as e:
                restarts += 1
                print(f"[trainer] {e}; restart {restarts}")
                if restarts > max_restarts:
                    raise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tr = Trainer(cfg, batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt,
                 lr=args.lr, total_steps=args.steps)
    t0 = time.time()
    losses = tr.run(args.steps)
    print(f"arch={cfg.name} steps={len(losses)} "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f} "
          f"({time.time()-t0:.1f}s, stragglers={len(tr.straggler.events)})")


if __name__ == "__main__":
    main()
