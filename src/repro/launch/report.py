"""Generate EXPERIMENTS.md tables from dry-run JSONLs.

Roofline fraction per cell: T_ideal / T_bound, where
  T_bound = max(compute_s, memory_s, collective_s)   (modeled step time)
  T_ideal = max(MODEL_FLOPS/(chips·peak), MIN_BYTES/(chips·HBM_bw))
MIN_BYTES is the unavoidable per-step HBM traffic: weights read once
(+ KV/state cache read once for serve steps). For train cells compute
dominates T_ideal; for decode cells the bytes term does.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch import flops as F
from repro.launch.hlo import HBM_BW, PEAK_FLOPS
from repro.models import lm
from repro.models.common import param_count


def min_bytes(arch: str, shape_name: str) -> float:
    """Unavoidable global HBM bytes per step (weights once + cache once)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_params = param_count(lm.model_spec(cfg))
    if shape.kind == "train":
        # fp32 params read + grads written + bf16 compute copies (approx)
        return n_params * (4 + 4 + 2)
    w = n_params * 2                                    # bf16 weights
    if shape.kind == "decode":
        cache = param_count(lm.cache_spec(cfg, shape.global_batch,
                                          shape.seq_len)) * 2
        return w + cache
    return w


def fraction(rec: dict) -> float:
    t = rec["roofline"]
    t_bound = max(v for k, v in t.items()
                  if k.endswith("_s") and isinstance(v, float))
    chips = rec["chips"]
    t_ideal = max(rec["model_flops_global"] / (chips * PEAK_FLOPS),
                  min_bytes(rec["arch"], rec["shape"]) / (chips * HBM_BW))
    return min(t_ideal / t_bound, 1.0) if t_bound > 0 else 0.0


def load(jsonl: str, mesh: str = "pod1") -> dict:
    out = {}
    for line in Path(jsonl).read_text().splitlines():
        r = json.loads(line)
        if r.get("status") == "ok" and r.get("mesh") == mesh:
            out[(r["arch"], r["shape"])] = r
    return out


def md_table(jsonl: str, mesh: str = "pod1") -> str:
    rows = load(jsonl, mesh)
    lines = ["| arch | shape | compute_s | memory_s | collective_s | bottleneck"
             " | MODEL/HLO flops | roofline frac | arg+temp GB/chip |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(rows.items()):
        t = r["roofline"]
        m = r.get("memory", {})
        gb = (m.get("argument_size_in_bytes", 0)
              + m.get("temp_size_in_bytes", 0)) / 1e9
        lines.append(
            f"| {arch} | {shape} | {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['bottleneck'][:-2]} "
            f"| {(r.get('useful_ratio') or 0):.2f} | {fraction(r):.3f} "
            f"| {gb:.1f} |")
    return "\n".join(lines)


def skipped_table(jsonl: str) -> str:
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for line in Path(jsonl).read_text().splitlines():
        r = json.loads(line)
        if r.get("status") == "skipped" and (r["arch"], r["shape"]) not in seen:
            seen.add((r["arch"], r["shape"]))
            lines.append(f"| {r['arch']} | {r['shape']} | {r['reason']} |")
    return "\n".join(lines)


def multi_pod_summary(jsonl: str) -> str:
    p1 = load(jsonl, "pod1")
    p2 = load(jsonl, "pod2")
    ok = sorted(set(p1) & set(p2))
    lines = ["| arch | shape | pod1 compile_s | pod2 compile_s | "
             "pod2 collective_s | pod-axis sharded |",
             "|---|---|---|---|---|---|"]
    for key in ok:
        a, b = p1[key], p2[key]
        lines.append(f"| {key[0]} | {key[1]} | {a['compile_s']} | "
                     f"{b['compile_s']} | {b['roofline']['collective_s']:.2e} "
                     f"| yes (512 chips) |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    jsonl = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_opt.jsonl"
    print(md_table(jsonl))
