"""Real-plane serving driver: FMplex end-to-end on a CPU-scale backbone.

Boots one FMplexServer with a shared backbone, binds N tasks (each with its
own decoder head + LoRA adapter), replays a Poisson workload through BFQ, and
prints per-task latency + fairness.

  PYTHONPATH=src python -m repro.launch.serve --tasks 4 --rps 20 --seconds 5
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM
from repro.core.request import Request, SLO
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions
from repro.serving.metrics import jain_fairness, latency_stats


def build_server(n_tasks: int, *, arch: str = "moment-large", seed: int = 0,
                 scheduler: str = "bfq", input_len: int = 32,
                 weights=None, slo_s: float | None = 1.0):
    cfg = reduced(get_config(arch))
    fm = PhysicalFM(cfg, seed=seed, input_len=input_len, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4, 8))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler=scheduler)
    rng = np.random.RandomState(seed)
    for i in range(n_tasks):
        w_dec = rng.randn(cfg.d_model, 4).astype(np.float32) * 0.1
        head = (lambda w: (lambda feats: feats @ w))(w_dec)
        adapter = fm.adapters.new(f"lora{i}", seed=i)
        ext = TaskExtensions(decoder=head, adapter_id=f"lora{i}",
                             adapter_weights=None)
        w = weights[i] if weights else 1.0
        # slo_s=None binds tasks without deadlines: the serving plane now
        # ENFORCES task SLOs (shedding/cancelling infeasible work), which a
        # demo measuring cold-compile runs usually does not want
        srv.bind_task(f"task{i}", "fm0", weight=w,
                      slo=SLO(slo_s), extensions=ext)
    return srv, cfg


def run_load(srv: FMplexServer, cfg, *, rps: float, seconds: float,
             n_tasks: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    t_end = time.perf_counter() + seconds
    all_reqs = []
    next_arrival = time.perf_counter()
    while time.perf_counter() < t_end:
        now = time.perf_counter()
        if now >= next_arrival:
            tid = f"task{rng.randint(n_tasks)}"
            x = rng.randn(srv.fms['fm0'].input_len, cfg.d_model).astype(np.float32)
            r = Request(tid, now, payload=x)
            srv.on_arrival(r, now)
            all_reqs.append(r)
            next_arrival = now + rng.exponential(1.0 / rps)
        batch = srv.step("fm0")
        if batch is None:
            time.sleep(0.0005)
    # drain
    while srv.step("fm0") is not None:
        pass
    return all_reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--rps", type=float, default=40.0)
    ap.add_argument("--seconds", type=float, default=5.0)
    ap.add_argument("--scheduler", default="bfq", choices=("bfq", "stfq", "s-be"))
    ap.add_argument("--arch", default="moment-large")
    args = ap.parse_args()

    srv, cfg = build_server(args.tasks, arch=args.arch, scheduler=args.scheduler)
    prof = srv.profiles["fm0"]
    print(f"backbone={cfg.name} l(1)={prof.l(1)*1e3:.1f}ms "
          f"l({prof.b_max})={prof.l(prof.b_max)*1e3:.1f}ms b_max={prof.b_max}")
    reqs = run_load(srv, cfg, rps=args.rps, seconds=args.seconds,
                    n_tasks=args.tasks)
    done = [r for r in reqs if r.finish_time is not None]
    stats = latency_stats(done)
    shares = {f"task{i}": sum(1 for r in done if r.task_id == f"task{i}")
              for i in range(args.tasks)}
    weights = {t: srv.vfms[t].weight for t in shares}
    print(f"served {stats['n']}/{len(reqs)} mean={stats['mean_ms']:.1f}ms "
          f"p99={stats['p99_ms']:.1f}ms fairness={jain_fairness(shares, weights):.3f}")


if __name__ == "__main__":
    main()
