"""Task pipeline (paper §4.3, Listings 1–2): compose encoder + vFM(+adapter)
+ decoder; fine-tune extensions with the backbone frozen; package artifacts.
"""
from __future__ import annotations

from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim.adamw import AdamW
from repro.taskapi.interfaces import Adapter, Decoder, Encoder, vFM


class Pipeline:
    def __init__(self, vfm: vFM, task_id: str = "task0", seed: int = 0):
        self.vfm = vfm
        self.task_id = task_id
        self.encoder: Optional[Encoder] = None
        self.decoder: Optional[Decoder] = None
        self.adapter: Optional[Adapter] = None
        self._rng = jax.random.PRNGKey(seed)
        self.state: dict = {"encoder": {}, "decoder": {}, "adapter": None}

    def _split(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    # ---- composition (Table 1) ----
    def add_encoder(self, enc: Encoder):
        self.encoder = enc
        self.state["encoder"] = enc.init(self._split())
        return self

    def add_decoder(self, dec: Decoder):
        self.decoder = dec
        self.state["decoder"] = dec.init(self._split())
        return self

    def attach_adapter(self, adapter: Adapter):
        self.adapter = adapter
        self.state["adapter"] = adapter.init(self._split(), self.vfm.cfg)
        return self

    def remove_adapter(self, adapter_id: str | None = None):
        self.adapter = None
        self.state["adapter"] = None
        return self

    # ---- inference ----
    def _forward(self, ext_params, x):
        e = self.encoder.apply(ext_params["encoder"], x) if self.encoder else x
        e = e.astype(jnp.float32)
        feats = self.vfm.run(e, lora_tree=ext_params.get("adapter"))
        y = self.decoder.apply(ext_params["decoder"], feats.astype(jnp.float32)) \
            if self.decoder else feats
        return y

    def run(self, x):
        return self._forward(self.state, jnp.asarray(x))

    # ---- fine-tuning (backbone frozen) ----
    def train(self, data: Iterable, *, steps: int = 50, lr: float = 1e-3,
              parts_to_train=("encoder", "decoder", "adapter"),
              loss: str = "mse", verbose: bool = False) -> list[float]:
        train_parts = {k: v for k, v in self.state.items()
                       if k in parts_to_train and v is not None}
        frozen = {k: v for k, v in self.state.items() if k not in train_parts}

        def loss_fn(tp, x, y):
            ext = {**frozen, **tp}
            pred = self._forward(ext, x)
            if loss == "mse":
                return jnp.mean((pred - y) ** 2)
            logp = jax.nn.log_softmax(pred, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

        opt = AdamW(lr=lr, weight_decay=0.0)
        opt_state = opt.init(train_parts)
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        losses = []
        it = iter(data)
        for step in range(steps):
            try:
                x, y = next(it)
            except StopIteration:
                it = iter(data)
                x, y = next(it)
            l, g = grad_fn(train_parts, jnp.asarray(x), jnp.asarray(y))
            train_parts, opt_state, _ = opt.update(g, opt_state, train_parts)
            losses.append(float(l))
            if verbose and step % 10 == 0:
                print(f"step {step}: loss {l:.4f}")
        self.state.update(train_parts)
        return losses

    # ---- deployment artifact ----
    def package(self, *, weight: float = 1.0, slo_s: float | None = None,
                demand_rps: float = 1.0) -> dict:
        from repro.taskapi.artifacts import package_pipeline
        return package_pipeline(self, weight=weight, slo_s=slo_s,
                                demand_rps=demand_rps)
