"""Deployment artifacts: the unit FMplex-Controller consumes (paper §4.3).

An artifact = pipeline spec + extension weights + task metadata (backbone id,
fair-share weight, SLO, expected demand). Serialized as npz + JSON-compatible
metadata so artifacts survive process/server boundaries.
"""
from __future__ import annotations

import io
import json
from typing import Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    if tree is None:
        return flat
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def package_pipeline(pipeline, *, weight: float = 1.0,
                     slo_s: Optional[float] = None,
                     demand_rps: float = 1.0) -> dict:
    meta = {
        "task_id": pipeline.task_id,
        "backbone": pipeline.vfm.cfg.name,
        "weight": weight,
        "slo_s": slo_s,
        "demand_rps": demand_rps,
        "adapter_id": (pipeline.adapter.adapter_id if pipeline.adapter else None),
        "adapter_rank": (pipeline.adapter.rank if pipeline.adapter else None),
        "has_encoder": pipeline.encoder is not None,
        "has_decoder": pipeline.decoder is not None,
    }
    return {
        "meta": meta,
        "encoder_weights": _flatten(pipeline.state.get("encoder")),
        "decoder_weights": _flatten(pipeline.state.get("decoder")),
        "adapter_tree": pipeline.state.get("adapter"),   # pytree (in-process)
        "encoder": pipeline.encoder,
        "decoder": pipeline.decoder,
    }


def serialize(artifact: dict) -> bytes:
    """npz-serialize weights + JSON metadata (wire format)."""
    buf = io.BytesIO()
    arrays = {}
    for k, v in artifact["encoder_weights"].items():
        arrays[f"enc/{k}"] = v
    for k, v in artifact["decoder_weights"].items():
        arrays[f"dec/{k}"] = v
    for k, v in _flatten(artifact["adapter_tree"]).items():
        arrays[f"ada/{k}"] = v
    arrays["__meta__"] = np.frombuffer(
        json.dumps(artifact["meta"]).encode(), dtype=np.uint8)
    np.savez_compressed(buf, **arrays)
    return buf.getvalue()


def deserialize(blob: bytes) -> dict:
    data = np.load(io.BytesIO(blob), allow_pickle=False)
    meta = json.loads(bytes(data["__meta__"]).decode())
    groups = {"enc": {}, "dec": {}, "ada": {}}
    for k in data.files:
        if k == "__meta__":
            continue
        g, rest = k.split("/", 1)
        groups[g][rest] = data[k]
    return {"meta": meta, "encoder_weights": groups["enc"],
            "decoder_weights": groups["dec"], "adapter_weights": groups["ada"]}


def task_spec(artifact: dict) -> dict:
    """Controller-facing task descriptor from an artifact."""
    m = artifact["meta"]
    return {"task_id": m["task_id"], "backbone": m["backbone"],
            "weight": m["weight"], "slo_s": m["slo_s"],
            "demand_rps": m["demand_rps"], "adapter_id": m["adapter_id"]}
