"""Reference Task-API extensions (paper Listing 1/2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.taskapi.interfaces import Decoder, Encoder


class LinearChannelCombiner(Encoder):
    """Multichannel time series -> patch embeddings.

    (B, T, C) --channel combine--> (B, T, C') --patchify--> (B, T/P, P·C')
    --linear--> (B, S, d_model). The paper's MOMENT encoder example.
    """

    def __init__(self, num_channels: int, new_num_channels: int,
                 patch: int, d_model: int):
        self.c_in, self.c_out, self.patch, self.d = \
            num_channels, new_num_channels, patch, d_model

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {
            "combine": jax.random.normal(k1, (self.c_in, self.c_out)) / self.c_in ** 0.5,
            "proj": jax.random.normal(
                k2, (self.patch * self.c_out, self.d)) / (self.patch * self.c_out) ** 0.5,
        }

    def apply(self, p, x):
        B, T, C = x.shape
        x = x @ p["combine"]                                   # (B, T, C')
        S = T // self.patch
        x = x[:, : S * self.patch].reshape(B, S, self.patch * self.c_out)
        return x @ p["proj"]                                   # (B, S, d)


class IdentityEncoder(Encoder):
    def apply(self, p, x):
        return x


class MLPDecoder(Decoder):
    """Pooled features -> task output (classification logits / regression)."""

    def __init__(self, input_dim: int, hidden_dim: int, output_dim: int):
        self.i, self.h, self.o = input_dim, hidden_dim, output_dim

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {
            "w1": jax.random.normal(k1, (self.i, self.h)) / self.i ** 0.5,
            "b1": jnp.zeros((self.h,)),
            "w2": jax.random.normal(k2, (self.h, self.o)) / self.h ** 0.5,
            "b2": jnp.zeros((self.o,)),
        }

    def apply(self, p, feats):
        x = feats.mean(axis=1) if feats.ndim == 3 else feats   # pool (B, d)
        x = jax.nn.gelu(x @ p["w1"] + p["b1"])
        return x @ p["w2"] + p["b2"]


class LinearDecoder(Decoder):
    def __init__(self, input_dim: int, output_dim: int):
        self.i, self.o = input_dim, output_dim

    def init(self, rng):
        return {"w": jax.random.normal(rng, (self.i, self.o)) / self.i ** 0.5,
                "b": jnp.zeros((self.o,))}

    def apply(self, p, feats):
        x = feats.mean(axis=1) if feats.ndim == 3 else feats
        return x @ p["w"] + p["b"]
