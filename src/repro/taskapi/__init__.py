from repro.taskapi.artifacts import deserialize, package_pipeline, serialize, task_spec
from repro.taskapi.interfaces import Adapter, Decoder, Encoder, vFM
from repro.taskapi.modules import (IdentityEncoder, LinearChannelCombiner,
                                   LinearDecoder, MLPDecoder)
from repro.taskapi.pipeline import Pipeline
