"""Task-API interfaces (paper Table 1): Encoder / Decoder / Adapter / vFM.

Pure-JAX module convention: a module instance holds hyperparameters; its
parameters are an explicit pytree (``init`` creates them, ``apply`` consumes
them) so pipelines can freeze the backbone and train only extensions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import lm


class Module:
    """Base for task extensions."""

    def init(self, rng) -> dict:
        return {}

    def apply(self, params, x):
        raise NotImplementedError

    def run(self, params, x):      # paper naming
        return self.apply(params, x)


class Encoder(Module):
    """Input-side adaptation: raw request -> backbone embeddings (B, S, d)."""


class Decoder(Module):
    """Task head: backbone features -> task output."""


class Adapter:
    """PEFT adapter attached to the vFM backbone (LoRA on q/v projections)."""

    def __init__(self, rank: int = 16, adapter_id: str = "adapter0"):
        self.rank = rank
        self.adapter_id = adapter_id

    def init(self, rng, cfg: ModelConfig):
        from repro.models import lora
        return lora.init_single_adapter(rng, cfg, self.rank)


class vFM:
    """Task-side handle to a (virtual) foundation model.

    Locally backed by a real backbone copy for fine-tuning; at deployment the
    artifact binds to a *shared* physical FM — the task keeps the same logical
    view (paper §4.1).
    """

    def __init__(self, backbone: str | ModelConfig, *, seed: int = 0,
                 params=None):
        self.cfg = backbone if isinstance(backbone, ModelConfig) \
            else get_config(backbone)
        self.params = params if params is not None \
            else lm.init_model(jax.random.PRNGKey(seed), self.cfg)

    def run(self, embeds, lora_tree=None):
        """Backbone features for a batch of embeddings (B, S, d) -> (B, S, d)."""
        aidx = None
        if lora_tree is not None:
            aidx = jnp.zeros((embeds.shape[0],), jnp.int32)
        feats, _, _ = lm.forward(self.params, self.cfg, embeds=embeds,
                                 lora=lora_tree, adapter_idx=aidx)
        return feats
