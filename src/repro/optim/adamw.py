"""AdamW + global-norm clipping + cosine schedule (pure JAX, optax-style API)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(z, params), jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g, state.v, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
