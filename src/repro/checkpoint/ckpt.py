"""Sharded checkpointing with async save and cross-mesh (elastic) restore.

Format: one ``.npz`` per save step holding every leaf (path-keyed) + a JSON
manifest (step, tree structure, dtypes). Restore ``device_put``s each leaf
with the *target* mesh's NamedSharding — the mesh/topology at restore time may
differ from save time (elastic scaling / failure recovery), which is what
"cross-mesh restore" means here: resharding happens on load, not save.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str | Path, step: int, tree, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Write checkpoint-<step>.npz (+ .meta.json). Async if blocking=False."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    # pull to host synchronously (cheap vs disk IO); IO itself can be async.
    # bf16 has no portable npy representation -> store as f32 (lossless).
    def to_host(v):
        a = np.asarray(v)
        return a.astype(np.float32) if a.dtype.name == "bfloat16" else a
    host = {k: to_host(v) for k, v in flat.items()}
    meta = {"step": step, "time": time.time(),
            "keys": sorted(host), "nbytes": int(sum(a.nbytes for a in host.values()))}

    def _write():
        tmp = ckpt_dir / f".tmp-{step}.npz"
        np.savez(tmp, **host)
        (ckpt_dir / f"checkpoint-{step}.meta.json").write_text(json.dumps(meta))
        os.replace(tmp, ckpt_dir / f"checkpoint-{step}.npz")  # atomic publish

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for f in ckpt_dir.iterdir()
             if (m := re.match(r"checkpoint-(\d+)\.npz$", f.name))]
    return max(steps) if steps else None


def save_snapshot(path: str | Path, snap) -> Path:
    """Persist an ``EngineSnapshot`` (core.spill) to ``<path>.npz`` +
    ``<path>.meta.json`` with the same atomic-publish discipline as ``save``.
    The snapshot's host spill arena is deliberately NOT serialized — it is a
    RAM cache whose misses fall back to recompute, so a cross-process restore
    starts with an empty one and loses nothing but warm-up time."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays, meta = snap.to_host_payload()
    tmp = path.parent / f".tmp-{path.name}.npz"
    np.savez(tmp, **arrays)
    path.with_suffix(path.suffix + ".meta.json").write_text(json.dumps(meta))
    out = path.with_suffix(path.suffix + ".npz")
    os.replace(tmp, out)            # atomic publish
    return out


def load_snapshot(path: str | Path):
    """Load an ``EngineSnapshot`` written by ``save_snapshot``. Digest
    verification happens in ``DecodeEngine.restore``, not here — a snapshot
    corrupted on disk restores with its bad pages dropped and their streams
    requeued, never with poisoned KV."""
    from repro.core.spill import EngineSnapshot
    path = Path(path)
    data = np.load(path.with_suffix(path.suffix + ".npz"))
    meta = json.loads(
        path.with_suffix(path.suffix + ".meta.json").read_text())
    return EngineSnapshot.from_host_payload(
        {k: data[k] for k in data.files}, meta)


def restore(ckpt_dir: str | Path, tree_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``. ``shardings``: matching
    tree of NamedSharding for the CURRENT mesh (cross-mesh restore), or None
    for plain host arrays."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(ckpt_dir / f"checkpoint-{step}.npz")
    flat_keys = list(_flatten(tree_like))
    missing = [k for k in flat_keys if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None \
        else [None] * len(leaves_p)
    out = []
    for (path, like), sh in zip(leaves_p, sh_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(data[key])
        if np.dtype(like.dtype).name != arr.dtype.name:
            arr = jax.numpy.asarray(arr).astype(like.dtype)  # handles bf16
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
