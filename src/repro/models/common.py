"""Minimal pure-JAX module system.

Models are described as pytrees of ``ParamSpec`` (shape + logical axes + init).
From one spec tree we derive:
  * ``init_params``    — materialized arrays (smoke tests, real serving)
  * ``shape_structs``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no allocation)
  * ``logical_axes``   — same-structure tree of logical axis name tuples, consumed by
                         ``repro.sharding`` to build PartitionSpecs.

No flax dependency; everything is explicit pytrees + pure functions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                  # logical axis names per dim (None = replicated dim)
    init: str = "normal"         # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_params(rng: jax.Array, spec_tree, dtype=None):
    """Materialize a spec tree into arrays. ``dtype`` overrides spec dtype."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, rngs):
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dt))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
            scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
            out.append((jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def shape_structs(spec_tree, dtype=None):
    """ShapeDtypeStruct tree for dry-run lowering — never touches device memory."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        spec_tree, is_leaf=_is_spec)


def logical_axes(spec_tree):
    """Tree of logical-axis tuples, same structure as the param tree."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def param_bytes(spec_tree, bytes_per_el=4) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) * bytes_per_el for s in leaves)


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading stacking dim (for lax.scan over layer periods)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale, s.dtype),
        spec_tree, is_leaf=_is_spec)


class ShardCtx:
    """Sharding-constraint injector threaded through model code.

    ``shard(x, ("batch", None, "heads"))`` applies a with_sharding_constraint
    derived from logical-axis rules when a mesh is active, else is a no-op
    (CPU smoke tests).
    """

    def __init__(self, rules=None, mesh=None):
        self.rules = rules
        self.mesh = mesh

    def __call__(self, x, axes):
        if self.rules is None or self.mesh is None:
            return x
        from repro.sharding.rules import spec_for  # local import to avoid cycle
        spec = spec_for(self.rules, axes, self.mesh, jnp.shape(x))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


NO_SHARD = ShardCtx()
