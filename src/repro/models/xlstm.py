"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, exp gating).

Both are implemented in their exact recurrent form via ``lax.scan`` over time —
the same code path serves train/prefill (full sequence) and decode (S=1 with a
carried state), which is what makes xLSTM the O(1)-per-token arch that the
``long_500k`` cell exercises. States are stabilized in log space per the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def _di(cfg) -> int:
    return int(cfg.xlstm_proj_factor * cfg.d_model)


def _chunked_scan(step, state, xs, S: int, chunk: int = 64):
    """Time scan in remat'ed chunks: the backward pass keeps only per-chunk
    boundary states alive instead of one (B,H,hd,hd) matrix memory per step —
    without this, 4k-step training saves ~40 GB of states per device."""
    c = min(S, chunk)
    while S % c:
        c -= 1
    n = S // c
    xs_c = jax.tree.map(lambda a: a.reshape((n, c) + a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_step(st, xc):
        return jax.lax.scan(step, st, xc)

    state, ys = jax.lax.scan(chunk_step, state, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((n * c,) + a.shape[2:]), ys)
    return state, ys


# ---------------- mLSTM ----------------

def mlstm_spec(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    di = _di(cfg)
    hd = di // h
    return {
        "up": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "wq": ParamSpec((di, h, hd), ("mlp", "heads", None)),
        "wk": ParamSpec((di, h, hd), ("mlp", "heads", None)),
        "wv": ParamSpec((di, h, hd), ("mlp", "heads", None)),
        "wif": ParamSpec((di, 2 * h), ("mlp", None), scale=0.1),
        "b_if": ParamSpec((2 * h,), (None,), init="zeros"),
        "down": ParamSpec((di, d), ("mlp", "embed")),
    }


def mlstm_init_state(cfg, batch):
    h = cfg.num_heads
    hd = _di(cfg) // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_forward(p, x, cfg, shard, state=None, seq_lens=None):
    """x: (B, S, d) -> (y, state'). Exact recurrence, scan over S.

    ``seq_lens`` (B,) makes the scan variable-length for right-padded rows:
    a per-timestep validity mask carries every state leaf through pad
    positions unchanged, so the returned state is exactly the state at each
    row's true length (pad-position outputs are garbage and discarded)."""
    B, S, d = x.shape
    h = cfg.num_heads
    di = _di(cfg)
    hd = di // h
    dt = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("batch", None, "mlp"))
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q = jnp.einsum("bsd,dhk->bshk", xin, p["wq"].astype(dt)).astype(jnp.float32)
    k = (jnp.einsum("bsd,dhk->bshk", xin, p["wk"].astype(dt)).astype(jnp.float32) * scale)
    v = jnp.einsum("bsd,dhk->bshk", xin, p["wv"].astype(dt)).astype(jnp.float32)
    ifl = (jnp.einsum("bsd,dg->bsg", xin, p["wif"].astype(dt)).astype(jnp.float32)
           + p["b_if"].astype(jnp.float32))
    i_log, f_raw = jnp.split(ifl, 2, axis=-1)              # (B, S, H)
    f_log = -jax.nn.softplus(-f_raw)                       # log sigmoid(f)

    if state is None:
        state = mlstm_init_state(cfg, B)

    ok = None if seq_lens is None else \
        jnp.arange(S)[:, None] < seq_lens[None, :]         # (S, B)

    def step(st, t):
        qt, kt, vt, il, fl, okt = t                        # (B,H,hd) ×3, (B,H) ×2
        m_new = jnp.maximum(fl + st["m"], il)
        i_g = jnp.exp(il - m_new)[..., None]               # (B,H,1)
        f_g = jnp.exp(fl + st["m"] - m_new)[..., None]
        C = f_g[..., None] * st["C"] + i_g[..., None] * (vt[..., :, None] * kt[..., None, :])
        n = f_g * st["n"] + i_g * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), jnp.exp(-m_new))
        st2 = {"C": C, "n": n, "m": m_new}
        if okt is not None:
            sel = lambda a, b: jnp.where(
                okt.reshape((B,) + (1,) * (a.ndim - 1)), a, b)
            st2 = {k2: sel(st2[k2], st[k2]) for k2 in st2}
        return st2, num / den[..., None]

    state, hs = _chunked_scan(step, state,
                              (q.swapaxes(0, 1), k.swapaxes(0, 1),
                               v.swapaxes(0, 1), i_log.swapaxes(0, 1),
                               f_log.swapaxes(0, 1), ok), S)
    y = hs.swapaxes(0, 1).reshape(B, S, di).astype(dt)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["down"].astype(dt)), state


# ---------------- sLSTM ----------------

def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    di = _di(cfg)
    return {
        "up": ParamSpec((d, di), ("embed", "mlp")),
        "w": ParamSpec((di, 4 * di), ("mlp", None), scale=0.05),
        "r": ParamSpec((di, 4 * di), ("mlp", None), scale=0.05),
        "b": ParamSpec((4 * di,), (None,), init="zeros"),
        "down": ParamSpec((di, d), ("mlp", "embed")),
    }


def slstm_init_state(cfg, batch):
    di = _di(cfg)
    z = lambda: jnp.zeros((batch, di), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, di), -1e30, jnp.float32)}


def slstm_forward(p, x, cfg, shard, state=None, seq_lens=None):
    """x: (B, S, d) -> (y, state'). Inherently sequential (recurrent h).
    ``seq_lens`` (B,): variable-length scan for right-padded rows — state
    leaves (including the recurrent ``h``) carry through pad positions
    unchanged, see ``mlstm_forward``."""
    B, S, d = x.shape
    di = _di(cfg)
    dt = x.dtype
    xin = jnp.einsum("bsd,de->bse", x, p["up"].astype(dt))
    xin = shard(xin, ("batch", None, "mlp"))
    wx = (jnp.einsum("bsd,dg->bsg", xin, p["w"].astype(dt)).astype(jnp.float32)
          + p["b"].astype(jnp.float32))
    r = p["r"].astype(jnp.float32)
    if state is None:
        state = slstm_init_state(cfg, B)

    ok = None if seq_lens is None else \
        jnp.arange(S)[:, None] < seq_lens[None, :]         # (S, B)

    def step(st, t):
        wxt, okt = t
        gates = wxt + st["h"] @ r                          # (B, 4di)
        zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)
        zt = jnp.tanh(zi)
        ot = jax.nn.sigmoid(oi)
        f_log = -jax.nn.softplus(-fi)
        m_new = jnp.maximum(f_log + st["m"], ii)
        i_g = jnp.exp(ii - m_new)
        f_g = jnp.exp(f_log + st["m"] - m_new)
        c = f_g * st["c"] + i_g * zt
        n = jnp.maximum(f_g * st["n"] + i_g, 1e-6)
        h = ot * c / n
        st2 = {"c": c, "n": n, "h": h, "m": m_new}
        if okt is not None:
            st2 = {k2: jnp.where(okt[:, None], st2[k2], st[k2])
                   for k2 in st2}
        return st2, h

    state, hs = _chunked_scan(step, state, (wx.swapaxes(0, 1), ok), S)
    y = hs.swapaxes(0, 1).astype(dt)
    return jnp.einsum("bsd,de->bse", y, p["down"].astype(dt)), state
