"""Mamba (selective SSM) block for Jamba's hybrid stack.

TPU adaptation: the recurrence is evaluated chunkwise — ``lax.scan`` over
sequence chunks carrying the SSM state, with the full (chunk × d_state) update
materialized per step. This bounds the lowered temp footprint (the naive
associative-scan form materializes B×S×d_in×d_state states, which fails
memory_analysis at 4k×8k-wide configs) while keeping per-chunk compute dense
for the MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def mamba_spec(cfg) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((dc, di), (None, "mlp"), scale=0.5),
        "conv_b": ParamSpec((di,), ("mlp",), init="zeros"),
        "x_bc": ParamSpec((di, 2 * ds), ("mlp", None)),
        "x_dt": ParamSpec((di, 1), ("mlp", None)),
        "dt_bias": ParamSpec((di,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((di, ds), ("mlp", None), init="zeros"),
        "D": ParamSpec((di,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _ssm_chunk(x, dt, B, C, A, D, h0):
    """Sequential scan over one chunk. x/dt: (T, di); B/C: (T, ds); h0: (di, ds)."""
    dA = jnp.exp(dt[:, :, None] * A[None])                 # (T, di, ds)
    dBx = dt[:, :, None] * B[:, None, :] * x[:, :, None]   # (T, di, ds)

    def step(h, t):
        dA_t, dBx_t = t
        h = h * dA_t + dBx_t
        return h, h

    hT, hs = jax.lax.scan(step, h0, (dA, dBx))
    y = jnp.einsum("tds,ts->td", hs, C) + x * D[None]
    return y, hT


def mamba_forward(p, x, cfg, shard, conv_state=None, ssm_state=None,
                  chunk: int = 128, seq_lens=None):
    """x: (B, S, d). Returns (y, (conv_state, ssm_state)) — states are the
    decode cache. Prefill/train: pass states=None.

    ``seq_lens`` (B,) makes the scan variable-length for right-padded rows:
    ``dt`` is zeroed at pad positions, so ``dA = exp(0·A) = 1`` and
    ``dBx = 0`` — the SSM update is an exact identity through the padding —
    and the returned conv state is gathered per row at the TRUE length
    instead of the bucket tail. Outputs at pad positions are garbage (the
    caller discards them); valid positions are bit-identical to an unpadded
    run because the conv window and the recurrence are causal."""
    Bsz, S, d = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dt_x = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_x))
    xin, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di) each
    xin = shard(xin, ("batch", None, "mlp"))

    # causal depthwise conv (width dc)
    if conv_state is None:
        conv_state = jnp.zeros((Bsz, dc - 1, di), dt_x)
    xpad = jnp.concatenate([conv_state, xin], axis=1)      # (B, S+dc-1, di)
    if dc <= 1:
        new_conv_state = conv_state
    elif seq_lens is None:
        new_conv_state = xpad[:, -(dc - 1):]
    else:
        # per-row: the dc-1 inputs PRECEDING each row's true end live at
        # xpad[b, L_b : L_b + dc-1] (L_b == S reduces to the slice above)
        idx = seq_lens[:, None] + jnp.arange(dc - 1)[None, :]
        new_conv_state = jnp.take_along_axis(xpad, idx[:, :, None], axis=1)
    w = p["conv_w"].astype(dt_x)
    xc = sum(xpad[:, i:i + S] * w[i][None, None] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"].astype(dt_x))

    bc = jnp.einsum("bsd,dn->bsn", xc, p["x_bc"].astype(dt_x)).astype(jnp.float32)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # (B, S, ds)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bs", xc, p["x_dt"].astype(dt_x)).astype(jnp.float32)[..., None]
        + p["dt_bias"].astype(jnp.float32))                # (B, S, di)
    if seq_lens is not None:
        valid = jnp.arange(S)[None, :] < seq_lens[:, None]  # (B, S)
        dt = jnp.where(valid[:, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (di, ds)
    D = p["D"].astype(jnp.float32)

    if ssm_state is None:
        ssm_state = jnp.zeros((Bsz, di, ds), jnp.float32)

    c = min(S, chunk)
    while S % c:
        c -= 1
    n = S // c

    def batch_row(xr, dtr, Br, Cr, h0):
        @jax.checkpoint
        def step(h, t):
            # remat per chunk: the backward pass recomputes the in-chunk state
            # trajectory instead of saving (c, di, d_state) tensors per chunk
            xt, dtt, Bt, Ct = t
            y, h = _ssm_chunk(xt, dtt, Bt, Ct, A, D, h)
            return h, y
        hT, ys = jax.lax.scan(
            step, h0,
            (xr.reshape(n, c, di).astype(jnp.float32), dtr.reshape(n, c, di),
             Br.reshape(n, c, ds), Cr.reshape(n, c, ds)))
        return ys.reshape(S, di), hT

    y, hT = jax.vmap(batch_row)(xc, dt, Bm, Cm, ssm_state)
    y = y.astype(dt_x) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(dt_x))
    return out, (new_conv_state, hT)


def mamba_decode(p, x, cfg, shard, conv_state, ssm_state):
    """One-step decode. x: (B, 1, d); conv_state: (B, dc-1, di); ssm: (B, di, ds)."""
    return mamba_forward(p, x, cfg, shard, conv_state=conv_state,
                         ssm_state=ssm_state, chunk=1)
