"""Shared layers: norms, embeddings, RoPE / M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


# ---------------- norms ----------------

def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


@jax.custom_jvp
def _grad_transparent_barrier(x):
    # optimization_barrier has no differentiation rule; it is semantically the
    # identity, so expose it to autodiff as one (identity tangent, and the
    # linear tangent rule transposes to an identity cotangent for reverse mode)
    return jax.lax.optimization_barrier(x)


@_grad_transparent_barrier.defjvp
def _grad_transparent_barrier_jvp(primals, tangents):
    return _grad_transparent_barrier(primals[0]), tangents[0]


def rmsnorm(w, x, eps: float = 1e-5):
    dt = x.dtype
    # the barrier pins the residual stream (and the TP psum feeding it) to its
    # storage dtype: without it XLA hoists this f32 convert above the
    # all-reduce, doubling every TP collective (§Perf iteration 1)
    x = _grad_transparent_barrier(x)
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ---------------- embeddings ----------------

def embed_spec(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)


def embed(tok_w, tokens):
    return jnp.take(tok_w, tokens, axis=0)


def head_spec(d: int, vocab: int) -> ParamSpec:
    return ParamSpec((d, vocab), ("embed", "vocab"))


# ---------------- RoPE ----------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, pos, theta: float = 10000.0):
    """x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                        # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, pos3, sections, theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); pos3: (B, S, 3) — temporal/height/width position streams.
    ``sections`` (e.g. (16, 24, 24)) partitions the hd/2 rotary frequencies,
    each partition rotated by its own position stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    # section id per frequency
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=hd // 2)     # (hd/2,)
    pos_per_freq = jnp.take_along_axis(
        pos3.astype(jnp.float32),                        # (B, S, 3)
        jnp.broadcast_to(sec_id, pos3.shape[:-1] + (hd // 2,)).astype(jnp.int32),
        axis=-1)                                         # (B, S, hd/2)
    angles = (pos_per_freq * freqs)[..., None, :]        # (B, S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
