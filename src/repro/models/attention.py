"""GQA attention: chunked flash-style prefill (memory-sane lowering) + decode.

The prefill path is a pure-jnp online-softmax flash attention (lax.scan over KV
chunks nested in a scan over Q chunks). It is (a) the reference oracle for the
Pallas kernel in ``repro.kernels.flash_attention`` and (b) what the dry-run
lowers — naive (S×S)-materializing attention would blow past HBM in
memory_analysis() at 32k.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------- specs ----------------

def attn_spec(cfg, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        spec["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return spec


# ---------------- core flash attention (jnp reference) ----------------

def _chunk(n: int, target: int) -> int:
    c = min(n, target)
    while n % c:
        c -= 1
    return c


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024, kv_len=None,
                    prefix_k=None, prefix_v=None, prefix_len=None):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0 (GQA).
    Returns (B, Sq, H, hd). Q/K positions are aligned at the end (standard
    causal self-attention when Sq == Sk; for Sq < Sk, q is the suffix).

    ``kv_len``: optional (B,) per-row valid key count — key positions at or
    beyond a row's length are masked out (right-padded variable-length
    prefill). Query rows past the length still see a non-empty causal
    window, so their (discarded) outputs stay finite.

    ``prefix_k``/``prefix_v``: optional (B, Sp, KV, hd) precomputed prefix
    K/V prepended before ``k``/``v`` on the key axis (chunked shared-prefix
    prefill: the tail's queries attend to dequantized prefix pages that were
    never part of this dispatch's QKV projection). ``prefix_len`` (B,) gives
    each row's true prefix length — positions at or beyond it are masked out
    (bucket-padded prefix tables point the slack at the trash page). Queries
    sit causally AFTER the whole prefix: q position 0 is absolute position
    ``prefix_len``, so every valid prefix key is visible to every query.
    """
    B, Sq, H, hd = q.shape
    Sk_new = k.shape[1]
    KV = k.shape[2]
    if prefix_k is not None:
        assert prefix_v is not None and prefix_len is not None
        assert window is None, "sliding window over a prefix is unsupported"
        Sp = prefix_k.shape[1]
        k = jnp.concatenate([prefix_k.astype(k.dtype), k], axis=1)
        v = jnp.concatenate([prefix_v.astype(v.dtype), v], axis=1)
        tail_ok = (jnp.arange(Sk_new)[None] < kv_len[:, None]
                   if kv_len is not None
                   else jnp.ones((B, Sk_new), jnp.bool_))
        key_valid = jnp.concatenate(
            [jnp.arange(Sp)[None] < prefix_len[:, None], tail_ok], axis=1)
    elif kv_len is not None:
        key_valid = jnp.arange(Sk_new)[None] < kv_len[:, None]
    else:
        key_valid = None
    _, Sk, KV, _ = k.shape
    G = H // KV
    qc = _chunk(Sq, q_chunk)
    kc = _chunk(Sk, kv_chunk)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_off = Sk - Sq  # absolute position offset of q block

    # operands stay in their storage dtype (bf16 on TPU); the MXU accumulates
    # in f32 via preferred_element_type — avoids materializing f32 copies of
    # Q/K/V, which would double HBM traffic (§Perf iteration 2)
    qr = q.reshape(B, Sq // qc, qc, KV, G, hd)
    kr = k.reshape(B, Sk // kc, kc, KV, hd)
    vr = v.reshape(B, Sk // kc, kc, KV, hd)

    def q_step(_, qi):
        qblk, qidx = qi                                   # (B, qc, KV, G, hd)
        q_pos = q_off + qidx * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki[:3]
            k_pos = kidx * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), jnp.bool_)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            mask = mask[None, None, None]                 # (1, 1, 1, qc, kc)
            if key_valid is not None:
                vmask = ki[3]                             # (B, kc)
                mask = mask & vmask[:, None, None, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        xs = (kr.swapaxes(0, 1), vr.swapaxes(0, 1), jnp.arange(Sk // kc))
        if key_valid is not None:
            xs = xs + (key_valid.reshape(B, Sk // kc, kc).swapaxes(0, 1),)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, KV, G, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4)          # (B, qc, KV, G, hd)

    _, chunks = jax.lax.scan(q_step, None, (qr.swapaxes(0, 1), jnp.arange(Sq // qc)))
    out = chunks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None):
    """Single-token attention against a KV cache.

    q: (B, H, hd); caches: (B, S, KV, hd); cache_len: (B,) valid lengths
    (the new token's position is cache_len - 1 after insertion).
    """
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # bf16 operands + f32 accumulation: the KV cache is streamed once in its
    # storage dtype instead of being copied to f32 first (§Perf iteration 2)
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)[None]                              # (1, S)
    mask = pos < cache_len[:, None]
    if window is not None:
        mask &= pos >= (cache_len[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------- full layer ops ----------------

def qkv_project(p, x, cfg, pos=None, pos3=None, rope: bool = True,
                lora=None, adapter_idx=None, lora_impl: str = "gather",
                lora_seg=None):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if lora is not None and adapter_idx is not None:
        from repro.models.lora import qv_lora
        q, v = qv_lora(x, lora, adapter_idx, q, v, impl=lora_impl,
                       seg=lora_seg)
    if rope:
        if cfg.mrope_sections is not None and pos3 is not None:
            q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        elif pos is not None:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def out_project(p, attn_out, dtype):
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(dtype))


def self_attention(p, x, cfg, shard, *, causal=True, pos=None, pos3=None,
                   lora=None, adapter_idx=None, lora_impl="gather",
                   lora_seg=None, seq_lens=None, prefix=None, prefix_len=None):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v)).

    ``seq_lens``: (B,) true lengths of right-padded rows — pad key positions
    are masked out of the attention (variable-length prefill admission).

    ``prefix``: optional dict(k, v) of precomputed (B, Sp, KV, hd) prefix K/V
    (dequantized shared-prefix pages, chunked prefill) that the queries attend
    to in ADDITION to this dispatch's own K/V; ``prefix_len`` (B,) true prefix
    lengths. The returned (k, v) stay tail-only — the cache stores only what
    this dispatch computed."""
    q, k, v = qkv_project(p, x, cfg, pos=pos, pos3=pos3, lora=lora,
                          adapter_idx=adapter_idx, lora_impl=lora_impl,
                          lora_seg=lora_seg)
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    v = shard(v, ("batch", None, "kv_heads", None))
    if prefix is not None:
        o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                            kv_len=seq_lens, prefix_k=prefix["k"],
                            prefix_v=prefix["v"], prefix_len=prefix_len)
    else:
        o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                            kv_len=seq_lens)
    return out_project(p, o, x.dtype), (k, v)


def cross_attention(p, x, enc_kv, cfg, shard):
    """Decoder cross-attention over precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k, v = enc_kv
    o = flash_attention(q, k.astype(x.dtype), v.astype(x.dtype), causal=False)
    return out_project(p, o, x.dtype)


def self_attention_decode(p, x, cache, cfg, shard, *, pos=None, pos3=None,
                          lora=None, adapter_idx=None, lora_impl="gather",
                          lora_seg=None):
    """One-step decode. x: (B, 1, d); cache: dict(k, v, len). Returns (out, cache').

    When the cache carries ``k_scale``/``v_scale`` it is an int8 KV pool
    (persistent decode serving, see ``core.decode_engine``): the new token's
    K/V are quantized into the scales fixed at prefill admission and attention
    runs through ``kernels.decode_attention_int8``, so the cache is only ever
    streamed as int8.
    """
    q, k, v = qkv_project(p, x, cfg, pos=pos, pos3=pos3, lora=lora,
                          adapter_idx=adapter_idx, lora_impl=lora_impl,
                          lora_seg=lora_seg)
    B = x.shape[0]
    idx = cache["len"]                                    # (B,) insert position
    bidx = jnp.arange(B)
    if "page_table" in cache:
        # paged int8 pool: the new token lands in arena page
        # page_table[b, len // ps] at offset len % ps. The FIRST token of a
        # page quantizes with the slot's admission-era running scale and
        # stamps it as the page scale (a recycled page's stale scale must
        # never leak in); later tokens reuse the page's stamped scale — for
        # a partial prompt page that is its admission per-page scale, so
        # earlier codes keep dequantizing correctly. Attention gathers K/V
        # through the page table (ops.paged_decode_attention). The slot's
        # decode-era |K|/|V| running maxima ride in ``k_max``/``v_max`` for
        # the engine's proactive scale refresh.
        ps = cache["k"].shape[1]
        page = jnp.take_along_axis(cache["page_table"],
                                   (idx // ps)[:, None], axis=1)[:, 0]
        off = idx % ps
        fresh = (off == 0)[:, None]
        ks = jnp.maximum(jnp.where(fresh, cache["slot_k_scale"],
                                   cache["k_scale"][page]), 1e-8)
        vs = jnp.maximum(jnp.where(fresh, cache["slot_v_scale"],
                                   cache["v_scale"][page]), 1e-8)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        kq = jnp.clip(jnp.round(kf / ks[:, :, None]),
                      -127, 127).astype(jnp.int8)
        vq = jnp.clip(jnp.round(vf / vs[:, :, None]),
                      -127, 127).astype(jnp.int8)
        k_pages = cache["k"].at[page, off].set(kq)
        v_pages = cache["v"].at[page, off].set(vq)
        k_sc = cache["k_scale"].at[page].set(ks)
        v_sc = cache["v_scale"].at[page].set(vs)
        k_max = jnp.maximum(cache["k_max"], jnp.max(jnp.abs(kf), axis=-1))
        v_max = jnp.maximum(cache["v_max"], jnp.max(jnp.abs(vf), axis=-1))
        from repro.kernels import ops
        o = ops.paged_decode_attention(q[:, 0], k_pages, v_pages, k_sc, v_sc,
                                       cache["page_table"], idx + 1,
                                       window=cfg.sliding_window)
        out = out_project(p, o.astype(x.dtype)[:, None], x.dtype)
        return out, {"k": k_pages, "v": v_pages, "k_scale": k_sc,
                     "v_scale": v_sc, "k_max": k_max, "v_max": v_max,
                     "len": idx + 1}
    if "k_scale" in cache:
        from repro.kernels import ops
        # scales are per (B, KV), fixed at prefill; epsilon-guard free slots
        # whose scales were never written (their rows are masked out anyway)
        ks = jnp.maximum(cache["k_scale"], 1e-8)
        vs = jnp.maximum(cache["v_scale"], 1e-8)
        kq = jnp.clip(jnp.round(k[:, 0].astype(jnp.float32) / ks[:, :, None]),
                      -127, 127).astype(jnp.int8)
        vq = jnp.clip(jnp.round(v[:, 0].astype(jnp.float32) / vs[:, :, None]),
                      -127, 127).astype(jnp.int8)
        k_cache = cache["k"].at[bidx, idx].set(kq)
        v_cache = cache["v"].at[bidx, idx].set(vq)
        o = ops.decode_attention_int8(q[:, 0], k_cache, v_cache, ks, vs,
                                      idx + 1, window=cfg.sliding_window)
        o = o.astype(x.dtype)
    else:
        k_cache = cache["k"].at[bidx, idx].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, idx].set(v[:, 0].astype(cache["v"].dtype))
        o = decode_attention(q[:, 0], k_cache, v_cache, idx + 1,
                             window=cfg.sliding_window)
    out = out_project(p, o[:, None], x.dtype)
    return out, {"k": k_cache, "v": v_cache, "len": idx + 1}


def self_attention_verify(p, x, cache, cfg, shard, *, pos=None, pos3=None,
                          lora=None, adapter_idx=None, lora_impl="gather",
                          lora_seg=None):
    """Speculative verify window: T = k+1 positions through the paged pool.

    x: (B, T, d) — position 0 embeds the slot's last sampled token (what a
    plain decode step would feed), positions 1..k the drafted continuation.
    Only the paged int8 pool is supported (speculation is gated to
    ``paged=True`` in the engine).

    Every window position's K/V is written into the slot's decode-private
    pages with EXACTLY the scale a sequential walk of T single-token steps
    would pick: a position reuses the pre-window page scale iff it lands in
    the page already holding token ``len - 1`` (only the window's first page
    can predate the window — positions are strictly increasing), otherwise
    it is the first write to a fresh page and quantizes with the slot's
    running scale, which the sequential walk stamps at ``off == 0`` and
    reuses for the rest of that page. Attention then reads each position j
    against keys ``0..len+j`` via ``ops.paged_verify_attention`` —
    bit-identical arithmetic to j+1 successive single-token steps.

    The returned cache advances by the FULL window (``len += T``) and
    carries per-position running-max stacks ``k_cmax``/``v_cmax``
    (B, T, KV) so the engine's acceptance pass can roll ``len`` /
    ``k_max`` / ``v_max`` back to each slot's commit point in-graph.
    Rejected positions' codes and fresh-page scale stamps sit past the
    rolled-back length, where the next dispatch's ``off == 0`` write
    re-stamps and overwrites them — rollback is a length/tracker reset,
    never a page free.
    """
    assert "page_table" in cache, "speculative verify requires the paged pool"
    q, k, v = qkv_project(p, x, cfg, pos=pos, pos3=pos3, lora=lora,
                          adapter_idx=adapter_idx, lora_impl=lora_impl,
                          lora_seg=lora_seg)
    B, T = x.shape[:2]
    idx = cache["len"]                                    # (B,)
    ps = cache["k"].shape[1]
    pos_abs = idx[:, None] + jnp.arange(T)[None]          # (B, T)
    page = jnp.take_along_axis(cache["page_table"], pos_abs // ps, axis=1)
    off = pos_abs % ps
    in_old = (pos_abs // ps) == ((idx - 1) // ps)[:, None]
    ks = jnp.maximum(jnp.where(in_old[..., None], cache["k_scale"][page],
                               cache["slot_k_scale"][:, None]), 1e-8)
    vs = jnp.maximum(jnp.where(in_old[..., None], cache["v_scale"][page],
                               cache["slot_v_scale"][:, None]), 1e-8)
    kf = k.astype(jnp.float32)                            # (B, T, KV, hd)
    vf = v.astype(jnp.float32)
    kq = jnp.clip(jnp.round(kf / ks[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vf / vs[..., None]), -127, 127).astype(jnp.int8)
    pf, of = page.reshape(-1), off.reshape(-1)
    k_pages = cache["k"].at[pf, of].set(kq.reshape((B * T,) + kq.shape[2:]))
    v_pages = cache["v"].at[pf, of].set(vq.reshape((B * T,) + vq.shape[2:]))
    # duplicate page indices across a row's positions carry identical scale
    # values (same page => same in_old branch), so last-write-wins is exact
    k_sc = cache["k_scale"].at[pf].set(ks.reshape(B * T, -1))
    v_sc = cache["v_scale"].at[pf].set(vs.reshape(B * T, -1))
    k_cmax = jnp.maximum(jax.lax.cummax(jnp.max(jnp.abs(kf), axis=-1), axis=1),
                         cache["k_max"][:, None])
    v_cmax = jnp.maximum(jax.lax.cummax(jnp.max(jnp.abs(vf), axis=-1), axis=1),
                         cache["v_max"][:, None])
    from repro.kernels import ops
    o = ops.paged_verify_attention(q, k_pages, v_pages, k_sc, v_sc,
                                   cache["page_table"], idx,
                                   window=cfg.sliding_window)
    out = out_project(p, o.astype(x.dtype), x.dtype)
    return out, {"k": k_pages, "v": v_pages, "k_scale": k_sc, "v_scale": v_sc,
                 "k_max": k_cmax[:, -1], "v_max": v_cmax[:, -1],
                 "k_cmax": k_cmax, "v_cmax": v_cmax, "len": idx + T}
