"""Gated (SwiGLU) feed-forward."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def mlp_spec(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
        "wi_up": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp(p, x, shard):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = shard(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
