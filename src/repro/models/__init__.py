from repro.models import attention, blocks, common, layers, lm, mamba, mlp, moe, xlstm  # noqa
