"""Config-driven block dispatcher.

A model is ``num_periods`` repetitions of a *period* — a fixed sequence of
sublayers (attention / mamba / sLSTM / mLSTM, each optionally followed by a
dense-FFN or MoE sublayer). Parameters and caches carry a leading
``num_periods`` axis and are consumed by ``lax.scan`` in ``repro.models.lm`` —
this keeps the HLO one-period-sized for 80-layer configs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import xlstm as xl
from repro.models.common import ParamSpec
from repro.models.layers import rmsnorm, rmsnorm_spec
from repro.models.mlp import mlp, mlp_spec
from repro.models.moe import moe_ffn, moe_spec


@dataclasses.dataclass(frozen=True)
class SubLayer:
    kind: str
    has_moe: bool
    has_ffn: bool
    has_cross: bool = False


def period_len(cfg: ModelConfig) -> int:
    base = len(cfg.block_pattern) if cfg.block_pattern else 1
    if cfg.uses_moe:
        base = math.lcm(base, cfg.moe_every)
    assert cfg.num_layers % base == 0, (cfg.name, cfg.num_layers, base)
    return base


def period_layout(cfg: ModelConfig, cross: bool = False) -> list[SubLayer]:
    plen = period_len(cfg)
    blocks = cfg.blocks
    out = []
    for pos in range(plen):
        kind = blocks[pos]
        has_ffn = cfg.d_ff > 0 and kind not in (MLSTM, SLSTM)
        has_moe = has_ffn and cfg._layer_has_moe(pos)
        out.append(SubLayer(kind, has_moe, has_ffn, cross))
    return out


# ---------------- specs ----------------

def sublayer_spec(cfg: ModelConfig, lay: SubLayer) -> dict:
    d = cfg.d_model
    spec: dict = {"ln1": rmsnorm_spec(d)}
    if lay.kind == ATTN:
        spec["attn"] = attn.attn_spec(cfg)
    elif lay.kind == MAMBA:
        spec["mamba"] = mam.mamba_spec(cfg)
    elif lay.kind == MLSTM:
        spec["mlstm"] = xl.mlstm_spec(cfg)
    elif lay.kind == SLSTM:
        spec["slstm"] = xl.slstm_spec(cfg)
    if lay.has_cross:
        spec["ln_x"] = rmsnorm_spec(d)
        spec["cross"] = attn.attn_spec(cfg, cross=True)
    if lay.has_ffn:
        spec["ln2"] = rmsnorm_spec(d)
        spec["ffn"] = moe_spec(cfg) if lay.has_moe else mlp_spec(cfg)
    return spec


def sublayer_cache_spec(cfg: ModelConfig, lay: SubLayer, batch: int, s_max: int,
                        enc_len: int = 0, kv_quant: bool = False,
                        paged: bool = False, page_size: int = 16,
                        num_pages: int = 0) -> Optional[dict]:
    """Decode-time cache carried per sublayer (logical axes included).

    ``kv_quant``: store self-attention K/V as int8 with per-(batch, kv-head)
    symmetric scales (persistent serving pools — halves cache traffic; scales
    are written at prefill admission). Cross-attention K/V stay bf16.

    ``paged``: the paged serving pool layout (``core.decode_engine`` with
    ``paged=True``) — instead of one dense (batch, s_max) region per slot,
    self-attention K/V live in a global arena of ``num_pages`` fixed-size
    pages shared by all slots (copy-on-write prefix sharing maps one page
    into several tables), addressed through a per-slot ``page_table``
    (int32 arena page ids; entries past a stream's length stay 0, a valid —
    masked — index). Scales are per (page, kv-head) — each page is
    quantized over its OWN content at admission, which is what makes a
    shared prefix page bit-identical regardless of which stream wrote it.
    ``slot_k_scale`` / ``slot_v_scale`` keep each slot's admission-time
    running scales so decode-era appends quantize into a consistent range
    and stamp it onto fresh pages; ``k_max`` / ``v_max`` track the slot's
    decode-era magnitude maxima for the engine's proactive scale refresh.
    ``s_max`` bounds pages per slot (the page-table width), NOT reserved
    memory: a stream only ever holds the pages its tokens occupy. int8-only
    (the arena layout exists to halve streamed bytes; a bf16 arena would
    just be a slower dense pool).
    """
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    dt = jnp.bfloat16
    kv_dt = jnp.int8 if kv_quant else dt
    if paged and lay.kind == ATTN:
        assert kv_quant and num_pages > 0, \
            "paged pools are int8 self-attention only"
        mp = -(-s_max // page_size)                 # page-table width
        spec = {
            "k": ParamSpec((num_pages, page_size, kv, hd),
                           (None, None, "kv_heads", None),
                           init="zeros", dtype=kv_dt),
            "v": ParamSpec((num_pages, page_size, kv, hd),
                           (None, None, "kv_heads", None),
                           init="zeros", dtype=kv_dt),
            "k_scale": ParamSpec((num_pages, kv), (None, "kv_heads"),
                                 init="zeros", dtype=jnp.float32),
            "v_scale": ParamSpec((num_pages, kv), (None, "kv_heads"),
                                 init="zeros", dtype=jnp.float32),
            "slot_k_scale": ParamSpec((batch, kv), ("batch", "kv_heads"),
                                      init="zeros", dtype=jnp.float32),
            "slot_v_scale": ParamSpec((batch, kv), ("batch", "kv_heads"),
                                      init="zeros", dtype=jnp.float32),
            "k_max": ParamSpec((batch, kv), ("batch", "kv_heads"),
                               init="zeros", dtype=jnp.float32),
            "v_max": ParamSpec((batch, kv), ("batch", "kv_heads"),
                               init="zeros", dtype=jnp.float32),
            "page_table": ParamSpec((batch, mp), ("batch", None),
                                    init="zeros", dtype=jnp.int32),
            "len": ParamSpec((batch,), ("batch",), init="zeros",
                             dtype=jnp.int32),
        }
        if lay.has_cross:
            # encoder–decoder: cross K/V are per-SLOT pooled state (the
            # encoder output does not grow with decode) — dense bf16
            # sidecars beside the paged self-attention arena
            spec["ck"] = ParamSpec((batch, enc_len, kv, hd),
                                   ("batch", "cache_seq", "kv_heads", None),
                                   init="zeros", dtype=dt)
            spec["cv"] = ParamSpec((batch, enc_len, kv, hd),
                                   ("batch", "cache_seq", "kv_heads", None),
                                   init="zeros", dtype=dt)
        return spec
    if lay.kind == ATTN:
        spec = {
            "k": ParamSpec((batch, s_max, kv, hd), ("batch", "cache_seq", "kv_heads", None),
                           init="zeros", dtype=kv_dt),
            "v": ParamSpec((batch, s_max, kv, hd), ("batch", "cache_seq", "kv_heads", None),
                           init="zeros", dtype=kv_dt),
            "len": ParamSpec((batch,), ("batch",), init="zeros", dtype=jnp.int32),
        }
        if kv_quant:
            spec["k_scale"] = ParamSpec((batch, kv), ("batch", "kv_heads"),
                                        init="zeros", dtype=jnp.float32)
            spec["v_scale"] = ParamSpec((batch, kv), ("batch", "kv_heads"),
                                        init="zeros", dtype=jnp.float32)
        if lay.has_cross:
            spec["ck"] = ParamSpec((batch, enc_len, kv, hd),
                                   ("batch", "cache_seq", "kv_heads", None),
                                   init="zeros", dtype=dt)
            spec["cv"] = ParamSpec((batch, enc_len, kv, hd),
                                   ("batch", "cache_seq", "kv_heads", None),
                                   init="zeros", dtype=dt)
        return spec
    di = cfg.mamba_expand * cfg.d_model
    if lay.kind == MAMBA:
        return {
            "conv": ParamSpec((batch, cfg.mamba_d_conv - 1, di), ("batch", None, "mlp"),
                              init="zeros", dtype=dt),
            "ssm": ParamSpec((batch, di, cfg.mamba_d_state), ("batch", "mlp", None),
                             init="zeros"),
        }
    dix = int(cfg.xlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    if lay.kind == MLSTM:
        return {
            "C": ParamSpec((batch, h, dix // h, dix // h), ("batch", "heads", None, None),
                           init="zeros"),
            "n": ParamSpec((batch, h, dix // h), ("batch", "heads", None), init="zeros"),
            "m": ParamSpec((batch, h), ("batch", "heads"), init="zeros"),
        }
    if lay.kind == SLSTM:
        return {k: ParamSpec((batch, dix), ("batch", "mlp"), init="zeros")
                for k in ("c", "n", "h", "m")}
    return None


# ---------------- apply ----------------

def _ffn_apply(p, x, cfg, lay, shard, seq_lens=None):
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if lay.has_moe:
        # var-len prefill: pads must not claim expert capacity — a real
        # token's routing is invariant to its admission bucket's padding
        valid = None if seq_lens is None else \
            (jnp.arange(x.shape[1])[None] < seq_lens[:, None])
        out, aux = moe_ffn(p["ffn"], h, k=cfg.experts_per_token,
                           dispatch=cfg.moe_dispatch, shard=shard,
                           valid=valid)
        return x + out, aux
    return x + mlp(p["ffn"], h, shard), 0.0


def sublayer_apply(p, x, cfg: ModelConfig, lay: SubLayer, shard, *,
                   mode: str, cache=None, pos=None, pos3=None, causal=True,
                   enc_out=None, lora=None, adapter_idx=None,
                   lora_impl: str = "gather", lora_seg=None, seq_lens=None,
                   prefix=None, prefix_len=None):
    """Apply one sublayer. mode: 'full' (train/prefill) or 'decode'.

    Returns (x, cache', aux_loss). cache' is None unless a cache was provided
    (prefill fills it; decode updates it).

    ``seq_lens``: (B,) per-row true lengths for right-padded variable-length
    prefill — pad keys are masked out of attention, pad K/V are zeroed before
    the cache write (so int8 admission scales see only real tokens), and the
    cache ``len`` is set per row instead of to the padded S.

    ``prefix``/``prefix_len``: chunked shared-prefix prefill — a dict(k, v)
    of precomputed (B, Sp, KV, hd) prefix K/V this sublayer's queries attend
    to IN FRONT of their own keys (dequantized shared pages; see
    ``attention.self_attention``). The cache fill below stores only the
    tail's K/V — the prefix already lives in the paged arena.
    """
    aux = 0.0
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)

    if lay.kind == ATTN:
        if mode == "decode":
            if pos is None:
                pos = cache["len"][:, None]                       # rope position
            if pos3 is None and cfg.mrope_sections is not None:
                pos3 = jnp.repeat(pos[..., None], 3, axis=-1)     # text: t=h=w
            out, attn_cache = attn.self_attention_decode(
                p["attn"], h, cache, cfg, shard, pos=pos, pos3=pos3,
                lora=lora, adapter_idx=adapter_idx, lora_impl=lora_impl,
                lora_seg=lora_seg)
            new_cache = dict(cache, **attn_cache)
        elif mode == "verify":
            # speculative verify window: T positions at absolute rope
            # positions len..len+T-1, through the paged pool
            if pos is None:
                pos = cache["len"][:, None] + jnp.arange(x.shape[1])[None]
            if pos3 is None and cfg.mrope_sections is not None:
                pos3 = jnp.repeat(pos[..., None], 3, axis=-1)     # text: t=h=w
            out, attn_cache = attn.self_attention_verify(
                p["attn"], h, cache, cfg, shard, pos=pos, pos3=pos3,
                lora=lora, adapter_idx=adapter_idx, lora_impl=lora_impl,
                lora_seg=lora_seg)
            new_cache = dict(cache, **attn_cache)
        else:
            out, (k, v) = attn.self_attention(
                p["attn"], h, cfg, shard, causal=causal, pos=pos, pos3=pos3,
                lora=lora, adapter_idx=adapter_idx, lora_impl=lora_impl,
                lora_seg=lora_seg, seq_lens=seq_lens, prefix=prefix,
                prefix_len=prefix_len)
            new_cache = None
            if cache is not None:  # prefill: fill the cache
                S = x.shape[1]
                new_cache = dict(cache)
                if seq_lens is not None:
                    # zero the pad positions' K/V: decode masks them via the
                    # per-row len anyway, but the int8 admission scales below
                    # are computed over the whole S axis
                    valid = (jnp.arange(S)[None] < seq_lens[:, None])
                    k = k * valid[..., None, None].astype(k.dtype)
                    v = v * valid[..., None, None].astype(v.dtype)
                if "k_scale" in cache:
                    # int8 pool admission: quantize the prompt's K/V once and
                    # fix the per-(batch, kv-head) scales for the decode steps
                    from repro.kernels import ops
                    kq, vq, ks, vs = ops.quantize_kv(k, v)
                    new_cache["k"] = jnp.zeros_like(cache["k"]).at[:, :S].set(kq)
                    new_cache["v"] = jnp.zeros_like(cache["v"]).at[:, :S].set(vq)
                    new_cache["k_scale"] = ks
                    new_cache["v_scale"] = vs
                else:
                    new_cache["k"] = jnp.zeros_like(cache["k"]).at[:, :S].set(
                        k.astype(cache["k"].dtype))
                    new_cache["v"] = jnp.zeros_like(cache["v"]).at[:, :S].set(
                        v.astype(cache["v"].dtype))
                new_cache["len"] = jnp.full_like(cache["len"], S) \
                    if seq_lens is None \
                    else seq_lens.astype(cache["len"].dtype)
        x = x + out
        if lay.has_cross:
            hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
            if mode == "decode":
                ck, cv = cache["ck"], cache["cv"]
                o = attn.decode_attention(
                    jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"].astype(hx.dtype))[:, 0],
                    ck, cv, jnp.full((hx.shape[0],), ck.shape[1], jnp.int32))
                x = x + attn.out_project(p["cross"], o[:, None], hx.dtype)
            else:
                # train/prefill: project encoder output to cross K/V here
                ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(hx.dtype))
                cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(hx.dtype))
                if new_cache is not None:  # prefill: persist for decode
                    new_cache["ck"] = ck.astype(cache["ck"].dtype)
                    new_cache["cv"] = cv.astype(cache["cv"].dtype)
                x = x + attn.cross_attention(p["cross"], hx, (ck, cv), cfg, shard)
        if lay.has_ffn:
            x, aux = _ffn_apply(p, x, cfg, lay, shard,
                                seq_lens=None if mode == "decode" else seq_lens)
        return x, new_cache, aux

    if lay.kind == MAMBA:
        if mode == "decode":
            out, (conv, ssm) = mam.mamba_decode(p["mamba"], h, cfg, shard,
                                                cache["conv"], cache["ssm"])
            new_cache = {"conv": conv, "ssm": ssm}
        else:
            out, (conv, ssm) = mam.mamba_forward(p["mamba"], h, cfg, shard,
                                                 seq_lens=seq_lens)
            new_cache = {"conv": conv, "ssm": ssm} if cache is not None else None
        x = x + out
        if lay.has_ffn:
            x, aux = _ffn_apply(p, x, cfg, lay, shard,
                                seq_lens=None if mode == "decode" else seq_lens)
        return x, new_cache, aux

    if lay.kind == MLSTM:
        out, state = xl.mlstm_forward(
            p["mlstm"], h, cfg, shard,
            state=cache if mode == "decode" else None,
            seq_lens=None if mode == "decode" else seq_lens)
        return x + out, (state if cache is not None else None), aux

    if lay.kind == SLSTM:
        out, state = xl.slstm_forward(
            p["slstm"], h, cfg, shard,
            state=cache if mode == "decode" else None,
            seq_lens=None if mode == "decode" else seq_lens)
        return x + out, (state if cache is not None else None), aux

    raise ValueError(f"unknown block kind {lay.kind}")
