"""Top-k Mixture-of-Experts FFN with expert parallelism.

Two dispatch strategies (selected by ``dispatch=``):

* ``"gshard"`` — classic capacity-based one-hot einsum dispatch (GShard/Switch).
  Memory-sane via per-sequence-subgroup scanning, shards cleanly under GSPMD
  (experts → ``model`` axis). This is the baseline the roofline measures. Its
  known cost: the dispatch/combine einsums add O(T·E·C·d) FLOPs, which dominates
  for small-``d_ff`` archs (olmoe) — see EXPERIMENTS.md §Perf.
* ``"scatter"`` — gather/scatter-based dispatch: O(T·k·d) data movement, no
  dense dispatch FLOPs. The beyond-paper optimization for compute-bound MoE.

Both share the same router and capacity math; a pure-jnp per-token loop oracle
(``moe_ref``) pins correctness in tests.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def moe_spec(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts_v")),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed")),
    }


def capacity(group: int, k: int, num_experts: int, factor: float = 1.25) -> int:
    c = int(group * k / num_experts * factor)
    c = max(c, k)
    return (c + 7) // 8 * 8 if c > 8 else c


def _router(p, xg, k):
    """xg: (g, d) -> gates (g, k), idx (g, k), load-balance aux loss."""
    logits = jnp.einsum("gd,de->ge", xg.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum(frac_tokens * frac_probs)
    e = probs.shape[-1]
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx_k.reshape(-1)].add(1.0) / idx_k.size
    aux = e * jnp.sum(me * ce)
    return gate_k, idx_k, aux


def _positions(idx_k, num_experts, cap, valid=None):
    """Slot assignment: (g, k) expert ids -> (pos_in_expert, keep) each (g, k).

    First choices get priority over second choices (k-major order), matching
    GShard. ``valid`` ((g,) bool, optional): tokens marked invalid (right-pad
    positions of a variable-length prefill) are excluded from the capacity
    cumsum and always dropped — a pad must never claim an expert slot ahead
    of a real token.
    """
    g, k = idx_k.shape
    mask = jax.nn.one_hot(idx_k, num_experts, dtype=jnp.int32)      # (g, k, E)
    if valid is not None:
        mask = mask * valid[:, None, None].astype(jnp.int32)
    mflat = mask.transpose(1, 0, 2).reshape(k * g, num_experts)     # k-major
    pos_flat = jnp.cumsum(mflat, axis=0) - mflat                    # (k*g, E)
    pos = (pos_flat.reshape(k, g, num_experts) * mask.transpose(1, 0, 2)).sum(-1)
    pos = pos.transpose(1, 0)                                        # (g, k)
    keep = pos < cap
    if valid is not None:
        keep = keep & valid[:, None]
    return pos, keep


def _expert_ffn(p, x_e, shard):
    """x_e: (E, C, d) -> (E, C, d), experts sharded over 'model'."""
    dt = x_e.dtype
    x_e = shard(x_e, ("experts", None, None))
    g = jnp.einsum("ecd,edf->ecf", x_e, p["wi_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x_e, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = shard(h, ("experts", None, "mlp"))
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    return shard(out, ("experts", None, None))


def _group_gshard(p, xg, k, cap, shard, valid=None):
    """One subgroup, einsum dispatch. xg: (B, g, d) -> (B, g, d), aux."""
    gate_k, idx_k, aux = jax.vmap(lambda t: _router(p, t, k))(xg)
    e = p["router"].shape[-1]

    def per_row(xr, gr, ir, vr):
        pos, keep = _positions(ir, e, cap, valid=vr)
        # dispatch one-hots, summed over k: (g, E, C)
        oh = (jax.nn.one_hot(ir, e, dtype=xr.dtype)[..., None]
              * jax.nn.one_hot(pos, cap, dtype=xr.dtype)[..., None, :]
              * keep[..., None, None].astype(xr.dtype))              # (g, k, E, C)
        dispatch = oh.sum(axis=1)                                    # (g, E, C)
        combine = (oh * gr[..., None, None].astype(xr.dtype)).sum(axis=1)
        x_e = jnp.einsum("gec,gd->ecd", dispatch, xr)
        y_e = _expert_ffn(p, x_e, shard)
        return jnp.einsum("gec,ecd->gd", combine, y_e)

    if valid is None:
        out = jax.vmap(lambda a, b, c: per_row(a, b, c, None))(
            xg, gate_k, idx_k)
    else:
        out = jax.vmap(per_row)(xg, gate_k, idx_k, valid)
    return out, aux.mean()


def _group_scatter(p, xg, k, cap, shard, valid=None):
    """One subgroup, scatter/gather dispatch. xg: (B, g, d) -> (B, g, d), aux."""
    e = p["router"].shape[-1]

    def per_row(xr, gr, ir, vr):
        g = xr.shape[0]
        pos, keep = _positions(ir, e, cap, valid=vr)
        slot = jnp.where(keep, ir * cap + pos, e * cap)              # overflow slot
        tok = jnp.broadcast_to(jnp.arange(g)[:, None], (g, k)).reshape(-1)
        x_e = jnp.zeros((e * cap + 1, xr.shape[-1]), xr.dtype)
        x_e = x_e.at[slot.reshape(-1)].set(xr[tok], mode="drop")
        y_e = _expert_ffn(p, x_e[:-1].reshape(e, cap, -1), shard)
        y_tok = y_e.reshape(e * cap, -1)[jnp.minimum(slot, e * cap - 1).reshape(-1)]
        y_tok = y_tok.reshape(g, k, -1) * (keep * gr).astype(xr.dtype)[..., None]
        return y_tok.sum(axis=1)

    gate_k, idx_k, aux = jax.vmap(lambda t: _router(p, t, k))(xg)
    if valid is None:
        out = jax.vmap(lambda a, b, c: per_row(a, b, c, None))(
            xg, gate_k, idx_k)
    else:
        out = jax.vmap(per_row)(xg, gate_k, idx_k, valid)
    return out, aux.mean()


def moe_ffn(p, x, *, k: int,
            dispatch: Literal["gshard", "scatter", "ep"] = "gshard",
            subgroup: int = 1024, shard=None, valid=None):
    """x: (B, S, d) -> (B, S, d), aux_loss. Scans over seq subgroups to bound
    dispatch-tensor memory; vmaps over batch (sharded over data axes).

    ``valid`` ((B, S) bool, optional): right-padded variable-length prefill —
    pad positions are excluded from routing entirely (no expert-capacity
    claim, zero FFN output), so a real token's expert assignment is invariant
    to how much padding its admission bucket carries.

    dispatch="ep" uses explicit shard_map expert parallelism (local
    scatter/gather dispatch + a single bf16 psum over the model axis) — the
    §Perf replacement for both the gshard einsum (compute waste) and the
    GSPMD-global scatter (collective explosion)."""
    from repro.models.common import NO_SHARD
    shard = shard or NO_SHARD
    if dispatch == "ep":
        out = _moe_ep(p, x, k, shard, valid)
        if out is not None:
            return out
        dispatch = "gshard"   # mesh/divisibility fallback
    B, S, d = x.shape
    gc = min(S, subgroup)
    while S % gc:
        gc -= 1
    nsub = S // gc
    e = p["router"].shape[-1]
    cap = capacity(gc, k, e)
    fn = _group_gshard if dispatch == "gshard" else _group_scatter

    if nsub == 1:
        out, aux = fn(p, x, k, cap, shard, valid=valid)
        return out, aux

    xs = x.reshape(B, nsub, gc, d).swapaxes(0, 1)                    # (nsub, B, gc, d)
    if valid is not None:
        vs = valid.reshape(B, nsub, gc).swapaxes(0, 1)

        def step_v(_, xv):
            xsub, vsub = xv
            out, aux = fn(p, xsub, k, cap, shard, valid=vsub)
            return None, (out, aux)

        _, (outs, auxs) = jax.lax.scan(step_v, None, (xs, vs))
        return outs.swapaxes(0, 1).reshape(B, S, d), auxs.mean()

    def step(_, xsub):
        out, aux = fn(p, xsub, k, cap, shard)
        return None, (out, aux)

    _, (outs, auxs) = jax.lax.scan(step, None, xs)
    return outs.swapaxes(0, 1).reshape(B, S, d), auxs.mean()


def _moe_ep(p, x, k: int, shard, valid=None):
    """shard_map expert parallelism.

    Tokens stay sharded over the batch axes and replicated over 'model'; each
    model rank routes ALL its local tokens but dispatches (locally, via
    scatter) only to the experts it owns, runs its expert FFNs, combines
    locally, and a single psum over 'model' assembles the output — identical
    capacity/drop semantics to the gshard path (same _positions), but the only
    collective is one activation-sized bf16 all-reduce. Returns None when the
    mesh or expert count doesn't fit (caller falls back to gshard)."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = getattr(shard, "mesh", None)
    e = p["router"].shape[-1]
    if mesh is None or "model" not in mesh.axis_names:
        return None
    M = mesh.shape["model"]
    if e % M != 0:
        return None
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B, S, d = x.shape
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if B % dp != 0:
        return None
    e_loc = e // M
    t_loc = (B // dp) * S
    cap = capacity(t_loc, k, e)

    def local_fn(xl, vl, router_w, wg, wu, wo):
        b_loc, s, _ = xl.shape
        xf = xl.reshape(b_loc * s, d)
        gate_k, idx_k, aux = _router({"router": router_w}, xf, k)
        pos, keep = _positions(idx_k, e, cap,
                               valid=None if vl is None
                               else vl.reshape(b_loc * s))
        m_idx = jax.lax.axis_index("model")
        is_local = (idx_k // e_loc) == m_idx
        keep_loc = keep & is_local
        slot = jnp.where(keep_loc, (idx_k % e_loc) * cap + pos, e_loc * cap)
        tok = jnp.broadcast_to(jnp.arange(b_loc * s)[:, None],
                               (b_loc * s, k)).reshape(-1)
        x_e = jnp.zeros((e_loc * cap + 1, d), xf.dtype)
        x_e = x_e.at[slot.reshape(-1)].set(xf[tok])
        x_e = x_e[:-1].reshape(e_loc, cap, d)
        g = jnp.einsum("ecd,edf->ecf", x_e, wg.astype(xf.dtype))
        u = jnp.einsum("ecd,edf->ecf", x_e, wu.astype(xf.dtype))
        y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                         wo.astype(xf.dtype))
        y_tok = y_e.reshape(e_loc * cap, d)[
            jnp.minimum(slot, e_loc * cap - 1).reshape(-1)]
        y = (y_tok.reshape(b_loc * s, k, d)
             * (keep_loc * gate_k).astype(xf.dtype)[..., None]).sum(axis=1)
        y = jax.lax.psum(y, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(b_loc, s, d), aux

    xspec = P(batch_axes if batch_axes else None, None, None)
    wspecs = (P(None, None), P("model", None, None),
              P("model", None, None), P("model", None, None))
    if valid is None:
        out, aux = shard_map(
            lambda xl, rw, wg, wu, wo: local_fn(xl, None, rw, wg, wu, wo),
            mesh=mesh, in_specs=(xspec,) + wspecs,
            out_specs=(xspec, P()), check_rep=False,
        )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    else:
        vspec = P(batch_axes if batch_axes else None, None)
        out, aux = shard_map(
            local_fn, mesh=mesh, in_specs=(xspec, vspec) + wspecs,
            out_specs=(xspec, P()), check_rep=False,
        )(x, valid, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    return out, aux.mean()


def moe_ref(p, x, k: int):
    """Dense per-token oracle (no capacity drops) for unit tests."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gate_k, idx_k, _ = _router(p, xf, k)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):
            w = p["wi_gate"][idx_k[t, j]], p["wi_up"][idx_k[t, j]], p["wo"][idx_k[t, j]]
            h = jax.nn.silu(xf[t] @ w[0]) * (xf[t] @ w[1])
            acc += gate_k[t, j] * (h @ w[2]).astype(jnp.float32)
        outs.append(acc)
    return jnp.stack(outs).reshape(B, S, d).astype(x.dtype)
