"""Unified config-driven model: init / forward / loss / prefill / decode.

One implementation serves all 11 configs (decoder LMs, MoE, SSM, hybrid,
enc-dec, VLM backbone, representation FM). Layers are scanned per *period*
(see ``repro.models.blocks``), activations are remat'ed in training, and the
loss is computed in sequence chunks with vocab-sharded logits so the 256k-vocab
archs never materialize (B, S, V).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.common import NO_SHARD, ParamSpec, init_params, shape_structs, stack_specs
from repro.models.layers import embed, embed_spec, head_spec, rmsnorm, rmsnorm_spec


# ---------------- specs ----------------

def model_spec(cfg: ModelConfig) -> dict:
    plen = blk.period_len(cfg)
    nper = cfg.num_layers // plen
    layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
    spec: dict = {
        "layers": [stack_specs(blk.sublayer_spec(cfg, lay), nper) for lay in layout],
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if cfg.vocab_size > 0:
        spec["embed"] = embed_spec(cfg.vocab_size, cfg.d_model)
        spec["head"] = head_spec(cfg.d_model, cfg.vocab_size)
    if cfg.is_representation:
        spec["head"] = ParamSpec((cfg.d_model, cfg.d_model), ("embed", None))
    if cfg.is_encoder_decoder:
        enc_lay = blk.SubLayer(kind="attn", has_moe=False, has_ffn=cfg.d_ff > 0)
        spec["encoder"] = {
            "layers": [stack_specs(blk.sublayer_spec(cfg, enc_lay), cfg.encoder_layers)],
            "final_norm": rmsnorm_spec(cfg.d_model),
        }
    return spec


def init_model(rng, cfg: ModelConfig, dtype=None):
    return init_params(rng, model_spec(cfg), dtype=dtype)


def model_structs(cfg: ModelConfig, dtype=None):
    return shape_structs(model_spec(cfg), dtype=dtype)


def cache_spec(cfg: ModelConfig, batch: int, s_max: int,
               kv_quant: bool = False, paged: bool = False,
               page_size: int = 16, num_pages: int = 0,
               enc_len: Optional[int] = None) -> list:
    """Stacked per-period decode cache (list over sublayers).

    ``kv_quant``: int8 self-attention K/V + per-(batch, kv-head) scales —
    the persistent serving pool layout (see ``core.decode_engine``).

    ``paged``: block-paged int8 arena + per-slot page table instead of the
    dense (batch, s_max) regions — ``num_pages`` fixed-size pages shared by
    all slots, so pool memory is ``num_pages × page_size`` tokens regardless
    of ``batch`` (see ``blocks.sublayer_cache_spec``). ``s_max`` only bounds
    the page-table width (max pages one stream may hold).

    ``enc_len``: encoder-output length for the cross-attention K/V state of
    enc-dec models (defaults to ``s_max``) — the serving engine passes its
    fixed encoder frame count so the per-slot cross state is sized to the
    audio frontend, not to the decode budget."""
    plen = blk.period_len(cfg)
    nper = cfg.num_layers // plen
    layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
    if not cfg.is_encoder_decoder:
        enc_len = 0
    elif enc_len is None:
        enc_len = s_max
    return [stack_specs(blk.sublayer_cache_spec(cfg, lay, batch, s_max, enc_len,
                                                kv_quant=kv_quant, paged=paged,
                                                page_size=page_size,
                                                num_pages=num_pages), nper)
            for lay in layout]


def init_cache(cfg: ModelConfig, batch: int, s_max: int, kv_quant: bool = False,
               paged: bool = False, page_size: int = 16, num_pages: int = 0,
               enc_len: Optional[int] = None):
    return init_params(jax.random.PRNGKey(0),
                       cache_spec(cfg, batch, s_max, kv_quant=kv_quant,
                                  paged=paged, page_size=page_size,
                                  num_pages=num_pages, enc_len=enc_len))


# ---------------- stack forward ----------------

def _stack_forward(layers_p, layout, x, cfg, shard, *, mode, cache, pos, pos3,
                   causal, enc_out, remat, lora=None, adapter_idx=None,
                   lora_impl="gather", lora_seg=None, seq_lens=None,
                   prefix=None, prefix_len=None):
    """Scan over periods. Returns (x, new_cache, aux_sum)."""
    with_cache = cache is not None
    with_lora = lora is not None
    with_prefix = prefix is not None

    def body(carry, xs):
        x = carry
        xs = list(xs)
        p_layers = xs.pop(0)
        cache_layers = xs.pop(0) if with_cache else [None] * len(layout)
        lora_layers = xs.pop(0) if with_lora else [None] * len(layout)
        prefix_layers = xs.pop(0) if with_prefix else [None] * len(layout)
        new_caches, aux = [], 0.0
        for i, lay in enumerate(layout):
            x, nc, a = blk.sublayer_apply(
                p_layers[i], x, cfg, lay, shard, mode=mode, cache=cache_layers[i],
                pos=pos, pos3=pos3, causal=causal, enc_out=enc_out,
                lora=(lora_layers[i] or None), adapter_idx=adapter_idx,
                lora_impl=lora_impl, lora_seg=lora_seg, seq_lens=seq_lens,
                prefix=(prefix_layers[i] or None), prefix_len=prefix_len)
            new_caches.append(nc)
            aux = aux + a
        # residual-stream boundary constraint: under sequence parallelism the
        # "seq" rule maps to the model axis, so the scan carry (and the remat
        # residuals saved per layer) live sharded — see §Perf iteration 1
        x = shard(x, ("batch", "seq", "embed"))
        if with_cache:
            return x, (new_caches, aux)
        return x, aux

    fn = jax.checkpoint(body) if remat else body
    xs = [layers_p]
    if with_cache:
        xs.append(cache)
    if with_lora:
        xs.append(lora)
    if with_prefix:
        xs.append(prefix)
    xs = tuple(xs)
    x, ys = jax.lax.scan(fn, x, xs)
    if with_cache:
        new_cache, auxs = ys
        return x, new_cache, jnp.sum(auxs)
    return x, None, jnp.sum(ys)


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None, cache=None,
            mode: str = "full", pos=None, pos3=None, enc_embeds=None,
            shard=NO_SHARD, remat: bool = False, lora=None, adapter_idx=None,
            lora_impl: str = "gather", lora_seg=None, seq_lens=None,
            prefix=None, prefix_len=None):
    """Backbone forward. Returns (hidden (B,S,d), new_cache, aux_loss).

    Inputs: ``tokens`` (B,S) int32 or ``embeds`` (B,S,d) (stub frontends);
    enc-dec models additionally take ``enc_embeds`` (B,S_enc,d).

    ``lora_impl``: "gather" (per-request gather-einsum; train/dry-run) or
    "segmented" (SGMV serve path — requires ``lora_seg`` metadata built once
    per adapter-sorted co-batch, see ``kernels.segmented_lora``).

    ``seq_lens``: (B,) per-row true lengths for right-padded variable-length
    batches (serving admission) — pad key positions are masked out of every
    attention sublayer and excluded from the prefill cache.

    ``prefix``/``prefix_len``: chunked shared-prefix prefill — a list aligned
    with the period layout of per-sublayer dict(k, v) precomputed prefix K/V
    (leading ``num_periods`` axis, like ``cache``; None for non-attention
    sublayers) that every attention sublayer attends to in front of its own
    keys. Pass absolute ``pos`` (``prefix_len + arange(S)``) so RoPE matches.
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        if mode != "decode":
            enc_lay = [blk.SubLayer(kind="attn", has_moe=False, has_ffn=cfg.d_ff > 0)]
            e = shard(enc_embeds.astype(jnp.bfloat16), ("batch", None, "embed"))
            e_pos = jnp.arange(enc_embeds.shape[1])[None]
            e, _, _ = _stack_forward(params["encoder"]["layers"], enc_lay, e, cfg,
                                     shard, mode="full", cache=None, pos=e_pos,
                                     pos3=None, causal=False, enc_out=None,
                                     remat=remat)
            enc_out = rmsnorm(params["encoder"]["final_norm"], e, cfg.norm_eps)

    if embeds is None:
        x = embed(params["embed"].astype(jnp.bfloat16), tokens)
    else:
        x = embeds.astype(jnp.bfloat16)
    x = shard(x, ("batch", None, "embed"))

    if pos is None and mode not in ("decode", "verify"):
        pos = jnp.arange(x.shape[1])[None]

    layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
    causal = not cfg.is_representation
    x, new_cache, aux = _stack_forward(
        params["layers"], layout, x, cfg, shard, mode=mode, cache=cache, pos=pos,
        pos3=pos3, causal=causal, enc_out=enc_out, remat=remat, lora=lora,
        adapter_idx=adapter_idx, lora_impl=lora_impl, lora_seg=lora_seg,
        seq_lens=seq_lens, prefix=prefix, prefix_len=prefix_len)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_cache, aux


# ---------------- losses ----------------

def chunked_ce_loss(head_w, x, labels, weights, shard, chunk: int = 512,
                    true_vocab: Optional[int] = None):
    """Cross-entropy over vocab-sharded logits, scanned in sequence chunks.

    x: (B, S, d); labels/weights: (B, S). Never materializes (B, S, V).
    ``true_vocab``: mask out TP-padding vocab entries (see sharding.padding).
    """
    B, S, d = x.shape
    V = head_w.shape[-1]
    c = min(S, chunk)
    while S % c:
        c -= 1
    n = S // c
    xs = (x.reshape(B, n, c, d).swapaxes(0, 1),
          labels.reshape(B, n, c).swapaxes(0, 1),
          weights.reshape(B, n, c).swapaxes(0, 1))
    pad_mask = None
    if true_vocab is not None and true_vocab < V:
        pad_mask = jnp.where(jnp.arange(V) < true_vocab, 0.0, -1e30)

    def step(acc, t):
        xc, lc, wc = t
        logits = jnp.einsum("bsd,dv->bsv", xc.astype(jnp.float32),
                            head_w.astype(jnp.float32))
        logits = shard(logits, ("batch", None, "vocab"))
        if pad_mask is not None:
            logits = logits + pad_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * wc), None

    # remat: never keep per-chunk logits alive for the backward pass
    tot, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32), xs)
    return tot / jnp.maximum(jnp.sum(weights), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: dict, shard=NO_SHARD,
            remat: bool = True, aux_weight: float = 0.01):
    """batch keys: tokens | embeds (+labels), enc_embeds, pos3, weights."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    x, _, aux = forward(params, cfg, tokens=tokens, embeds=embeds,
                        enc_embeds=batch.get("enc_embeds"), pos3=batch.get("pos3"),
                        shard=shard, remat=remat)
    if cfg.is_representation:
        # masked-reconstruction pretext (MOMENT-style): predict input embeddings
        recon = jnp.einsum("bsd,de->bse", x, params["head"].astype(x.dtype))
        err = (recon.astype(jnp.float32) - embeds.astype(jnp.float32)) ** 2
        loss = jnp.mean(err)
        return loss, {"loss": loss, "aux": aux}
    if "labels" in batch:
        labels, weights = batch["labels"], jnp.ones_like(batch["labels"], jnp.float32)
    else:
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        weights = jnp.concatenate(
            [jnp.ones((tokens.shape[0], tokens.shape[1] - 1), jnp.float32),
             jnp.zeros((tokens.shape[0], 1), jnp.float32)], axis=1)
    ce = chunked_ce_loss(params["head"], x, labels, weights, shard,
                         true_vocab=cfg.true_vocab)
    loss = ce + aux_weight * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ---------------- serving steps ----------------

def finite_logits(logits) -> jnp.ndarray:
    """Per-row numeric-health flag: ``(B,)`` bool, True iff every logit in
    the row is finite. Computed IN-GRAPH so the decode engine's quarantine
    check rides the chunk's existing host sync (same pattern as the paged
    pool's scale-drift flag — zero extra D2H round trips, no new jit keys):
    a NaN/Inf adapter or activation poisons only its own row's flag, and the
    engine retires that stream with a ``quarantined`` status while co-batched
    rows keep exact token parity."""
    return jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None, enc_embeds=None,
            pos3=None, cache, shard=NO_SHARD, lora=None, adapter_idx=None,
            lora_impl: str = "gather", lora_seg=None, seq_lens=None,
            pos=None, prefix=None, prefix_len=None):
    """Fill the decode cache from a prompt. Returns (last_logits, cache).
    ``lora``/``adapter_idx``: co-batched multi-task admission — the prompt
    pass applies the same per-request adapters the decode steps will.
    ``seq_lens``: (B,) true prompt lengths for right-padded variable-length
    admission — pads are masked from attention and the cache, and the "last"
    logits come from each row's final REAL token.

    Paged pools (``init_cache(paged=True)``) admit through THIS same dense
    prefill on a page-aligned bucket-sized FLOAT cache (``kv_quant=False``,
    one whole page multiple); the engine's page scatter
    (``DecodeEngine._paged_write_fn``) then quantizes each page over its own
    content — per-(page, kv-head) scales are a pure function of the tokens a
    page covers, so two streams admitting the same prefix write bit-identical
    pages, the property copy-on-write prefix sharing rests on.
    ``decode_step`` takes the paged branch automatically when the cache
    carries a ``page_table``.

    Chunked shared-prefix admission passes ``tokens`` holding only the
    PRIVATE TAIL plus ``prefix``/``prefix_len``/``pos``: ``prefix`` is the
    per-sublayer dequantized K/V of the already-mapped shared pages (see
    ``forward``), ``pos = prefix_len + arange(tail)`` keeps RoPE at absolute
    positions, ``seq_lens`` counts TAIL tokens only, and the returned cache
    holds only the tail's K/V — the engine scatters it after the prefix
    pages in the slot's page table."""
    x, cache, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                          enc_embeds=enc_embeds, pos3=pos3, cache=cache,
                          mode="full", shard=shard, lora=lora,
                          adapter_idx=adapter_idx, lora_impl=lora_impl,
                          lora_seg=lora_seg, seq_lens=seq_lens, pos=pos,
                          prefix=prefix, prefix_len=prefix_len)
    if seq_lens is None:
        last = x[:, -1]
    else:
        last = x[jnp.arange(x.shape[0]), jnp.maximum(seq_lens, 1) - 1]
    if "head" in params and cfg.vocab_size > 0:
        logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32),
                            params["head"].astype(jnp.float32))
        logits = shard(logits, ("batch", "vocab"))
        return logits, cache
    return last, cache


def decode_step(params, cfg: ModelConfig, *, tokens=None, embeds=None, cache,
                shard=NO_SHARD, lora=None, adapter_idx=None,
                lora_impl: str = "gather", lora_seg=None):
    """One-token serve step. tokens: (B,) int32 or embeds: (B, d).
    ``lora``/``adapter_idx``: co-batched multi-task serving (FMplex vFMs)."""
    if embeds is None:
        x = embed(params["embed"].astype(jnp.bfloat16), tokens[:, None])
    else:
        x = embeds[:, None].astype(jnp.bfloat16)
    x, cache, _ = forward(params, cfg, embeds=x, cache=cache, mode="decode",
                          shard=shard, lora=lora, adapter_idx=adapter_idx,
                          lora_impl=lora_impl, lora_seg=lora_seg)
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["head"].astype(jnp.float32))
    logits = shard(logits, ("batch", "vocab"))
    return logits, cache


def verify_step(params, cfg: ModelConfig, *, tokens, cache, shard=NO_SHARD,
                lora=None, adapter_idx=None, lora_impl: str = "gather",
                lora_seg=None):
    """Speculative verify: score T = k+1 positions in ONE batched forward.

    tokens: (B, T) int32 — column 0 is the slot's last sampled token (what a
    plain ``decode_step`` would feed), columns 1..k the drafted continuation.
    Returns (logits (B, T, V), cache') where ``logits[:, j]`` equals the
    logits a sequential ``decode_step`` walk would produce after feeding
    ``tokens[:, :j+1]`` — the same embed gather, the same per-position paged
    attention arithmetic (``attention.self_attention_verify``), the same f32
    head contraction, so greedy acceptance against ``argmax(logits)`` is
    bit-exact. The cache advances by the full window; the caller rolls each
    slot back to its commit point via the ``k_cmax``/``v_cmax``/``len``
    contract."""
    x = embed(params["embed"].astype(jnp.bfloat16), tokens)
    x, cache, _ = forward(params, cfg, embeds=x, cache=cache, mode="verify",
                          shard=shard, lora=lora, adapter_idx=adapter_idx,
                          lora_impl=lora_impl, lora_seg=lora_seg)
    logits = jnp.einsum("btd,dv->btv", x.astype(jnp.float32),
                        params["head"].astype(jnp.float32))
    logits = shard(logits, ("batch", None, "vocab"))
    return logits, cache


def backbone_features(params, cfg: ModelConfig, embeds, shard=NO_SHARD):
    """Representation-FM forward (MOMENT-style): embeds -> features (B, S, d)."""
    x, _, _ = forward(params, cfg, embeds=embeds, shard=shard)
    return x
