"""Multi-adapter backbone LoRA (FMplex task customization, S-LoRA style).

Adapters attach to the q and v projections of every attention sublayer. A
*stack* holds all co-resident adapters of a physical FM: leaves are shaped
(num_periods, NA, ...) so they scan with the layer periods. Each request
carries ``adapter_idx`` (B,) int32 — the sentinel NA means "base model".

Two execution paths:
  * gather-einsum (default, GSPMD-friendly): per-request A/B gathered then
    applied — exact, used in training and the dry-run;
  * segmented Pallas kernel (TPU serve path, ``repro.kernels.segmented_lora``)
    for adapter-sorted batches.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models.common import ParamSpec, stack_specs


def lora_sublayer_spec(cfg: ModelConfig, num_adapters: int, rank: int) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    mk = lambda out: {
        "a": ParamSpec((num_adapters, d, rank), ("adapters", "embed", None),
                       scale=0.05),
        "b": ParamSpec((num_adapters, rank, out), ("adapters", None, "heads_flat"),
                       init="zeros"),
    }
    return {"q": mk(h * hd), "v": mk(kv * hd)}


def lora_spec(cfg: ModelConfig, num_adapters: int, rank: int) -> list:
    """Per-sublayer stacked spec list matching ``lm.model_spec`` layers."""
    from repro.models import blocks as blk
    plen = blk.period_len(cfg)
    nper = cfg.num_layers // plen
    layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
    out = []
    for lay in layout:
        if lay.kind == ATTN:
            out.append(stack_specs(lora_sublayer_spec(cfg, num_adapters, rank), nper))
        else:
            out.append(stack_specs({}, nper))
    return out


def apply_lora_delta(x, a_stack, b_stack, adapter_idx):
    """Gather-based per-request LoRA delta.

    x: (B, S, d); a_stack: (NA, d, r); b_stack: (NA, r, out);
    adapter_idx: (B,) with NA == "no adapter". Returns (B, S, out).
    """
    na = a_stack.shape[0]
    safe = jnp.minimum(adapter_idx, na - 1)
    a = a_stack[safe].astype(x.dtype)                    # (B, d, r)
    b = b_stack[safe].astype(x.dtype)                    # (B, r, out)
    h = jnp.einsum("bsd,bdr->bsr", x, a)
    delta = jnp.einsum("bsr,bro->bso", h, b)
    return jnp.where((adapter_idx < na)[:, None, None], delta,
                     jnp.zeros_like(delta))


def qv_lora(x, lora_sub: Optional[dict], adapter_idx, q, v):
    """Add LoRA deltas to projected q/v. q: (B,S,H,hd); v: (B,S,KV,hd)."""
    if lora_sub is None or not lora_sub or adapter_idx is None:
        return q, v
    B, S, H, hd = q.shape
    KV = v.shape[2]
    dq = apply_lora_delta(x, lora_sub["q"]["a"], lora_sub["q"]["b"], adapter_idx)
    dv = apply_lora_delta(x, lora_sub["v"]["a"], lora_sub["v"]["b"], adapter_idx)
    return q + dq.reshape(B, S, H, hd), v + dv.reshape(B, S, KV, hd)


def init_single_adapter(rng, cfg: ModelConfig, rank: int):
    """One adapter's weights (NA=1 stack) — Task-API fine-tuning target."""
    from repro.models.common import init_params
    return init_params(rng, lora_spec(cfg, 1, rank))


def stack_adapters(adapters: list):
    """Combine per-adapter pytrees (NA=1 each) into one NA=n stack."""
    def cat(*xs):
        return jnp.concatenate(xs, axis=1)   # axis 1: (nper, NA, ...)
    return jax.tree.map(cat, *adapters)
