"""Multi-adapter backbone LoRA (FMplex task customization, S-LoRA style).

Adapters attach to the q and v projections of every attention sublayer. A
*stack* holds all co-resident adapters of a physical FM: leaves are shaped
(num_periods, NA, ...) so they scan with the layer periods. Each request
carries ``adapter_idx`` (B,) int32 — the sentinel NA means "base model".

Two execution paths:
  * gather-einsum (default, GSPMD-friendly): per-request A/B gathered then
    applied — exact, used in training and the dry-run;
  * segmented Pallas kernel (TPU serve path, ``repro.kernels.segmented_lora``)
    for adapter-sorted batches.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models.common import ParamSpec, stack_specs


def lora_sublayer_spec(cfg: ModelConfig, num_adapters: int, rank: int) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    mk = lambda out: {
        "a": ParamSpec((num_adapters, d, rank), ("adapters", "embed", None),
                       scale=0.05),
        "b": ParamSpec((num_adapters, rank, out), ("adapters", None, "heads_flat"),
                       init="zeros"),
    }
    return {"q": mk(h * hd), "v": mk(kv * hd)}


def lora_spec(cfg: ModelConfig, num_adapters: int, rank: int) -> list:
    """Per-sublayer stacked spec list matching ``lm.model_spec`` layers."""
    from repro.models import blocks as blk
    plen = blk.period_len(cfg)
    nper = cfg.num_layers // plen
    layout = blk.period_layout(cfg, cross=cfg.is_encoder_decoder)
    out = []
    for lay in layout:
        if lay.kind == ATTN:
            out.append(stack_specs(lora_sublayer_spec(cfg, num_adapters, rank), nper))
        else:
            out.append(stack_specs({}, nper))
    return out


def apply_lora_delta(x, a_stack, b_stack, adapter_idx):
    """Gather-based per-request LoRA delta.

    x: (B, S, d); a_stack: (NA, d, r); b_stack: (NA, r, out);
    adapter_idx: (B,) with NA == "no adapter". Returns (B, S, out).

    Accumulates in f32 (matching the segmented kernel's MXU accumulation) so
    the two paths agree to float-roundoff, then casts back to x.dtype.
    """
    na = a_stack.shape[0]
    safe = jnp.minimum(adapter_idx, na - 1)
    a = a_stack[safe]                                    # (B, d, r)
    b = b_stack[safe]                                    # (B, r, out)
    h = jnp.einsum("bsd,bdr->bsr", x, a,
                   preferred_element_type=jnp.float32)
    delta = jnp.einsum("bsr,bro->bso", h, b.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    delta = delta.astype(x.dtype)
    return jnp.where((adapter_idx < na)[:, None, None], delta,
                     jnp.zeros_like(delta))


def apply_lora_delta_segmented(x, a_stack, b_stack, seg):
    """Segmented (SGMV) per-token LoRA delta — the serve hot path.

    x: (B, S, d); a_stack: (NA, d, r); b_stack: (NA, r, out); ``seg`` is the
    per-batch metadata dict built once by the executor plane:
      perm          (Tp,)  int32 — flat-token gather into adapter-sorted,
                                   block-padded order (pads clamped to 0)
      inv           (T,)   int32 — inverse gather back to token order
      block_adapter (Tp // block_t,) int32 — one adapter id per block
                                   (>= NA means "no adapter": zero delta)
      block_t       int (static)  — kernel token-block size
    Returns (B, S, out). Every (block_t, d) tile multiplies against exactly
    one adapter's (d, r) @ (r, out), so the kernel runs dense MXU matmuls with
    per-block A/B DMA instead of materializing (B, d, r) gathered weights.
    """
    from repro.kernels import ops

    B, S, d = x.shape
    out = b_stack.shape[-1]
    x_flat = x.reshape(B * S, d)
    x_sorted = jnp.take(x_flat, seg["perm"], axis=0)
    delta = ops.segmented_lora(x_sorted, seg["block_adapter"], a_stack, b_stack,
                               block_t=seg["block_t"])
    return jnp.take(delta, seg["inv"], axis=0).reshape(B, S, out)


def qv_lora(x, lora_sub: Optional[dict], adapter_idx, q, v,
            impl: str = "gather", seg: Optional[dict] = None):
    """Add LoRA deltas to projected q/v. q: (B,S,H,hd); v: (B,S,KV,hd).

    ``impl``: "gather" (train/dry-run default) or "segmented" (serve path;
    requires ``seg`` metadata — see ``apply_lora_delta_segmented``).
    """
    if lora_sub is None or not lora_sub or adapter_idx is None:
        return q, v
    B, S, H, hd = q.shape
    KV = v.shape[2]
    if impl == "segmented":
        if seg is None:
            # fail loudly: a silent gather fallback would pass every parity
            # test while serving the exact path this impl exists to replace
            raise ValueError("lora impl 'segmented' requires seg metadata "
                             "(perm/inv/block_adapter/block_t)")
        dq = apply_lora_delta_segmented(x, lora_sub["q"]["a"],
                                        lora_sub["q"]["b"], seg)
        dv = apply_lora_delta_segmented(x, lora_sub["v"]["a"],
                                        lora_sub["v"]["b"], seg)
    else:
        dq = apply_lora_delta(x, lora_sub["q"]["a"], lora_sub["q"]["b"],
                              adapter_idx)
        dv = apply_lora_delta(x, lora_sub["v"]["a"], lora_sub["v"]["b"],
                              adapter_idx)
    return q + dq.reshape(B, S, H, hd), v + dv.reshape(B, S, KV, hd)


def init_single_adapter(rng, cfg: ModelConfig, rank: int):
    """One adapter's weights (NA=1 stack) — Task-API fine-tuning target."""
    from repro.models.common import init_params
    return init_params(rng, lora_spec(cfg, 1, rank))


def stack_adapters(adapters: list):
    """Combine per-adapter pytrees (NA=1 each) into one NA=n stack."""
    def cat(*xs):
        return jnp.concatenate(xs, axis=1)   # axis 1: (nper, NA, ...)
    return jax.tree.map(cat, *adapters)
