"""Synthetic-but-learnable token pipeline (deterministic, seedable).

Sequences follow a noisy affine recurrence over the vocab with per-sequence
(a, b) drawn from a small set — enough structure that a ~100M model's loss
drops well below uniform entropy within a few hundred steps, which is what the
end-to-end training example validates. For stub-frontend archs the pipeline
emits frame/patch embeddings + aligned labels.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.rng = np.random.RandomState(seed)
        self.params = [(5, 17), (7, 3), (11, 29), (13, 7)]

    def _tokens(self, n, s):
        V = max(self.cfg.vocab_size, 2)
        out = np.zeros((n, s), np.int64)
        for i in range(n):
            a, b = self.params[self.rng.randint(len(self.params))]
            x = self.rng.randint(V)
            for t in range(s):
                out[i, t] = x
                x = (a * x + b) % V
                if self.rng.rand() < 0.05:
                    x = self.rng.randint(V)
        return out.astype(np.int32)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        b = {}
        if cfg.is_encoder_decoder:
            b["enc_embeds"] = self.rng.randn(
                self.batch, self.seq, cfg.d_model).astype(np.float32) * 0.1
            b["tokens"] = self._tokens(self.batch, self.seq)
        elif cfg.frontend_stub:
            b["embeds"] = self.rng.randn(
                self.batch, self.seq, cfg.d_model).astype(np.float32) * 0.1
            if cfg.vocab_size > 0:
                b["labels"] = self._tokens(self.batch, self.seq)
            if cfg.mrope_sections:
                pos = np.arange(self.seq, dtype=np.int32)
                b["pos3"] = np.tile(pos[None, :, None], (self.batch, 1, 3))
        else:
            b["tokens"] = self._tokens(self.batch, self.seq)
        return b
