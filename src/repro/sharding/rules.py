"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

A *rule set* maps logical axis names (attached to every ParamSpec dim and every
activation constraint in model code) to mesh axes. ``spec_for`` resolves a
tuple of logical names into a ``PartitionSpec``, dropping mesh axes that do not
divide the dimension (replicate instead of pad) and never using a mesh axis
twice in one spec — so one rule set serves every (arch × shape × mesh) cell.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---- activation rules (used by ShardCtx inside model code) ----
ACT_RULES = {
    "batch": ("pod", "data"),
    "embed": None,
    "seq": None,          # residual-stream sequence dim; "model" = Megatron SP
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "cache_seq": None,
}

# §Perf: sequence-parallel residual stream — layer-boundary activations (and
# the remat residuals the backward pass keeps alive) shard over 'model'
SP_ACT = dict(ACT_RULES, seq="model")

# ---- parameter rules ----
TP_RULES = {            # tensor parallel only; weights replicated over data
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "experts_v": None,
    "vocab": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "cache_seq": None,
}
FSDP_RULES = dict(TP_RULES, embed=("pod", "data"))   # + shard d_model rows over data

# long-context decode: shard the KV-cache sequence over data (batch=1 cells)
LONG_CTX_ACT = dict(ACT_RULES, cache_seq="data")
LONG_CTX_PARAM = dict(TP_RULES, cache_seq="data")
LONG_CTX_FSDP = dict(FSDP_RULES, cache_seq="data")


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for(rules: dict, axes: Sequence[Optional[str]], mesh,
             shape: Optional[Sequence[int]] = None) -> P:
    """Resolve logical axes -> PartitionSpec under ``rules`` on ``mesh``."""
    used: set[str] = set()
    parts = []
    for i, name in enumerate(axes):
        rule = rules.get(name) if name is not None else None
        if rule is None:
            parts.append(None)
            continue
        cand = (rule,) if isinstance(rule, str) else tuple(rule)
        cand = tuple(a for a in cand if a in mesh.axis_names and a not in used)
        # longest prefix that fits the dim. Uneven sharding (dim % size != 0)
        # is allowed — GSPMD pads — as long as every extra axis still has at
        # least one row per shard (dim >= prod); otherwise replicate.
        take: list[str] = []
        prod = 1
        for a in cand:
            sz = _axis_size(mesh, a)
            if shape is not None and shape[i] < prod * sz:
                break
            take.append(a)
            prod *= sz
        if not take:
            parts.append(None)
        else:
            used.update(take)
            parts.append(tuple(take) if len(take) > 1 else take[0])
    return P(*parts)


def tree_shardings(rules: dict, axes_tree, mesh, struct_tree):
    """Map a logical-axes tree + struct tree -> NamedSharding tree."""
    def one(axes, struct):
        return NamedSharding(mesh, spec_for(rules, axes, mesh, struct.shape))
    return jax.tree.map(one, axes_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def replicated(mesh):
    return NamedSharding(mesh, P())
