"""TP-friendly head/vocab padding (Megatron-style, exact).

pjit requires argument dims to divide evenly across mesh axes. GQA configs like
qwen2-7b (28 q heads, 4 kv heads) don't divide a 16-way model axis, so we apply
the standard serving transformation:

* **KV expansion** — store each kv head ``r = tp / gcd(kv, tp)`` times so the
  expanded kv dim divides tp. Per-device cache bytes equal the classic
  "replicate KV within TP groups" scheme.
* **Q-group padding** — pad each kv group's q heads to a multiple of ``r`` so
  the padded-q → expanded-kv mapping ``h -> h // (H'/KV')`` matches the
  original ``h -> h // G``. Pad heads have zero weights: zero q/k/v/o rows make
  them exact no-ops (outputs and gradients identically zero).
* **Vocab padding** — round the vocab to a multiple of 128; pad logits are
  masked to -inf in the loss (see ``chunked_ce_loss``), so softmax is unchanged.

``pad_params`` converts real (unpadded) weights into padded weights for
correctness tests; the dry-run only needs the padded shapes.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig


def pad_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    if tp <= 1:
        return cfg
    over = {}
    has_attn = any(b == ATTN for b in cfg.blocks) or cfg.is_encoder_decoder
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if has_attn and (KV % tp != 0 or H % tp != 0) and KV < tp:
        r = tp // math.gcd(KV, tp)
        G = H // KV
        Gp = math.ceil(G / r) * r
        over["num_heads"] = KV * Gp
        over["num_kv_heads"] = KV * r
        over["head_dim"] = cfg.head_dim
    if cfg.vocab_size > 0 and cfg.vocab_size % tp != 0:
        over["vocab_size"] = math.ceil(cfg.vocab_size / 128) * 128
        over["true_vocab"] = cfg.vocab_size
    if not over:
        return cfg
    return dataclasses.replace(cfg, **over)


def pad_params(params_small, cfg: ModelConfig, padded: ModelConfig):
    """Zero-pad real weights from ``cfg`` layout to ``padded`` layout.

    Only head/vocab dims change; q heads are padded *per kv group* and kv heads
    are replicated ``r`` times (values must be duplicated, not zeroed, so that
    expanded-cache attention matches).
    """
    import jax

    G = cfg.num_heads // cfg.num_kv_heads
    Gp = padded.num_heads // cfg.num_kv_heads      # padded group size
    r = padded.num_kv_heads // cfg.num_kv_heads

    def pad_leaf(path, x):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        x = np.asarray(x)
        if "embed" in keys and x.ndim == 2 and x.shape[0] == cfg.vocab_size:
            out = np.zeros((padded.vocab_size, x.shape[1]), x.dtype)
            out[: cfg.vocab_size] = x
            return jnp.asarray(out)
        if "head" in keys and x.ndim == 2 and x.shape[1] == cfg.vocab_size:
            out = np.zeros((x.shape[0], padded.vocab_size), x.dtype)
            out[:, : cfg.vocab_size] = x
            return jnp.asarray(out)
        name = keys[-1]
        def pad_q(arr, axis):
            shp = list(arr.shape)
            shp[axis] = padded.num_heads
            out = np.zeros(shp, arr.dtype)
            src = np.split(arr, cfg.num_kv_heads, axis=axis)
            dst = np.split(out, cfg.num_kv_heads, axis=axis)
            for s, d in zip(src, dst):
                sl = [slice(None)] * arr.ndim
                sl[axis] = slice(0, G)
                d[tuple(sl)] = s
            return jnp.asarray(np.concatenate(dst, axis=axis))
        def rep_kv(arr, axis):
            return jnp.asarray(np.repeat(arr, r, axis=axis))
        # heads axis is always ndim-2: wq (..., d, H, hd), bq (..., H, hd)
        if name in ("wq", "bq") and x.shape[x.ndim - 2] == cfg.num_heads:
            return pad_q(x, x.ndim - 2)
        if name == "wo" and x.shape[x.ndim - 3] == cfg.num_heads:
            return pad_q(x, x.ndim - 3)
        if name in ("wk", "wv") and x.shape[x.ndim - 2] == cfg.num_kv_heads:
            return rep_kv(x, x.ndim - 2)
        if name in ("bk", "bv") and x.shape[x.ndim - 2] == cfg.num_kv_heads:
            return rep_kv(x, x.ndim - 2)
        return jnp.asarray(x)

    return jax.tree_util.tree_map_with_path(pad_leaf, params_small)
