"""whisper-base — enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                # decoder layers
    encoder_layers=6,
    is_encoder_decoder=True,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    frontend_stub="audio_frames",   # input_specs() supplies precomputed frame embeddings
    source="arXiv:2212.04356; unverified",
))
