"""moment-large — the paper's own primary backbone (MOMENT, a T5-large-style
time-series encoder used as a representation FM) [arXiv:2402.03885 via paper §7].

Representation-based: the backbone is a feature extractor with fixed input/output
shape; tasks attach encoders/decoder heads. No decode shapes exist for it.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="moment-large",
    family="representation",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=0,                 # patch-embedded time series, no token vocab
    is_representation=True,
    frontend_stub="ts_patches",   # input_specs() supplies precomputed patch embeddings
    source="paper §7 / arXiv:2402.03885",
))
