"""Config registry: importing this package registers every architecture."""
from repro.configs.base import ModelConfig, get_config, list_configs, reduced, register
from repro.configs import (  # noqa: F401  (registration side effects)
    xlstm_125m,
    whisper_base,
    h2o_danube_1_8b,
    minitron_8b,
    qwen2_7b,
    stablelm_1_6b,
    qwen2_vl_72b,
    olmoe_1b_7b,
    grok_1_314b,
    jamba_v0_1_52b,
    moment_large,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, grid

ASSIGNED = [
    "xlstm-125m", "whisper-base", "h2o-danube-1.8b", "minitron-8b", "qwen2-7b",
    "stablelm-1.6b", "qwen2-vl-72b", "olmoe-1b-7b", "grok-1-314b", "jamba-v0.1-52b",
]

__all__ = [
    "ModelConfig", "get_config", "list_configs", "reduced", "register",
    "SHAPES", "ShapeSpec", "applicable", "grid", "ASSIGNED",
]
