"""Assigned input-shape grid: 4 shapes × 10 archs = 40 cells.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers ``prefill_step``;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV/state
cache of ``seq_len``). ``long_500k`` only applies to sub-quadratic archs.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.configs.base import ModelConfig

Kind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only (representation) arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""


def grid(cfg: ModelConfig) -> list[tuple[ShapeSpec, bool, str]]:
    return [(s, *applicable(cfg, s)) for s in SHAPES.values()]
