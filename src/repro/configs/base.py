"""Model/config registry for the FMplex reproduction.

Every architecture (the 10 assigned LM-family archs + the paper's own
representation backbone) is described by a single ``ModelConfig``. The model zoo
(``repro.models``) is config-driven: block kinds, attention flavor, MoE, and
frontend stubs are all selected from fields here, so one implementation serves
every arch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

# Block kinds understood by repro.models.blocks
ATTN = "attn"          # (SWA-)GQA attention + MLP/MoE
MAMBA = "mamba"        # Mamba SSM block (Jamba)
SLSTM = "slstm"        # xLSTM sLSTM block
MLSTM = "mlstm"        # xLSTM mLSTM block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm | representation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads

    # attention flavor
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA width (h2o-danube, jamba attn layers)
    rope_theta: float = 10000.0
    mrope_sections: Optional[Sequence[int]] = None  # Qwen2-VL M-RoPE (t, h, w)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # apply MoE FFN every k-th layer (jamba: 2)

    # block pattern: None -> all ATTN. Otherwise a cycle applied over layers,
    # e.g. jamba 1:7 attn:mamba -> ("mamba",)*3 + ("attn",) + ("mamba",)*4 cycled.
    block_pattern: Optional[Sequence[str]] = None

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # representation-based FM (paper's MOMENT analogue): encoder-only, no LM head
    is_representation: bool = False

    # modality frontend stub: if set, input_specs() provides precomputed
    # frame/patch embeddings of shape (batch, seq, d_model) instead of token ids.
    frontend_stub: Optional[str] = None   # None | "audio_frames" | "vision_patches"

    # mamba-specific
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xLSTM-specific
    xlstm_proj_factor: float = 2.0

    # MoE dispatch strategy: "gshard" (capacity einsum, baseline) or
    # "scatter" (gather/scatter, beyond-paper optimization — see §Perf)
    moe_dispatch: str = "gshard"

    # numerics / misc
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    true_vocab: Optional[int] = None  # set when vocab was padded for TP
    source: str = ""                 # provenance note

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ---- derived ----
    @property
    def blocks(self) -> Sequence[str]:
        """Per-layer block kind, length num_layers."""
        if self.block_pattern is None:
            return tuple(ATTN for _ in range(self.num_layers))
        pat = tuple(self.block_pattern)
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long-context decode (SSM/hybrid/SWA)."""
        if any(b in (MAMBA, SLSTM, MLSTM) for b in self.blocks):
            return True
        return self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        """Encoder-only (representation) archs have no decode step."""
        return not self.is_representation

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings and not self.is_representation:
            n += self.vocab_size * d                  # lm head
        for i, kind in enumerate(self.blocks):
            if kind == ATTN:
                n += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d  # qkvo
                if self.qkv_bias:
                    n += (h + 2 * kv) * hd
                n += self._ffn_params(i)
                n += 2 * d                             # norms
            elif kind == MAMBA:
                d_in = self.mamba_expand * d
                n += d * (2 * d_in)                    # in_proj
                n += d_in * self.mamba_d_conv          # conv
                n += d_in * (self.mamba_d_state * 2 + 1)  # x_proj (B,C,dt low-rank-ish)
                n += d_in * self.mamba_d_state         # A
                n += d_in * 2                          # D, dt_bias
                n += d_in * d                          # out_proj
                n += d                                 # norm
                if self.uses_moe and self._layer_has_moe(i):
                    n += self._ffn_params(i)
                    n += d
            elif kind in (SLSTM, MLSTM):
                pf = self.xlstm_proj_factor
                d_in = int(pf * d)
                n += d * (4 * d_in) + d_in * d         # gates up + down (approx)
                n += 2 * d
        if self.is_encoder_decoder:
            # encoder blocks (attn + mlp) + cross-attention in decoder counted above;
            # add encoder stack + decoder cross-attn
            enc = self.encoder_layers * (4 * d * d + self._ffn_params(0) + 2 * d)
            cross = self.num_layers * (d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d + d)
            n += enc + cross
        return n

    def _layer_has_moe(self, i: int) -> bool:
        return self.uses_moe and (i % self.moe_every == self.moe_every - 1)

    def _ffn_params(self, i: int) -> int:
        d = self.d_model
        if self.d_ff == 0:
            return 0
        if self._layer_has_moe(i):
            return self.num_experts * 3 * d * self.d_ff
        if self.uses_moe and self.moe_every > 1:
            return 3 * d * self.d_ff  # dense interleave layer
        if self.uses_moe:
            return self.num_experts * 3 * d * self.d_ff
        return 3 * d * self.d_ff      # gated (SwiGLU) FFN

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.uses_moe:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        moe_layers = sum(1 for i in range(self.num_layers) if self._layer_has_moe(i))
        unused = moe_layers * (self.num_experts - self.experts_per_token) * 3 * d * self.d_ff
        return full - unused


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    import math
    period = len(cfg.block_pattern) if cfg.block_pattern else 1
    if cfg.num_experts > 0:
        period = math.lcm(period, cfg.moe_every)
    small = dict(
        num_layers=period if period > 1 else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        sliding_window=64 if cfg.sliding_window else None,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else None,
        name=cfg.name + "-smoke",
    )
    small.update(over)
    return dataclasses.replace(cfg, **small)
