"""jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE 16e top-2 every other layer
[arXiv:2403.19887; hf]."""
from repro.configs.base import ModelConfig, register, ATTN, MAMBA

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    # 1 attention layer per 8 (attn:mamba = 1:7), attention at position 4 of each block
    block_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    source="arXiv:2403.19887; hf",
))
