"""qwen2-vl-72b — VLM backbone, M-RoPE, vision frontend stubbed [arXiv:2409.12191; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),     # t/h/w sections over head_dim/2 = 64
    frontend_stub="vision_patches",  # input_specs() supplies precomputed patch embeddings
    source="arXiv:2409.12191; hf",
))
