"""qwen2-7b — GQA kv=4, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    source="arXiv:2407.10671; hf",
))
