"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig, register, MLSTM, SLSTM

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                      # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
    xlstm_proj_factor=2.0,
    source="arXiv:2405.04517; unverified",
))
