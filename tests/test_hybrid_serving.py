"""Serving beyond attention-only stacks through the unified cache-manager
plane: per-sublayer cache plans (paged attention KV vs fixed-size pooled
recurrent / cross-attention state), the state-slot lifecycle + admission
gate, clean capability demotion (speculation, prefix sharing, spill) on
hybrid / recurrent / enc-dec stacks, var-len bucketed prefill parity for
hybrids, zero steady-state recompiles across hybrid churn, snapshot/restore
of the dense-state side, ServeLoop end-to-end on a hybrid FM, and the
whisper encoder-decoder decode path through the engine."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.core.cache_manager import CachePlan
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM
from repro.models import lm
from repro.serving.metrics import mixed_stats, page_gauges

# one sublayer of every cache kind: paged attention KV beside mamba
# conv+ssm state and both xLSTM state flavors — the stack the refactor
# exists for
HYB = ModelConfig(name="hyb-serve", family="hybrid", num_layers=4,
                  d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                  d_ff=128, vocab_size=128,
                  block_pattern=(MAMBA, ATTN, MLSTM, SLSTM))


@pytest.fixture(scope="module")
def hyb_fm():
    fm = PhysicalFM(HYB, seed=0, input_len=16, lora_rank=4)
    fm.adapters.new("lora0", seed=0)
    return fm


def _engine(fm, **kw):
    """Engine constructor with capability-demotion warnings silenced —
    the demotions themselves are asserted by the tests that target them."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return DecodeEngine(fm, **kw)


def _greedy_reference(fm, prompt, steps, s_max, enc_feats=None, enc_len=None):
    """Teacher-forced oracle: exact-length (unpadded) prefill + greedy decode
    on a dense int8 cache — what the bucketed paged engine must match
    token-for-token on ANY stack."""
    cfg = fm.cfg
    ai = jnp.full((1,), fm.adapters.capacity(), jnp.int32)
    cache = lm.init_cache(cfg, 1, s_max, kv_quant=True, enc_len=enc_len)
    enc = jnp.asarray(np.asarray(enc_feats, np.float32)[None]) \
        if enc_feats is not None else None
    lg, cache = lm.prefill(fm.params, cfg, tokens=jnp.asarray(prompt[None]),
                           cache=cache, lora=fm.adapters.stacked(),
                           adapter_idx=ai, lora_impl="gather",
                           enc_embeds=enc)
    toks = [int(jnp.argmax(lg, -1)[0])]
    for _ in range(steps - 1):
        lg, cache = lm.decode_step(
            fm.params, cfg, tokens=jnp.asarray([toks[-1]], jnp.int32),
            cache=cache, lora=fm.adapters.stacked(), adapter_idx=ai,
            lora_impl="gather")
        toks.append(int(jnp.argmax(lg, -1)[0]))
    return toks


# ---------------- the cache plan ----------------

def test_cache_plan_classifies_sublayers_and_capabilities():
    plan = CachePlan.for_config(HYB, paged=True)
    assert [s.kind for s in plan.sublayers] == [MAMBA, ATTN, MLSTM, SLSTM]
    assert [s.paged for s in plan.sublayers] == [False, True, False, False]
    assert [s.fixed_state for s in plan.sublayers] == [True, False, True, True]
    assert plan.paged and plan.has_attention and plan.has_recurrent
    assert plan.needs_state_slots
    # every attention-only serving plane demotes on the hybrid
    assert not plan.prefix_sharing_ok and not plan.chunked_prefill_ok
    assert not plan.speculative_ok and not plan.spill_resume_ok

    attn = CachePlan.for_config(reduced(get_config("stablelm-1.6b")), True)
    assert attn.prefix_sharing_ok and attn.speculative_ok \
        and attn.spill_resume_ok and not attn.needs_state_slots

    # a pure recurrent stack has nothing to page: the arena demotes away
    rec = CachePlan.for_config(reduced(get_config("xlstm-125m")), paged=True)
    assert not rec.paged and not rec.has_attention and rec.needs_state_slots

    enc = CachePlan.for_config(reduced(get_config("whisper-base")), True)
    assert enc.has_encoder and enc.needs_state_slots \
        and not enc.speculative_ok and not enc.prefix_sharing_ok


# ---------------- hybrid var-len parity through the paged engine ----------------

def test_hybrid_paged_varlen_admission_matches_reference(hyb_fm):
    """A hybrid stack joins the same bucketed right-padded admission path as
    attention-only stacks: pads are invisible to the attention KV, the
    recurrent scans (length-aware dt/gate masking), and the rope positions —
    greedy tokens match the exact-length dense reference bit-for-bit."""
    eng = _engine(hyb_fm, num_slots=2, prompt_len=16, max_new=8, chunk=2,
                  paged=True, page_size=8)
    assert eng.plan.has_recurrent and eng.state_pool is not None
    rng = np.random.RandomState(7)
    for plen in (3, 9, 16):                      # buckets 4, 16, 16
        p = rng.randint(0, HYB.vocab_size, plen).astype(np.int32)
        eng.join("t", p, max_new_tokens=6, rid=0)
        (d,) = eng.drain()
        assert d.tokens == _greedy_reference(hyb_fm, p, 6, eng.s_max)
    assert eng.state_pool.slots_in_use() == set()


def test_hybrid_churn_zero_recompiles(hyb_fm):
    """Join/leave churn over variable prompt lengths and budgets on the
    hybrid paged engine adds ZERO executables once each bucket is warm —
    the true length stays a traced operand for the recurrent scans too."""
    eng = _engine(hyb_fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                  paged=True, page_size=8, prompt_buckets=(4, 16))
    rng = np.random.RandomState(3)
    for plen in (4, 16):                         # warm each bucket once
        eng.join("w", rng.randint(0, HYB.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
    eng.drain()
    compiles = eng.compile_count()
    done = []
    for i, plen in enumerate((1, 3, 7, 9, 13, 16, 2, 11)):
        eng.join(f"t{i}", rng.randint(0, HYB.vocab_size, plen),
                 adapter_id="lora0" if i % 2 else None,
                 max_new_tokens=2 + i % 3, rid=i)
        if not eng.free_slots():
            done += eng.step_chunk()
    done += eng.drain()
    assert len(done) == 8
    assert eng.compile_count() == compiles
    assert eng.state_pool.slots_in_use() == set()
    assert eng.state_pool.peak_in_use >= 2


def test_moe_routing_excludes_pad_tokens():
    """Var-len MoE prefill: pad positions are excluded from expert routing —
    they claim no capacity (a pad must never displace a real token from its
    expert) and contribute zero output, so a real token's result is
    bit-invariant to the pad CONTENT of its admission bucket. This is what
    lets MoE hybrids (jamba) join the bucketed prefill path."""
    import jax

    from repro.models.common import init_params
    from repro.models.moe import moe_ffn, moe_spec

    cfg = reduced(get_config("jamba-v0.1-52b"))
    p = init_params(jax.random.PRNGKey(0), moe_spec(cfg))
    B, S, d = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
    valid = jnp.arange(S)[None] < jnp.asarray([11, 16])[:, None]
    for disp in ("gshard", "scatter"):
        o1, _ = moe_ffn(p, x, k=2, dispatch=disp, valid=valid)
        xg = jnp.where(valid[..., None], x, 123.0)   # garbage pads
        o2, _ = moe_ffn(p, xg, k=2, dispatch=disp, valid=valid)
        assert np.array_equal(np.asarray(o1)[0, :11], np.asarray(o2)[0, :11])
        assert np.array_equal(np.asarray(o1)[1], np.asarray(o2)[1])
        assert np.array_equal(np.asarray(o1)[0, 11:],
                              np.zeros((S - 11, d), np.float32))
        o3, _ = moe_ffn(p, x, k=2, dispatch=disp)    # valid=None unchanged
        o4, _ = moe_ffn(p, x, k=2, dispatch=disp,
                        valid=jnp.ones((B, S), bool))
        np.testing.assert_allclose(np.asarray(o3), np.asarray(o4), atol=1e-6)


# ---------------- capability demotion ----------------

def test_hybrid_demotes_speculation_and_prefix_sharing(hyb_fm):
    """spec_k > 0 on a hybrid warns and demotes to plain decode (recurrent
    state cannot rewind past rejected drafts); prefix sharing demotes
    silently (shared pages capture attention KV only). The engine still
    serves — demotion, not a crash."""
    with pytest.warns(RuntimeWarning, match="demoted to plain decode"):
        eng = DecodeEngine(hyb_fm, num_slots=2, prompt_len=16, max_new=4,
                           chunk=2, paged=True, page_size=8, spec_k=2)
    assert eng.spec_k == 0
    assert eng.prefix_sharing is False and eng.chunked_prefill is False
    p = np.arange(8, dtype=np.int32) % HYB.vocab_size
    eng.join("t", p, max_new_tokens=4, rid=0)
    (d,) = eng.drain()
    assert d.tokens == _greedy_reference(hyb_fm, p, 4, eng.s_max)
    # unpaged + spec_k on a hybrid: the demotion fires BEFORE the
    # paged-required check, so construction succeeds instead of raising
    with pytest.warns(RuntimeWarning, match="demoted to plain decode"):
        eng2 = DecodeEngine(hyb_fm, num_slots=2, prompt_len=16, max_new=4,
                            chunk=2, paged=False, spec_k=2)
    assert eng2.spec_k == 0 and not eng2.paged


def test_hybrid_demotes_spill_tier(hyb_fm):
    """A spill arena on a stack with per-slot dense state warns and demotes
    to None: the stream spill captures pages + trackers only, so preemption
    must take the lossless fold-and-re-prefill path."""
    with pytest.warns(RuntimeWarning, match="spill tier demoted"):
        eng = DecodeEngine(hyb_fm, num_slots=2, prompt_len=16, max_new=4,
                           chunk=2, paged=True, page_size=8,
                           spill_bytes=32 << 20)
    assert eng.spill is None


def test_pure_recurrent_paged_demotes_to_dense_pool():
    """paged=True on a stack with no attention sublayers (xLSTM) warns and
    runs the dense slot pool — the whole serving state is fixed-size state
    slots, there is nothing to page — and still decodes with exact parity."""
    cfg = reduced(get_config("xlstm-125m"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    with pytest.warns(RuntimeWarning, match="no attention sublayers"):
        eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=4, chunk=2,
                           paged=True, page_size=8)
    assert not eng.paged and not eng.plan.paged
    assert eng.state_pool is not None
    rng = np.random.RandomState(5)
    p = rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
    eng.join("t", p, max_new_tokens=4, rid=0)
    (d,) = eng.drain()
    assert d.tokens == _greedy_reference(fm, p, 4, eng.s_max)
    assert eng.state_pool.slots_in_use() == set()


# ---------------- state-slot lifecycle, admission gate, gauges ----------------

def test_state_slot_admission_gate_and_gauges(hyb_fm):
    """Hybrid admission counts fixed state slots alongside pages: with the
    state pool exhausted ``can_admit`` defers (and the deferral gauge
    ticks) even while decode slots are free; page_gauges / mixed_stats
    surface the state-slot occupancy."""
    eng = _engine(hyb_fm, num_slots=2, prompt_len=16, max_new=4, chunk=2,
                  paged=True, page_size=8)
    sp = eng.state_pool
    p = np.arange(8, dtype=np.int32) % HYB.vocab_size
    eng.join("a", p, max_new_tokens=4, rid=0)
    assert sp.slots_in_use() == {0} and sp.in_use_count() == 1
    assert eng.can_admit(prompt_tokens=8)
    # exhaust the state pool out-of-band: decode slot 1 stays free, so the
    # deferral is attributable to state-slot pressure alone
    sp.alloc(1)
    assert eng.free_slots()
    before = sp.slot_deferrals
    assert not eng.can_admit(prompt_tokens=8)
    assert sp.slot_deferrals == before + 1
    sp.free(1)
    assert eng.can_admit(prompt_tokens=8)
    g = page_gauges(eng)
    assert g["state_slots_total"] == eng.num_slots
    assert g["state_slots_in_use"] == 1 and g["state_slots_peak"] >= 1
    assert g["state_slot_deferrals"] == before + 1
    eng.drain()
    assert sp.slots_in_use() == set()
    stats = mixed_stats([], engine=eng)
    assert stats["state_slots"]["state_slots_in_use"] == 0
    assert stats["state_slots"]["state_slot_deferrals"] == before + 1


def test_hybrid_snapshot_restore_resumes_dense_state(hyb_fm):
    """Snapshot mid-flight captures the fixed-size per-slot state beside the
    used pages; a restore into a fresh arena (the old one scrambled — a
    simulated device reset) resumes every stream with EXACT token parity,
    and the restored state pool re-marks live slots."""
    eng = _engine(hyb_fm, num_slots=2, prompt_len=16, max_new=8, chunk=2,
                  paged=True, page_size=8)
    rng = np.random.RandomState(11)
    ps = [rng.randint(0, HYB.vocab_size, n).astype(np.int32) for n in (7, 12)]
    want = [_greedy_reference(hyb_fm, p, 8, eng.s_max) for p in ps]
    for i, p in enumerate(ps):
        eng.join(f"t{i}", p, max_new_tokens=8, rid=i)
    eng.step_chunk()                             # mid-flight: 2 tokens in
    snap = eng.snapshot()
    payload = snap.to_host_payload()             # dense state serializes too
    snap2 = type(snap).from_host_payload(*payload)
    old = eng
    for sub in old.pool:                         # scramble the dead arena
        if isinstance(sub, dict) and "page_table" in sub:
            sub["k"] = jnp.full_like(sub["k"], 77)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng = DecodeEngine.restore(hyb_fm, snap2, reuse_jits_from=old)
    assert eng.state_pool.slots_in_use() == {0, 1}
    done = sorted(eng.drain(), key=lambda s: s.rid)
    assert [d.tokens for d in done] == want


# ---------------- ServeLoop end-to-end on a hybrid FM ----------------

def test_serve_loop_hybrid_end_to_end(hyb_fm):
    """A hybrid FM serves through the full event loop — warmup, mixed-length
    generative churn, zero steady-state recompiles — with the state pool
    drained at the end. The enc-dec / hybrid gates are gone: the loop admits
    through the same engine path as attention-only stacks."""
    from repro.core.request import Request
    from repro.core.server import FMplexServer
    from repro.core.vfm import TaskExtensions

    hyb_fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s-hyb")
    srv.deploy_fm("fm0", hyb_fm, scheduler="bfq")
    srv.bind_task("gen", "fm0", weight=1.0,
                  extensions=TaskExtensions(adapter_id="lora0"))
    loop = srv.serve_loop("fm0", engine_kwargs=dict(
        num_slots=2, prompt_len=16, max_new=8, chunk=2,
        paged=True, page_size=8))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        loop.warmup(gen_task="gen")
    eng = srv.engines["fm0"]
    assert eng.state_pool is not None
    compiles = eng.compile_count()
    rng = np.random.RandomState(2)
    trace = [Request("gen", 0.0,
                     payload=rng.randint(0, HYB.vocab_size,
                                         3 + 3 * i).astype("int32"),
                     tokens=float(16 + 4), max_new_tokens=3 + i)
             for i in range(4)]
    loop.run(list(trace), max_wall=120)
    assert all(len(r.result) == r.max_new_tokens for r in trace)
    assert eng.compile_count() == compiles       # zero steady-state recompiles
    assert eng.state_pool.slots_in_use() == set()


# ---------------- whisper encoder-decoder through the engine ----------------

def test_whisper_enc_dec_decodes_through_engine():
    """The enc-dec assert is gone: whisper joins carry per-stream encoder
    frames, the engine writes them into the per-slot cross K/V state at
    admission, and greedy decode matches the dense reference with explicit
    ``enc_embeds`` exactly. A join with the wrong frame count is rejected
    (the encoder is bidirectional — frame count is shape-strict), and a
    frameless join falls back to zero frames (the warmup path)."""
    cfg = reduced(get_config("whisper-base"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    eng = _engine(fm, num_slots=2, prompt_len=8, max_new=6, chunk=2,
                  paged=True, page_size=8)
    assert eng.enc_len == 8 and eng.state_pool is not None
    assert not eng.prefix_sharing and eng.spec_k == 0
    rng = np.random.RandomState(1)
    feats = rng.randn(eng.enc_len, cfg.d_model).astype(np.float32) * 0.1
    p = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
    eng.join("t", p, max_new_tokens=5, rid=0, enc_feats=feats)
    (d,) = eng.drain()
    assert d.tokens == _greedy_reference(fm, p, 5, eng.s_max,
                                         enc_feats=feats,
                                         enc_len=eng.enc_len)
    with pytest.raises(AssertionError):          # wrong frame count: strict
        eng.join("t", p, max_new_tokens=2, rid=1, enc_feats=feats[:-1])
    eng.join("t", p, max_new_tokens=3, rid=2)    # frameless: zero-frame default
    (d2,) = eng.drain()
    assert len(d2.tokens) == 3
    assert eng.state_pool.slots_in_use() == set()
