"""Segmented (SGMV) LoRA serve path: numerical parity vs the gather-einsum
path on mixed-adapter co-batches, and steady-state recompile freedom of the
bucketed PhysicalFM serve plane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.physical import AdapterStore, PhysicalFM, slot_bucket_for
from repro.kernels.segmented_lora import (padded_tokens, segment_metadata,
                                          segmented_lora)
from repro.models.lora import (apply_lora_delta, apply_lora_delta_segmented,
                               init_single_adapter, qv_lora)

BT = 16


def _seg_meta(adapter_idx, na, S, bt=BT):
    """Build the serve-path metadata dict the way PhysicalFM does."""
    b = len(adapter_idx)
    tp = padded_tokens(b * S, min(b, na + 2), bt)
    perm, inv, blocks = segment_metadata(np.repeat(adapter_idx, S), na,
                                         block_t=bt, max_tokens=tp)
    return {"perm": jnp.asarray(perm), "inv": jnp.asarray(inv),
            "block_adapter": jnp.asarray(blocks), "block_t": bt}


# ---------------- delta-level parity (f32, atol 1e-4) ----------------

@pytest.mark.parametrize("out_dim", [64, 96, 32])   # == d, > d, < d (q/v dims)
def test_segmented_matches_gather_mixed_batch(out_dim):
    """Mixed-adapter batch incl. base-model sentinel rows; ragged segments
    (S=12 with block_t=16 -> no segment is a block multiple)."""
    B, S, d, r, na = 7, 12, 64, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    a = jax.random.normal(ks[1], (na, d, r), jnp.float32) * 0.05
    b = jax.random.normal(ks[2], (na, r, out_dim), jnp.float32) * 0.05
    aidx = np.array([0, 2, 0, na, 1, na, 2], np.int32)   # na == no adapter

    want = apply_lora_delta(x, a, b, jnp.asarray(aidx))
    got = apply_lora_delta_segmented(x, a, b, _seg_meta(aidx, na, S))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # sentinel rows contribute exactly zero delta
    assert np.abs(np.asarray(got)[aidx == na]).max() == 0.0


def test_segmented_all_base_model_rows():
    B, S, d, r, na = 4, 16, 32, 4, 2
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
    a = jnp.ones((na, d, r)) * 0.1
    b = jnp.ones((na, r, d)) * 0.1
    aidx = np.full((B,), na, np.int32)
    got = apply_lora_delta_segmented(x, a, b, _seg_meta(aidx, na, S))
    assert np.abs(np.asarray(got)).max() == 0.0


def test_qv_lora_impl_parity():
    """qv_lora dispatches both impls to the same q/v outputs."""
    B, S, H, KV, hd, d, r, na = 3, 8, 4, 2, 8, 32, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(2), 7)
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    q = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    sub = {"q": {"a": jax.random.normal(ks[3], (na, d, r)) * 0.05,
                 "b": jax.random.normal(ks[4], (na, r, H * hd)) * 0.05},
           "v": {"a": jax.random.normal(ks[5], (na, d, r)) * 0.05,
                 "b": jax.random.normal(ks[6], (na, r, KV * hd)) * 0.05}}
    aidx = np.array([1, na, 0], np.int32)
    q1, v1 = qv_lora(x, sub, jnp.asarray(aidx), q, v, impl="gather")
    q2, v2 = qv_lora(x, sub, jnp.asarray(aidx), q, v, impl="segmented",
                     seg=_seg_meta(aidx, na, S))
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v1), atol=1e-4)


def test_pallas_kernel_rectangular_out():
    """The Pallas kernel itself (interpret mode) supports out != d — the q/v
    serve deltas project to H*hd / KV*hd, not d."""
    T, d, r, na, out, bt = 64, 32, 4, 3, 48, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    a = jax.random.normal(ks[1], (na, d, r)) * 0.05
    b = jax.random.normal(ks[2], (na, r, out)) * 0.05
    blocks = jnp.asarray([0, 2, na, 1], jnp.int32)
    got = segmented_lora(x, blocks, a, b, block_t=bt, interpret=True)
    from repro.kernels import ref
    want = ref.segmented_lora_ref(x, blocks, a, b, bt)
    assert got.shape == (T, out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------- model-level parity on the serve plane ----------------

@pytest.fixture(scope="module")
def fm_pair():
    cfg = reduced(get_config("moment-large"))
    pair = {}
    for impl in ("segmented", "gather"):
        fm = PhysicalFM(cfg, seed=0, input_len=12, lora_rank=4,
                        lora_impl=impl, seg_block_t=BT)
        for i in range(3):
            tree = init_single_adapter(jax.random.PRNGKey(i), cfg, 4)
            # randomize B (zero-init by default) so deltas are nonzero
            leaves, tdef = jax.tree.flatten(tree)
            rks = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
            tree = jax.tree.unflatten(tdef, [
                jax.random.normal(k, l.shape, l.dtype) * 0.05
                for k, l in zip(rks, leaves)])
            fm.adapters.add(f"lora{i}", tree)
        pair[impl] = fm
    return pair


def test_run_batch_segmented_matches_gather(fm_pair):
    seg, gat = fm_pair["segmented"], fm_pair["gather"]
    cap = seg.adapters.capacity()
    rng = np.random.RandomState(0)
    x = rng.randn(6, 12, seg.cfg.d_model).astype(np.float32)
    aidx = np.array([0, 0, 2, cap, 1, 2], np.int32)   # mixed + sentinel
    f_seg = seg.run_batch(x, aidx)
    f_gat = gat.run_batch(x, aidx)
    np.testing.assert_allclose(f_seg, f_gat, atol=1e-4)
    # the adapters actually do something
    f_base = gat.run_batch(x, np.full(6, cap, np.int32))
    assert np.abs(f_gat - f_base).max() > 1e-3


def test_auto_impl_is_default_and_consults_crossover_table(fm_pair):
    """``lora_impl="auto"`` (the server default) resolves gather vs segmented
    per (batch bucket, adapter count) from the measured crossover table;
    explicit overrides pass through untouched."""
    from repro.core.physical import AUTO_LORA_TABLE
    assert PhysicalFM.__init__.__kwdefaults__["lora_impl"] == "auto"
    seg = fm_pair["segmented"]
    assert seg.resolve_lora_impl(32) == seg.lora_impl == "segmented"
    auto = PhysicalFM(seg.cfg, seed=0, input_len=12, lora_rank=4,
                      seg_block_t=BT)
    for i in range(3):
        auto.adapters.add(f"lora{i}", seg.adapters._trees[i])
    # the cell the bench called out: batch 32 spread over 4 adapters loses
    # to gather (block padding fragments); batch 32 on one adapter wins big
    assert auto.resolve_lora_impl(32, num_adapters=4) == "gather"
    assert auto.resolve_lora_impl(32, num_adapters=1) == "segmented"
    assert auto.resolve_lora_impl(6, num_adapters=3) == \
        AUTO_LORA_TABLE[(8, 4)]                  # buckets round up
    # auto serving matches the pinned paths (same numerics either way)
    rng = np.random.RandomState(0)
    x = rng.randn(5, 12, seg.cfg.d_model).astype(np.float32)
    aidx = np.array([0, 2, auto.adapters.capacity(), 1, 0], np.int32)
    np.testing.assert_allclose(auto.run_batch(x, aidx),
                               fm_pair["gather"].run_batch(x, aidx),
                               atol=1e-4)


def test_zero_recompiles_within_slot_capacity(fm_pair):
    """Binding a new task (adding an adapter) within the slot bucket must not
    add jit cache entries nor retrace the existing executable."""
    fm = fm_pair["segmented"]
    cap = fm.adapters.capacity()
    rng = np.random.RandomState(1)
    x = rng.randn(3, 12, fm.cfg.d_model).astype(np.float32)
    fm.run_batch(x, np.array([0, 1, cap], np.int32))
    keys_before = set(fm._jit_cache)
    compiles_before = fm.compile_count()
    assert len(fm.adapters) < cap                     # room in the bucket
    fm.adapters.new("late-bound", seed=9)             # bind a new task
    fm.run_batch(x, np.array([len(fm.adapters) - 1, 0, cap], np.int32))
    assert set(fm._jit_cache) == keys_before
    assert fm.compile_count() == compiles_before      # zero new executables
    fm.adapters.remove("late-bound")


# ---------------- adapter store invariants ----------------

def test_adapter_store_incremental_stack_and_sentinel():
    cfg = reduced(get_config("moment-large"))
    store = AdapterStore(cfg, rank=4)
    assert store.index("missing") == store.capacity()   # sentinel == NA
    t0 = store.new("a0", seed=0)
    st1 = store.stacked()
    na = jax.tree.leaves(st1)[0].shape[1]
    assert na == store.capacity() == slot_bucket_for(1)
    # incremental add reuses the cached stack object (no full rebuild)
    store.new("a1", seed=1)
    st2 = store.stacked()
    assert jax.tree.leaves(st2)[0].shape[1] == na       # same padded NA
    # slot 1 holds the new adapter, slots >= 2 stay zero
    leaf2 = jax.tree.leaves(st2)[0]
    assert float(jnp.abs(leaf2[:, 2:]).max()) == 0.0
    # sentinel stays out of range of real adapters after the add
    assert store.index("nope") == store.capacity() >= len(store.ids)
    # removal invalidates precisely: stack rebuilt without the adapter
    store.remove("a0")
    st3 = store.stacked()
    l0 = jax.tree.leaves(store._trees[0])[0][:, 0]
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(st3)[0][:, 0]),
                               np.asarray(l0))


def test_segment_metadata_inverse_roundtrip():
    from repro.kernels.segmented_lora import sort_by_adapter
    ids = np.random.RandomState(2).randint(0, 5, 57)
    tp = padded_tokens(57, 6, 16)
    perm, inv, blocks = segment_metadata(ids, 4, block_t=16, max_tokens=tp)
    x = np.random.RandomState(3).randn(57, 8).astype(np.float32)
    # gather-out then gather-back is the identity on real rows
    np.testing.assert_array_equal(x[perm][inv], x)
    # each block holds rows of exactly the adapter blocks[] names (pad rows,
    # marked -1 in the raw permutation, excluded)
    raw_perm, raw_blocks, total = sort_by_adapter(ids, 4, block_t=16,
                                                  max_tokens=tp)
    np.testing.assert_array_equal(raw_blocks, blocks)
    for i in range(total // 16):
        rows = raw_perm[i * 16:(i + 1) * 16]
        real = {int(ids[j]) for j in rows if j >= 0}
        assert len(real) <= 1
        if real:
            assert real.pop() == raw_blocks[i]


# ---------------- tight segment-padding bound ----------------

def test_padded_tokens_tight_bound():
    """``padded_tokens`` upper-bounds the actual sorted/padded total for any
    ragged segment split, is block-aligned, never exceeds the old loose bound
    (ceil(n/bt)*bt + s*bt), and is achieved exactly by the worst case of
    ``s - 1`` singleton segments plus one big remainder."""
    from repro.kernels.segmented_lora import sort_by_adapter
    rng = np.random.RandomState(7)
    for _ in range(50):
        n = rng.randint(1, 300)
        na = rng.randint(1, 10)
        bt = int(rng.choice([4, 8, 16]))
        ids = rng.randint(0, na + 1, n)            # includes the sentinel
        s_max = min(n, na + 2)
        tp = padded_tokens(n, s_max, bt)
        _, _, total = sort_by_adapter(ids, na, block_t=bt)
        assert total <= tp, (total, tp)
        assert tp % bt == 0
        assert tp <= -(-n // bt) * bt + s_max * bt
    # tightness: 3 singleton segments + a 97-token remainder needs every
    # block the bound grants
    n, bt = 100, 16
    ids = np.concatenate([np.arange(3), np.full(n - 3, 3)])
    _, _, total = sort_by_adapter(ids, 4, block_t=bt)
    assert total == padded_tokens(n, 4, bt) == 160


def test_ragged_singleton_segments_parity():
    """Worst-case ragged co-batch (every adapter a singleton segment except
    one bulk segment) keeps exact gather-path parity under the tight bound."""
    S, d, r, na = 1, 32, 4, 6
    aidx = np.array([0, 1, 2, 3, 4, na, 5, 5, 5, 5, 5, 5, 5], np.int32)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    x = jax.random.normal(ks[0], (len(aidx), S, d), jnp.float32)
    a = jax.random.normal(ks[1], (na, d, r)) * 0.05
    b = jax.random.normal(ks[2], (na, r, d)) * 0.05
    want = apply_lora_delta(x, a, b, jnp.asarray(aidx))
    got = apply_lora_delta_segmented(x, a, b, _seg_meta(aidx, na, S, bt=4))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert np.abs(np.asarray(got)[aidx == na]).max() == 0.0
