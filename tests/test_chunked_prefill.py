"""Chunked shared-prefix prefill (tail-only admission): exact token AND
sampling parity against the full-prefill path, COW divergence inside the
partial boundary page, sharer joins served from the prefix spill tier, and
zero steady-state recompiles across sharer churn with mixed tail buckets
after ``warm_chunked``."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM

BT = 8


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("stablelm-1.6b"))


def _randomized_adapter(fm, i):
    tree = fm.adapters._mod.init_single_adapter(
        jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
    leaves, tdef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
    return jax.tree.unflatten(tdef, [
        jax.random.normal(k, l.shape, l.dtype) * 0.05
        for k, l in zip(ks, leaves)])


def _fm(cfg, impl="segmented", na=3):
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4, lora_impl=impl,
                    seg_block_t=BT)
    for i in range(na):
        fm.adapters.add(f"lora{i}", _randomized_adapter(fm, i))
    return fm


def _isolated_tokens(fm, prompt, steps, **kw):
    """Reference: the prompt served ALONE on a fresh paged pool."""
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=24, chunk=2,
                       paged=True, page_size=4, **kw)
    eng.join("ref", prompt, adapter_id="lora0", max_new_tokens=steps, rid=0)
    (d,) = eng.drain()
    return d.tokens


def _shared_prompts(cfg, seed, n_sharers=3, prefix_tokens=8):
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, cfg.vocab_size, prefix_tokens).astype(np.int32)
    return [np.concatenate([prefix,
                            rng.randint(0, cfg.vocab_size,
                                        1 + i).astype(np.int32)])
            for i in range(n_sharers)]


def _serve_all(eng, prompts, steps=6):
    for i, p in enumerate(prompts):
        eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=steps, rid=i)
    return {d.rid: d.tokens for d in eng.drain()}


@pytest.mark.parametrize("sampling", [dict(temperature=0.0),
                                      dict(temperature=0.7, top_k=8,
                                           sample_seed=3)])
def test_chunked_matches_full_prefill_exactly(cfg, sampling):
    """Engines differing ONLY in ``chunked_prefill`` produce bit-identical
    token streams for every sharer — greedy AND seeded top-k sampling. The
    tail attends the prefix pages' float sidecars (the exact values a full
    prefill computes), so chunking changes the work done, not the math."""
    fm = _fm(cfg, na=1)
    prompts = _shared_prompts(cfg, seed=31)
    outs = {}
    for chunked in (False, True):
        eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6,
                           chunk=2, paged=True, page_size=4,
                           chunked_prefill=chunked, **sampling)
        outs[chunked] = _serve_all(eng, prompts)
        if chunked:
            assert eng.prefill_tokens_saved > 0
            assert eng.tail_tokens_computed < sum(len(p) for p in prompts)
        else:
            assert eng.prefill_tokens_saved == 0
    assert outs[True] == outs[False]


def test_admitted_log_charges_tail_only(cfg):
    """A chunked sharer's admission record carries the TAIL token count
    (what the device computed), not the full prompt — the number BFQ
    charges its task."""
    fm = _fm(cfg, na=1)
    prompts = _shared_prompts(cfg, seed=32, n_sharers=2)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=4, chunk=2,
                       paged=True, page_size=4)
    for i, p in enumerate(prompts):
        eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=4, rid=i)
    log = {rid: (toks, tail) for rid, _, toks, tail in eng.take_admitted()}
    assert log[0][0] == log[0][1] == len(prompts[0])   # holder: full charge
    toks, tail = log[1]
    assert toks == len(prompts[1]) and 0 < tail < toks  # sharer: tail only
    eng.drain()


def test_cow_divergence_inside_boundary_page(cfg):
    """Sharers whose prompts diverge INSIDE the partial boundary page: the
    chunked path maps only the full shared pages and recomputes the whole
    boundary page privately, so each stream matches its isolated reference
    and the boundary page is never shared."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(33)
    prefix = rng.randint(0, cfg.vocab_size, 10).astype(np.int32)  # 2.5 pages
    prompts = [np.concatenate([prefix,
                               rng.randint(0, cfg.vocab_size,
                                           2).astype(np.int32)])
               for _ in range(2)]
    assert not np.array_equal(prompts[0][10:], prompts[1][10:])
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4)
    s0 = eng.join("a", prompts[0], adapter_id="lora0", max_new_tokens=6,
                  rid=0)
    s1 = eng.join("b", prompts[1], adapter_id="lora0", max_new_tokens=6,
                  rid=1)
    assert eng.prefix_hits == 1
    # pages 0-1 shared, the divergent boundary page (index 2) private
    assert (eng._ptab[s0, :2] == eng._ptab[s1, :2]).all()
    assert eng._ptab[s0, 2] != eng._ptab[s1, 2]
    done = {d.rid: d.tokens for d in eng.drain()}
    for i, p in enumerate(prompts):
        assert done[i] == _isolated_tokens(fm, p, 6)


def test_sharer_join_after_prefix_spill_restore(cfg):
    """A sharer joining AFTER the prefix's last holder retired (pages moved
    to the host spill tier) restores the leading pages by DMA, tail-prefills
    the rest, and still matches the full-prefill reference exactly — the
    float sidecars ride through the spill round trip."""
    fm = _fm(cfg, na=1)
    prompts = _shared_prompts(cfg, seed=34, n_sharers=2)
    ref = {}
    for chunked in (False, True):
        eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6,
                           chunk=2, paged=True, page_size=4,
                           chunked_prefill=chunked, spill_bytes=64 << 20)
        eng.join("hold", prompts[0], adapter_id="lora0", max_new_tokens=6,
                 rid=0)
        (d0,) = eng.drain()                      # holder gone -> prefix spills
        assert len(eng._prefix_registry) == 0 and eng.spilled_pages > 0
        eng.join("late", prompts[1], adapter_id="lora0", max_new_tokens=6,
                 rid=1)
        if chunked:
            assert eng.spill_prefix_hits == 1 and eng.restored_pages >= 1
            assert eng.prefill_tokens_saved > 0
        (d1,) = eng.drain()
        ref[chunked] = (d0.tokens, d1.tokens)
    assert ref[True] == ref[False]


def test_zero_recompiles_across_sharer_churn_mixed_tails(cfg):
    """After one full-prefill warm per prompt bucket plus ``warm_chunked``,
    sharer churn — joins landing in EVERY tail bucket, leaves, a mid-stream
    preemption whose resume re-enters the chunked path — adds ZERO
    executables: tail lengths bucket, page ids and prefix lengths are
    traced operands, never jit keys."""
    fm = _fm(cfg)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4, prompt_buckets=(4, 16))
    rng = np.random.RandomState(35)
    for plen in (4, 16):                        # warm each prompt bucket
        eng.join("w", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
    eng.drain()
    eng.warm_chunked()
    compiles = eng.compile_count()
    # wave churn: a holder plus sharers whose private tails land in each
    # tail bucket (4, 8 behind the 8-token prefix; 16 behind the 4-token
    # one); everything drains between waves, so the registry also churns
    pfx8 = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    pfx4 = rng.randint(0, cfg.vocab_size, 4).astype(np.int32)
    waves = [(pfx8, (1, 5)), (pfx8, (6, 3)), (pfx4, (12, 2))]
    rid = 0
    for w, (prefix, tails) in enumerate(waves):
        eng.join("hold", np.concatenate(
            [prefix, rng.randint(0, cfg.vocab_size, 2).astype(np.int32)]),
            adapter_id="lora0", max_new_tokens=6, rid=1000 + w)
        sharers = []
        for tail in tails:
            rid += 1
            sharers.append(eng.join(f"s{rid}", np.concatenate(
                [prefix,
                 rng.randint(0, cfg.vocab_size, tail).astype(np.int32)]),
                adapter_id="lora0", max_new_tokens=4, rid=rid))
        if w == 1:                              # preempt + chunked resume
            eng.step_chunk()
            eng._preempt(sharers[0])
        eng.drain()
        assert eng.free_page_count() == eng.total_pages - 1
    assert eng.prefix_hits >= 6                 # the chunked path really ran
    assert eng.preemptions == 1
    assert eng.compile_count() == compiles
    assert eng.free_page_count() == eng.total_pages - 1
