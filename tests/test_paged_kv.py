"""Paged int8 KV pool: kernel parity (jnp oracle + Pallas interpret mode),
paged-vs-dense decode parity over join/leave churn with ragged prompts, page
recycling after retire, zero recompiles across churn + page allocation,
join-burst deferral (regression: beyond-capacity admission queues and drains
instead of crashing the tick), preemption under page pressure, memory-aware
loop admission, copy-on-write prefix sharing (exact parity, refcounted
release, sharer preemption isolation, admission-gate dedup discount),
bounded pending-queue lookahead (head-of-line regression), the required
prompt length on the paged memory gate, and proactive int8 scale refresh."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM
from repro.kernels import ops, ref
from repro.kernels.paged_decode_attention import paged_decode_attention

BT = 8


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("stablelm-1.6b"))


def _randomized_adapter(fm, i):
    tree = fm.adapters._mod.init_single_adapter(
        jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
    leaves, tdef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
    return jax.tree.unflatten(tdef, [
        jax.random.normal(k, l.shape, l.dtype) * 0.05
        for k, l in zip(ks, leaves)])


def _fm(cfg, impl="segmented", na=3):
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4, lora_impl=impl,
                    seg_block_t=BT)
    for i in range(na):
        fm.adapters.add(f"lora{i}", _randomized_adapter(fm, i))
    return fm


# ---------------- kernel parity ----------------

def _paged_case(seed=0, B=3, H=8, KV=2, hd=16, ps=8, P=11, MP=4,
                lens=(9, 25, 1)):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32))
    kp = jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8))
    vp = jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8))
    ks = jnp.asarray(rng.rand(P, KV).astype(np.float32) * 0.05 + 1e-3)
    vs = jnp.asarray(rng.rand(P, KV).astype(np.float32) * 0.05 + 1e-3)
    pt = np.zeros((B, MP), np.int32)           # disjoint pages per stream
    free = list(range(1, P))
    rng.shuffle(free)
    for b in range(B):
        for j in range(-(-int(lens[b]) // ps)):
            pt[b, j] = free.pop()
    return q, kp, vp, ks, vs, jnp.asarray(pt), jnp.asarray(
        np.asarray(lens, np.int32))


@pytest.mark.parametrize("window", [None, 6])
def test_paged_kernel_interpret_matches_ref(window):
    """Pallas paged decode (interpret mode on CPU) vs the jnp gather oracle."""
    q, kp, vp, ks, vs, pt, lens = _paged_case()
    want = ref.paged_decode_attention_ref(q, kp, vp, ks, vs, pt, lens,
                                          window=window)
    got = paged_decode_attention(q, kp, vp, ks, vs, pt, lens, window=window,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_ref_matches_dense_int8_ref():
    """Gathering pages into a dense layout and running the dense int8 oracle
    must reproduce the paged oracle exactly (uniform per-stream scales, the
    layout a fresh admission writes)."""
    B, KV, ps, MP, hd = 2, 2, 8, 3, 16
    P = 1 + B * MP
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, 6, hd).astype(np.float32))
    kp = rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8)
    vp = rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8)
    row_ks = rng.rand(B, KV).astype(np.float32) * 0.05 + 1e-3
    row_vs = rng.rand(B, KV).astype(np.float32) * 0.05 + 1e-3
    pt = 1 + np.arange(B * MP, dtype=np.int32).reshape(B, MP)
    ks = np.zeros((P, KV), np.float32)
    vs = np.zeros((P, KV), np.float32)
    for b in range(B):
        ks[pt[b]] = row_ks[b]
        vs[pt[b]] = row_vs[b]
    lens = np.array([19, 5], np.int32)
    got = ref.paged_decode_attention_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(ks),
        jnp.asarray(vs), jnp.asarray(pt), jnp.asarray(lens))
    k_dense = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, KV, MP * ps, hd)
    v_dense = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, KV, MP * ps, hd)
    want = ref.decode_attention_int8_ref(
        jnp.asarray(q), jnp.asarray(k_dense), jnp.asarray(v_dense),
        jnp.asarray(row_ks), jnp.asarray(row_vs), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_ops_paged_dispatch_model_layout():
    """The ops wrapper adapts the model-layout arena (P, ps, KV, hd)."""
    q, kp, vp, ks, vs, pt, lens = _paged_case(seed=2)
    got = ops.paged_decode_attention(q, kp.transpose(0, 2, 1, 3),
                                     vp.transpose(0, 2, 1, 3), ks, vs, pt,
                                     lens)
    want = ref.paged_decode_attention_ref(q, kp, vp, ks, vs, pt, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


# ---------------- paged engine vs dense engine ----------------

def _churn(eng, cfg, prompts, names):
    """Join/leave churn with ragged prompt lengths; returns rid->tokens."""
    out = {}
    i = 0
    for i in range(4):
        eng.join(f"t{i}", prompts[i], adapter_id=names[i % 4],
                 max_new_tokens=3 + i, rid=i)
    joined = 4
    while eng.active_count() or eng.pending_count():
        for s in eng.step_chunk():
            out[s.rid] = s.tokens
        while joined < len(prompts) and eng.free_slots() and \
                eng.can_admit(len(prompts[joined])):
            eng.join(f"t{joined}", prompts[joined],
                     adapter_id=names[joined % 4], max_new_tokens=4,
                     rid=joined)
            joined += 1
    return out


def test_paged_matches_dense_over_churn_ragged_prompts(cfg):
    """The paged pool must produce the SAME greedy token streams as the dense
    int8 pool across join/leave churn with ragged prompt lengths — paging is
    a memory layout, not a numeric change."""
    rng = np.random.RandomState(5)
    lens = [8, 3, 6, 1, 7, 4, 8, 2]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    names = ["lora0", "lora1", "lora2", None]
    outs = {}
    for mode in ("dense", "paged"):
        fm = _fm(cfg)
        kw = dict(num_slots=4, prompt_len=8, max_new=8, chunk=2)
        if mode == "paged":
            kw.update(paged=True, page_size=4)
        outs[mode] = _churn(DecodeEngine(fm, **kw), cfg, prompts, names)
    assert outs["paged"] == outs["dense"]
    assert len(outs["paged"]) == len(prompts)


def test_page_recycling_no_stale_leak(cfg):
    """A retired stream's pages go back to the free list; a new stream that
    recycles them must decode exactly like one admitted into a FRESH pool —
    no stale K/V from the previous owner can leak through the masks."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(9)
    p_old = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    p_new = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)

    def serve(eng, p, steps):
        eng.join("t", p, adapter_id="lora0", max_new_tokens=steps, rid=0)
        (d,) = eng.drain()
        return d.tokens

    recycled = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8,
                            chunk=2, paged=True, page_size=4, total_pages=7)
    first = serve(recycled, p_old, 8)           # fills most of the arena
    assert recycled.free_page_count() == 6      # all pages recycled
    got = serve(recycled, p_new, 6)             # reuses the same pages
    fresh = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8,
                         chunk=2, paged=True, page_size=4, total_pages=7)
    assert got == serve(fresh, p_new, 6)
    assert len(first) == 8 and len(got) == 6


def test_paged_zero_recompiles_across_churn_and_page_alloc(cfg):
    """After one warm join per prompt bucket, churn — including decode page
    allocation, recycling, deferral-drain — adds ZERO executables: page ids,
    tables and lengths are traced operands, never jit keys."""
    fm = _fm(cfg)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4, prompt_buckets=(4, 16))
    rng = np.random.RandomState(3)
    for plen in (4, 16):                        # warm each bucket once
        eng.join("w", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
    eng.drain()
    compiles = eng.compile_count()
    names = ["lora0", "lora1", None, "lora2"]
    for i, plen in enumerate((1, 3, 7, 9, 13, 16, 2, 11)):
        eng.join(f"t{i}", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id=names[i % 4], max_new_tokens=2 + i % 3, rid=i)
        if not eng.free_slots():
            eng.step_chunk()
    eng.drain()
    assert eng.compile_count() == compiles
    assert eng.free_page_count() == eng.total_pages - 1


# ---------------- deferral + preemption ----------------

def test_join_burst_defers_and_drains(cfg):
    """Regression for the mid-loop crash: a burst of admissions beyond pool
    capacity must QUEUE (join returns -1) and drain across chunks — every
    stream completes, nothing raises."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8, chunk=2,
                       paged=True, page_size=4, total_pages=9)
    rng = np.random.RandomState(1)
    slots = [eng.join(f"t{i}", rng.randint(0, cfg.vocab_size, 4 + i % 5),
                      adapter_id="lora0", max_new_tokens=6, rid=i)
             for i in range(6)]
    assert slots.count(-1) == 4 and eng.pending_count() == 4
    assert eng.deferrals == 4
    done = eng.drain()
    assert sorted(d.rid for d in done) == list(range(6))
    assert all(len(d.tokens) == 6 for d in done)
    assert eng.free_page_count() == 8           # everything returned


def test_dense_join_still_raises_when_full(cfg):
    """The dense layout keeps its historical contract: static slot capacity,
    the caller drains first."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=1, prompt_len=8, max_new=4, chunk=2)
    p = np.arange(8) % cfg.vocab_size
    eng.join("a", p, adapter_id="lora0", max_new_tokens=4, rid=0)
    with pytest.raises(RuntimeError, match="no free decode slots"):
        eng.join("b", p, adapter_id="lora0", max_new_tokens=4, rid=1)


def test_page_pressure_preempts_and_completes(cfg):
    """Two long streams on an arena that holds only one to completion: the
    younger stream is preempted (pages reclaimed, re-queued with its
    generated prefix) and BOTH still finish with full budgets. A resumed
    stream must keep its ORIGINAL prompt on the slot — folding the combined
    resume prompt back in would duplicate the generated prefix on a second
    preemption."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=24, chunk=4,
                       paged=True, page_size=4, total_pages=10)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]
    with warnings.catch_warnings():             # resume prompt > bucket warns
        warnings.simplefilter("ignore", RuntimeWarning)
        for i in range(2):
            eng.join(f"t{i}", prompts[i], adapter_id="lora0",
                     max_new_tokens=24, rid=i)
        done = eng.drain()
    assert sorted(d.rid for d in done) == [0, 1]
    assert all(len(d.tokens) == 24 for d in done)
    assert eng.preemptions > 0
    assert eng.free_page_count() == 9
    for d in done:                              # original prompt, always
        np.testing.assert_array_equal(d.prompt, prompts[d.rid])


def test_join_raises_when_prompt_can_never_fit(cfg):
    """A prompt whose bucket + chunk headroom exceeds the whole arena is a
    configuration error: deferring it would spin drain()/the serve loop
    forever, so join must raise immediately."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=8, chunk=4,
                       paged=True, page_size=4, total_pages=3)  # 2 usable
    with pytest.raises(ValueError, match="usable pages"):
        eng.join("t", np.arange(16, dtype=np.int32) % cfg.vocab_size,
                 adapter_id="lora0", max_new_tokens=4, rid=0)


def test_sharer_admitted_on_discount_strands_then_wedge_raises(cfg):
    """A full-length prompt that only fits the arena thanks to its shared
    prefix is ACCEPTED (deferred, not the old ValueError). If its
    registered sharer then retires, the request is stranded: it stops
    blocking other work, and only once the engine has nothing live and
    nothing viable left does step_chunk raise the configuration error —
    never mid-service for unrelated streams."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(61)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=4, chunk=2,
                       paged=True, page_size=4, total_pages=5,  # 4 usable
                       prompt_buckets=(8, 16))
    eng.join("a", prefix, adapter_id="lora0", max_new_tokens=4, rid=0)
    # full 16-token prompt: bucket 4 pages + chunk 1 > 4 usable — only the
    # 2-page prefix discount lets it in (deferred while A holds the pages)
    big = np.concatenate([prefix, rng.randint(0, cfg.vocab_size,
                                              8).astype(np.int32)])
    assert eng.join("b", big, adapter_id="lora0", max_new_tokens=2,
                    rid=1) == -1
    done = []
    with pytest.raises(ValueError, match="no longer fit"):
        for _ in range(50):                     # A retires -> B stranded
            done += eng.step_chunk()
    assert [d.rid for d in done] == [0]         # A served fine regardless
    assert len(done[0].tokens) == 4


# ---------------- copy-on-write prefix sharing ----------------

def _isolated_tokens(fm, prompt, steps, **kw):
    """Reference: the prompt served ALONE on a fresh paged pool."""
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=24, chunk=2,
                       paged=True, page_size=4, **kw)
    eng.join("ref", prompt, adapter_id="lora0", max_new_tokens=steps, rid=0)
    (d,) = eng.drain()
    return d.tokens


def test_prefix_sharing_exact_parity_and_dedup(cfg):
    """Streams sharing a page-aligned prompt prefix MAP the registered
    pages instead of copying them — and because admission quantizes per
    page (a page's scale depends only on the tokens it covers), the shared
    engine's token streams are EXACTLY the unshared engine's. After drain,
    every refcount returns to zero and the registry empties."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(21)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)  # 2 pages
    prompts = [np.concatenate([prefix,
                               rng.randint(0, cfg.vocab_size,
                                           1 + i).astype(np.int32)])
               for i in range(3)]
    outs, infos = {}, {}
    for share in (True, False):
        eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6,
                           chunk=2, paged=True, page_size=4,
                           prefix_sharing=share)
        for i, p in enumerate(prompts):
            eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=6, rid=i)
        infos[share] = (eng.shared_page_count(), eng.dedup_saved_pages(),
                        eng.used_page_count())
        outs[share] = {d.rid: d.tokens for d in eng.drain()}
        assert (eng._page_refs[1:] == 0).all()
        assert not eng._prefix_registry and not eng._page_key
        assert eng.free_page_count() == eng.total_pages - 1
    assert outs[True] == outs[False]            # sharing is exact
    shared, saved, used = infos[True]
    _, _, used_unshared = infos[False]
    assert shared == 2 and saved == 4           # 2 sharers x 2 prefix pages
    assert used == used_unshared - saved        # dedup = real pages saved


def test_prefix_sharing_divergent_tails_match_isolated(cfg):
    """COW boundary: sharers with different suffixes each produce the same
    stream as when served ALONE on a fresh pool — private tails never leak
    across the shared prefix pages."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(22)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.randint(0, cfg.vocab_size,
                                           2 + i).astype(np.int32)])
               for i in range(3)]
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=8, chunk=2,
                       paged=True, page_size=4)
    for i, p in enumerate(prompts):
        eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=8, rid=i)
    assert eng.prefix_hits == 2
    done = {d.rid: d.tokens for d in eng.drain()}
    for i, p in enumerate(prompts):
        assert done[i] == _isolated_tokens(fm, p, 8)


def test_prefix_no_sharing_across_adapters(cfg):
    """LoRA changes the projected V: identical prompts under different
    adapters must NOT share pages."""
    fm = _fm(cfg, na=2)
    rng = np.random.RandomState(23)
    p = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=8, max_new=4, chunk=2,
                       paged=True, page_size=4)
    eng.join("a", p, adapter_id="lora0", max_new_tokens=4, rid=0)
    eng.join("b", p, adapter_id="lora1", max_new_tokens=4, rid=1)
    assert eng.prefix_hits == 0 and eng.shared_page_count() == 0
    eng.join("c", p, adapter_id="lora0", max_new_tokens=4, rid=2)
    assert eng.prefix_hits == 1                 # same adapter DOES share
    eng.drain()


def test_preempt_sharer_keeps_other_stream_valid(cfg):
    """Preempting one sharer releases only ITS references: the surviving
    sharer's mapped pages stay intact and its stream matches the isolated
    reference token for token; the preempted stream resumes and completes
    with its original prompt preserved."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(24)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    pa = np.concatenate([prefix, rng.randint(0, cfg.vocab_size,
                                             2).astype(np.int32)])
    pb = np.concatenate([prefix, rng.randint(0, cfg.vocab_size,
                                             3).astype(np.int32)])
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=5, chunk=2,
                       paged=True, page_size=4)
    sa = eng.join("a", pa, adapter_id="lora0", max_new_tokens=5, rid=0)
    sb = eng.join("b", pb, adapter_id="lora0", max_new_tokens=5, rid=1)
    assert eng.shared_page_count() == 2
    eng.step_chunk()                            # both decode a little
    eng._preempt(sb)                            # evict the sharer B
    assert eng.preemptions == 1
    assert eng.shared_page_count() == 0         # B's references dropped...
    refs = eng._page_refs[eng._ptab[sa, :eng._held[sa]]]
    assert (refs == 1).all()                    # ...but A's pages survive
    done = {d.rid: d for d in eng.drain()}
    assert done[0].tokens == _isolated_tokens(fm, pa, 5)
    assert len(done[1].tokens) == 5             # resumed stream completed
    np.testing.assert_array_equal(done[1].prompt, pb)
    assert (eng._page_refs[1:] == 0).all()
    assert eng.free_page_count() == eng.total_pages - 1


def test_admission_gate_discounts_shared_prefix(cfg):
    """The memory gate knows a sharer only allocates its private tail: an
    admission that would NOT fit as a full copy passes ``can_admit`` when
    its prompt shares a registered prefix — the capacity multiplier the
    whole feature exists for."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(25)
    prefix = rng.randint(0, cfg.vocab_size, 12).astype(np.int32)  # 3 pages
    p0 = np.concatenate([prefix, rng.randint(0, cfg.vocab_size,
                                             2).astype(np.int32)])
    p1 = np.concatenate([prefix, rng.randint(0, cfg.vocab_size,
                                             3).astype(np.int32)])
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=4, chunk=2,
                       paged=True, page_size=4, total_pages=9,  # 8 usable
                       prompt_buckets=(16,))
    eng.join("a", p0, adapter_id="lora0", max_new_tokens=4, rid=0)
    # full copy: bucket 4 + chunk 1 headroom = 5 > 4 free -> blocked
    assert not eng.can_admit(len(p1))
    fresh = rng.randint(0, cfg.vocab_size, 15).astype(np.int32)
    assert not eng.can_admit(prompt=fresh, adapter_id="lora0")
    # sharer: 3 of its 4 bucket pages are already mapped -> fits
    assert eng.can_admit(prompt=p1, adapter_id="lora0")
    assert eng.join("b", p1, adapter_id="lora0", max_new_tokens=4,
                    rid=1) >= 0
    eng.drain()


def test_can_admit_requires_prompt_len_on_paged(cfg):
    """Regression: the paged memory gate consulted with the old silent
    1-token default wildly under-estimated admissions; the paged path now
    requires the prompt length (dense keeps the cheap slot-only check)."""
    fm = _fm(cfg, na=1)
    paged = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=4, chunk=2,
                         paged=True, page_size=4)
    with pytest.raises(TypeError, match="prompt_tokens"):
        paged.can_admit()
    assert paged.can_admit(8) is True
    assert paged.can_admit(prompt=np.arange(5, dtype=np.int32)) is True
    dense = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=4, chunk=2)
    assert dense.can_admit() is True            # dense: slot check only


# ---------------- pending-queue head-of-line lookahead ----------------

def test_pending_hol_small_admits_past_blocked_large_head(cfg):
    """Regression (head-of-line blocking): with a large deferred prompt at
    the pending head that free pages cannot cover, a small prompt queued
    BEHIND it admits anyway (bounded skip-ahead) — and the head itself
    still completes once pages free up."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(31)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=8, chunk=2,
                       paged=True, page_size=4, total_pages=9,  # 8 usable
                       prompt_buckets=(4, 16))
    # background stream holds half the arena and keeps decoding
    eng.join("bg", rng.randint(0, cfg.vocab_size, 16), adapter_id="lora0",
             max_new_tokens=8, rid=0)
    # large head: bucket 16 needs 4 pages + headroom > 4 free -> defers
    assert eng.join("big", rng.randint(0, cfg.vocab_size, 15),
                    adapter_id="lora0", max_new_tokens=4, rid=1) == -1
    # small prompt behind it: bucket 4 needs 1 page + headroom -> fits
    assert eng.join("small", rng.randint(0, cfg.vocab_size, 3),
                    adapter_id="lora0", max_new_tokens=6, rid=2) == -1
    done = eng.step_chunk()                     # drains the pending queue
    active = [s.rid for s in eng.slots if s is not None]
    assert 2 in active, "small prompt still starved behind the large head"
    assert 1 in eng.pending_rids(), "large head admitted without pages?"
    assert eng.hol_bypasses == 1
    done += eng.drain()                         # head admits as pages free
    assert sorted(d.rid for d in done) == [0, 1, 2]
    assert eng.free_page_count() == eng.total_pages - 1


def test_pending_hol_skip_cap_protects_head(cfg):
    """Fairness: after ``hol_skip_cap`` consecutive bypasses the lookahead
    window collapses to the head alone — later small prompts wait even
    though their pages are free, so the head is delayed, never starved."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(32)
    eng = DecodeEngine(fm, num_slots=8, prompt_len=16, max_new=12, chunk=2,
                       paged=True, page_size=4, total_pages=9,  # 8 usable
                       prompt_buckets=(4, 16), pending_lookahead=8,
                       hol_skip_cap=2)
    eng.join("bg", rng.randint(0, cfg.vocab_size, 16), adapter_id="lora0",
             max_new_tokens=12, rid=0)
    assert eng.join("big", rng.randint(0, cfg.vocab_size, 15),
                    adapter_id="lora0", max_new_tokens=2, rid=1) == -1
    for i in range(4):                          # four small prompts behind
        assert eng.join(f"s{i}", rng.randint(0, cfg.vocab_size, 2),
                        adapter_id="lora0", max_new_tokens=2,
                        rid=10 + i) == -1
    done = eng.step_chunk()
    # exactly hol_skip_cap smalls bypassed; the rest wait behind the head
    assert eng.hol_bypasses == 2
    assert eng.pending_rids()[0] == 1 and 13 in eng.pending_rids()
    done += eng.drain()
    assert sorted(d.rid for d in done) == [0, 1, 10, 11, 12, 13]


def test_boundary_page_stamped_at_slot_scale(cfg):
    """The prompt/decode boundary page (partial page decode appends into)
    must carry the SLOT-WIDE admission scale, not its prompt-local one — a
    page holding a few small-magnitude prompt tokens would otherwise clip
    every decode-era K/V written into it (regression for the per-page
    admission quantize)."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(51)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8, chunk=2,
                       paged=True, page_size=4)
    slot = eng.join("t", rng.randint(0, cfg.vocab_size, 6).astype(np.int32),
                    adapter_id="lora0", max_new_tokens=2, rid=0)
    bpage = int(eng._ptab[slot, 6 // 4])        # partial page (tokens 4-5)
    fpage = int(eng._ptab[slot, 0])             # full prompt page
    for sub in eng.pool:
        if not (isinstance(sub, dict) and "page_table" in sub):
            continue
        slot_ks = np.asarray(sub["slot_k_scale"])[:, slot]
        np.testing.assert_allclose(np.asarray(sub["k_scale"])[:, bpage],
                                   slot_ks, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(sub["v_scale"])[:, bpage],
                                   np.asarray(sub["slot_v_scale"])[:, slot],
                                   rtol=1e-6)
        # full pages keep their (finer) content-local scales
        assert (np.asarray(sub["k_scale"])[:, fpage] <= slot_ks + 1e-12).all()
    eng.drain()


# ---------------- proactive int8 scale refresh ----------------

def test_scale_refresh_triggers_deterministically(cfg):
    """With an artificially low threshold the refresh path fires on normal
    decode: the tail page re-quantizes in place (counted), the stream still
    completes, equal configurations reproduce the stream exactly, and the
    refresh adds no executables after its first compile."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(41)
    p = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)

    def stream(**kw):
        eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=12,
                           chunk=4, paged=True, page_size=4, **kw)
        eng.join("t", p, adapter_id="lora0", max_new_tokens=12, rid=0)
        (d,) = eng.drain()
        return d.tokens, eng

    t1, e1 = stream(scale_refresh=0.01)
    assert e1.scale_refreshes > 0
    compiles = e1.compile_count()
    e1.join("t2", p[:5], adapter_id="lora0", max_new_tokens=12, rid=1)
    e1.drain()
    assert e1.scale_refreshes > 1
    assert e1.compile_count() == compiles       # refresh jit compiled once
    t2, _ = stream(scale_refresh=0.01)
    assert t1 == t2                             # deterministic
    t3, e3 = stream(scale_refresh=0.0)          # disabled: never fires
    assert e3.scale_refreshes == 0 and len(t3) == 12


# ---------------- memory-aware loop admission ----------------

def _loop_server(cfg, *, engine_kwargs):
    from repro.core.server import FMplexServer
    from repro.core.vfm import TaskExtensions
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4,
                    lora_impl="segmented", seg_block_t=BT)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    fm.adapters.new("lora0", seed=0)
    srv.bind_task("gen", "fm0", weight=1.0,
                  extensions=TaskExtensions(adapter_id="lora0"))
    srv.decode_engine("fm0", **engine_kwargs)
    return srv, srv.serve_loop("fm0")


def test_loop_memory_aware_admission_defers_not_raises(cfg):
    """A generative burst against a tiny paged arena: the loop must DEFER
    admissions while pages are short (requests stay queued, ticks keep
    serving) and still complete every stream; occupancy samples land in
    ``page_samples`` for the kv-page gauges."""
    from repro.core.request import Request
    srv, loop = _loop_server(cfg, engine_kwargs=dict(
        num_slots=2, prompt_len=8, max_new=8, chunk=2,
        paged=True, page_size=4, total_pages=9))
    rng = np.random.RandomState(0)
    trace = [Request("gen", 0.0,
                     payload=rng.randint(0, cfg.vocab_size,
                                         4 + i % 5).astype(np.int32),
                     tokens=float(8 + 6), max_new_tokens=6)
             for i in range(6)]
    served = loop.run(trace)
    assert len(served) == 6
    assert all(r.finish_time is not None and len(r.result) == 6
               for r in served)
    eng = srv.engines["fm0"]
    assert eng.free_page_count() == 8
    # loop admissions are individually vetted by tick()'s can_admit gate
    # (one per admit tick), so none should spill into the engine's own
    # deferral queue — requests wait AT THEIR TAG in the scheduler instead
    assert eng.deferrals == 0
    assert loop.page_samples and max(loop.page_samples) > 0

    from repro.serving.metrics import mixed_stats, page_gauges
    stats = mixed_stats(served, page_samples=loop.page_samples)
    assert stats["kv_pages"]["occupancy_p95"] <= 1.0
    assert stats["decode"]["n"] == 6
    g = page_gauges(eng)
    assert g["paged"] and g["used_pages"] == 0 and g["free_pages"] == 8


def test_long_tail_trace_shape():
    from repro.serving.loadgen import long_tail_token_trace
    tr = long_tail_token_trace("t", 50.0, 4.0, prompt_len=16, vocab=100,
                               new_lo=8, new_hi=512, seed=0,
                               min_prompt_len=2)
    assert len(tr) > 50
    news = np.array([r.max_new_tokens for r in tr])
    assert news.min() >= 8 and news.max() <= 512
    assert np.median(news) < news.mean()        # long tail skews the mean
    assert all(2 <= len(r.payload) <= 16 for r in tr)


def test_shared_prefix_trace_shape():
    from repro.serving.loadgen import shared_prefix_token_trace
    tr = shared_prefix_token_trace("t", 50.0, 4.0, prefix_len=8,
                                   prompt_len=16, vocab=100,
                                   shared_frac=0.8, n_prefixes=2,
                                   max_new=6, seed=0)
    assert len(tr) > 50
    assert all(1 <= len(r.payload) <= 16 for r in tr)
    heads = {r.payload[:8].tobytes() for r in tr}
    counts = sorted((sum(1 for r in tr
                         if r.payload[:8].tobytes() == h) for h in heads),
                    reverse=True)
    # two dominant prefix families cover ~80% of the trace
    assert sum(counts[:2]) > 0.6 * len(tr)
    assert all(1 <= r.max_new_tokens <= 6 for r in tr)


def test_loop_shared_prefix_sampling_and_gauges(cfg):
    """The serve loop on a shared-prefix workload: dedup samples land in
    ``shared_samples``, ``mixed_stats`` grows the kv_sharing section and
    ``page_gauges`` reports the sharing counters."""
    from repro.core.request import Request
    srv, loop = _loop_server(cfg, engine_kwargs=dict(
        num_slots=4, prompt_len=16, max_new=6, chunk=2,
        paged=True, page_size=4))
    rng = np.random.RandomState(7)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    trace = [Request("gen", 0.0,
                     payload=np.concatenate(
                         [prefix, rng.randint(0, cfg.vocab_size,
                                              1 + i % 4).astype(np.int32)]),
                     tokens=float(12 + 4), max_new_tokens=4)
             for i in range(6)]
    served = loop.run(trace)
    assert len(served) == 6
    eng = srv.engines["fm0"]
    assert eng.prefix_hits > 0
    assert loop.shared_samples and max(loop.shared_samples) > 0

    from repro.serving.metrics import mixed_stats, page_gauges
    stats = mixed_stats(served, page_samples=loop.page_samples,
                        shared_samples=loop.shared_samples)
    assert stats["kv_sharing"]["dedup_frac_max"] > 0
    g = page_gauges(eng)
    assert g["prefix_hits"] > 0 and g["dedup_saved_pages"] == 0
    assert g["shared_pages"] == 0 and g["logical_pages"] == 0
