"""Checkpoint/restart + elastic resharding + fault-tolerant trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.distributed.elastic import shrink_plan
from repro.distributed.fault import FailureInjector, StragglerDetector
from repro.launch.train import Trainer


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ckpt.save(tmp_path, 5, t)
    got, step = ckpt.restore(tmp_path, t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_async(tmp_path):
    t = tree()
    th = ckpt.save(tmp_path, 1, t, blocking=False)
    th.join()
    ckpt.save(tmp_path, 7, t)
    assert ckpt.latest_step(tmp_path) == 7


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path, tree())


def test_trainer_restart_continues(tmp_path):
    """Failure at step 12 -> restart resumes from checkpoint, finishes all."""
    cfg = reduced(get_config("stablelm-1.6b"))
    tr = Trainer(cfg, batch=2, seq=16, ckpt_dir=tmp_path, ckpt_every=5,
                 lr=1e-3, total_steps=18, async_ckpt=False)
    inj = FailureInjector(fail_at_step=12)
    losses = tr.run(18, injector=inj)
    assert inj.fired
    assert len(losses) >= 18                     # pre-crash + resumed steps
    assert ckpt.latest_step(tmp_path) == 17


def test_straggler_detector():
    sd = StragglerDetector(threshold=2.0, patience=2)
    for i in range(10):
        sd.record(i, 0.1)
    assert not sd.events
    sd.record(10, 0.5)
    flagged = sd.record(11, 0.5)
    assert flagged and sd.events == [11]


def test_shrink_plan():
    p = shrink_plan(256, model_parallel=16, old_data=16)
    assert p.data == 16 and p.grad_accum == 1
    p = shrink_plan(128, model_parallel=16, old_data=16)
    assert p.data == 8 and p.grad_accum == 2     # global batch preserved
    p = shrink_plan(8, model_parallel=16, old_data=16)
    assert p is None                              # model groups broken


def test_cross_mesh_restore_reshards(tmp_path):
    """Restore with explicit shardings places arrays on the current mesh."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = tree()
    ckpt.save(tmp_path, 0, t)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    got, _ = ckpt.restore(tmp_path, t, shardings=sh)
    for leaf in jax.tree.leaves(got):
        assert isinstance(leaf, jax.Array)
