"""Event-loop serving plane: pooled/admission/decode interleaving under one
clock, mid-flight admission into the decode pool, double-buffered pooled
dispatch, zero steady-state recompiles across mixed churn, and the legacy
synchronous ``FMplexServer.step`` contract on top of the loop."""
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.bfq import group_sub_batches
from repro.core.physical import PhysicalFM
from repro.core.request import Batch, Request
from repro.core.serve_loop import ServeLoop, is_generative, is_pooled
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions


@pytest.fixture(scope="module")
def served():
    """One warmed server + loop shared by the module's read-only tests."""
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(0)
    for i in range(3):
        w = rng.randn(cfg.d_model, 2).astype(np.float32) * 0.1
        head = (lambda ww: (lambda f: f @ ww))(w)
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(f"task{i}", "fm0", weight=float(i + 1),
                      extensions=TaskExtensions(decoder=head,
                                                adapter_id=f"lora{i}"))
    loop = srv.serve_loop("fm0", engine_kwargs=dict(
        num_slots=2, prompt_len=8, max_new=16, chunk=2))
    # warm every executable: pooled buckets, one admission prefill per
    # prompt-length bucket (2/4/8), the decode chunk, the pool write
    loop.warmup(pooled_task="task0", gen_task="task1")
    return srv, cfg, loop, rng


def _pooled(cfg, rng, tid="task0", t=0.0):
    return Request(tid, t, payload=rng.randn(8, cfg.d_model).astype(np.float32))


def _gen(cfg, rng, tid="task1", t=0.0, new=6, plen=8):
    return Request(tid, t,
                   payload=rng.randint(0, cfg.vocab_size, plen).astype("int32"),
                   tokens=float(plen + new), max_new_tokens=new)


def test_mixed_run_interleaves_and_serves_all(served):
    srv, cfg, loop, rng = served
    trace = [_pooled(cfg, rng, t=0.001 * i) for i in range(8)]
    trace += [_gen(cfg, rng, tid="task1", t=0.0, new=12, plen=5),
              _gen(cfg, rng, tid="task2", t=0.0, new=12, plen=8)]
    before = dict(loop.ticks)
    out = loop.run(list(trace), max_wall=120)
    assert all(r.finish_time is not None and r.result is not None
               for r in trace)
    # one clock dispatched all three kinds of work
    for kind in ("pooled", "admit", "decode"):
        assert loop.ticks[kind] > before.get(kind, 0), kind
    # interleaving: pooled work completed while streams were still decoding
    gen = [r for r in trace if is_generative(r)]
    pooled = [r for r in trace if is_pooled(r)]
    last_gen = max(r.finish_time for r in gen)
    assert any(r.finish_time < last_gen for r in pooled)
    assert all(len(r.result) == r.max_new_tokens for r in gen)
    assert all(np.all(np.isfinite(r.result)) for r in pooled)


def test_mid_flight_admission_joins_between_chunks(served):
    """More streams than slots: arrivals join the pool as slots retire,
    WHILE other streams keep decoding — admission ticks outnumber one."""
    srv, cfg, loop, rng = served
    eng = srv.engines["fm0"]                      # fixture warmed it
    # variable budgets -> staggered retirement -> mid-flight joins
    trace = [_gen(cfg, rng, tid=f"task{i % 3}", t=0.0, new=3 + 2 * i,
                  plen=3 + i) for i in range(5)]
    a0, d0 = loop.ticks["admit"], loop.ticks["decode"]
    compiles = eng.compile_count()
    builds = srv.fms["fm0"].seg_meta_cache.builds
    loop.run(list(trace), max_wall=120)
    assert all(len(r.result) == r.max_new_tokens for r in trace)
    assert loop.ticks["admit"] - a0 >= 2          # joins spread across chunks
    assert loop.ticks["decode"] - d0 >= 3
    # steady state: mixed churn (variable lengths, join/leave) recompiles
    # nothing once every bucket is warm
    assert eng.compile_count() == compiles
    assert srv.fms["fm0"].seg_meta_cache.builds > builds  # compositions change
    assert not eng.active_count() and not loop._inflight


def test_step_batch_serves_mixed_batch_synchronously(served):
    """Legacy contract: one srv.step() call serves a mixed pooled+generative
    BFQ batch to completion (results on every request)."""
    srv, cfg, loop, rng = served
    now = time.perf_counter()
    reqs = [_pooled(cfg, rng, t=now), _gen(cfg, rng, tid="task2", t=now, new=4)]
    for r in reqs:
        srv.on_arrival(r, now)
    total = 0
    while any(r.finish_time is None for r in reqs):
        batch = srv.step("fm0")
        assert batch is not None
        total += batch.size
    assert total == 2
    assert reqs[0].result.shape == (2,)           # pooled head output
    assert len(reqs[1].result) == 4               # generated tokens
    assert reqs[1].first_token_time is not None


def test_pending_batch_resolves_after_later_dispatch(served):
    """Double buffering: a dispatched-but-unresolved pooled batch stays
    correct when another batch is prepped and dispatched before resolve."""
    srv, cfg, loop, rng = served
    vfms = srv.vfms_on("fm0")
    ex = srv.executors["fm0"]
    r1 = [_pooled(cfg, rng) for _ in range(2)]
    r2 = [_pooled(cfg, rng, tid="task1") for _ in range(2)]
    b1 = Batch(r1, group_sub_batches(r1, vfms))
    b2 = Batch(r2, group_sub_batches(r2, vfms))
    p1 = ex.execute_async(b1, vfms)               # tick N
    p2 = ex.execute_async(b2, vfms)               # tick N+1 prep overlaps
    out1, out2 = p1.resolve(), p2.resolve()
    assert out1 is p1.resolve()                   # idempotent
    ref1 = ex.execute(Batch(r1, group_sub_batches(r1, vfms)), vfms)
    for r in r1:
        np.testing.assert_allclose(np.asarray(out1[r.rid]),
                                   np.asarray(ref1[r.rid]), atol=1e-5)
    assert all(np.all(np.isfinite(np.asarray(v))) for v in out2.values())


def test_idle_tick_flushes_and_reports(served):
    srv, cfg, loop, rng = served
    assert loop.tick() == "idle"
    assert loop._pending is None and not loop._work_left()


@pytest.mark.parametrize("scheduler", ["s-be", "stfq"])
def test_no_decode_starvation_without_virtual_time(served, scheduler):
    """Schedulers with no token clock (FIFO, STFQ) have no meaningful decode
    tag; the loop must alternate the planes instead of letting either
    sustained pooled arrivals starve an admitted stream forever (FIFO ties
    at 0.0) or a 0.0 decode tag starve the pooled queue (STFQ real tags)."""
    srv, cfg, loop, rng = served
    orig_sched = srv.schedulers["fm0"]
    srv.deploy_fm("fm0", profile=srv.profiles["fm0"], scheduler=scheduler)
    try:
        stream = _gen(cfg, rng, tid="task1", new=8)
        loop.submit(stream)
        while not srv.engines["fm0"].active_count():
            loop.tick()
        # keep a pooled request queued on EVERY tick: both planes must make
        # progress under sustained contention
        mine = []
        for _ in range(200):
            if stream.finish_time is not None:
                break
            r = _pooled(cfg, rng)
            mine.append(r)
            loop.submit(r)
            loop.tick()
        # the stream retired DURING the contended phase (FIFO's 0.0-tie
        # preference for pooled used to hold it forever)...
        assert stream.finish_time is not None
        assert len(stream.result) == 8
        # ...and pooled work interleaved before it did (STFQ's 0.0 decode
        # tag used to undercut every real queue tag until the pool drained)
        assert any(r.finish_time is not None
                   and r.finish_time < stream.finish_time for r in mine)
        while loop._work_left():
            loop.tick()
    finally:
        srv.schedulers["fm0"] = orig_sched
