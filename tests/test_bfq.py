"""BFQ unit tests: tag math (paper Eqs. 1-3), batch formation, SLO-aware
admission, adapter sub-batching, work conservation, retro-correction."""
import pytest

from repro.core.bfq import BFQ, FIFOBatch, STFQ
from repro.core.profile import FMProfile
from repro.core.request import Request, SLO
from repro.core.vfm import VFM, TaskExtensions


def make(weight_a=1.0, weight_b=1.0, b_max=8, adapter_a=None, adapter_b=None):
    prof = FMProfile("fm", alpha=10e-3, beta=2e-3, b_max=b_max)
    sched = BFQ(prof)
    va = VFM("A", weight=weight_a, extensions=TaskExtensions(adapter_id=adapter_a))
    vb = VFM("B", weight=weight_b, extensions=TaskExtensions(adapter_id=adapter_b))
    return sched, {"A": va, "B": vb}


def test_arrival_tags_eq1_eq2():
    sched, vfms = make(weight_a=2.0)
    l1 = sched.profile.l(1)
    r1 = Request("A", 0.0)
    sched.on_arrival(vfms["A"], r1, 0.0)
    assert r1.start_tag == 0.0
    assert r1.finish_tag == pytest.approx(l1 / 2.0)          # F = S + l/w
    r2 = Request("A", 0.0)
    sched.on_arrival(vfms["A"], r2, 0.0)
    assert r2.start_tag == pytest.approx(r1.finish_tag)      # S = max(F_prev, v)
    # global tag advances only with dispatches
    b = sched.next_batch(vfms, 0.0)
    assert sched.v >= r1.finish_tag


def test_start_tag_jumps_to_v_for_idle_task():
    sched, vfms = make()
    for i in range(5):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.next_batch(vfms, 0.0)
    r = Request("B", 1.0)
    sched.on_arrival(vfms["B"], r, 1.0)
    assert r.start_tag == pytest.approx(sched.v)   # no credit for idling


def test_batch_respects_bmax():
    sched, vfms = make(b_max=4)
    for i in range(10):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 4
    assert len(vfms["A"].queue) == 6


def test_slo_limits_batch_growth():
    """Adding requests extends completion; stop before violating any SLO."""
    prof = FMProfile("fm", alpha=10e-3, beta=10e-3, b_max=16)
    sched = BFQ(prof)
    v = VFM("A", slo=SLO(0.045))
    for i in range(10):
        sched.on_arrival(v, Request("A", 0.0, slo=SLO(0.045)), 0.0)
    batch = sched.next_batch({"A": v}, 0.0)
    # l(b) = 10ms + 10ms*b <= 45ms -> b <= 3
    assert batch.size == 3


def test_adapter_sub_batching():
    sched, vfms = make(adapter_a="la", adapter_b=None)
    sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 2                       # one backbone co-batch
    assert batch.num_adapters == 1               # one adapter sub-batch
    adapters = dict(batch.sub_batches)
    assert len(adapters["la"]) == 1 and len(adapters[None]) == 1


def test_exec_time_charges_adapter_subbatches():
    sched, vfms = make(adapter_a="la", adapter_b="lb")
    sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    t = sched.exec_time(batch)
    p = sched.profile
    assert t == pytest.approx(p.l(2) + 2 * (p.adapter_alpha + p.adapter_beta * 1))


def test_retro_correction_eq3():
    """After a batch, queued requests of participating tasks get l(b)-based tags."""
    sched, vfms = make(b_max=2)
    for i in range(4):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 2
    lb = sched.profile.effective_per_request(2)
    sched.on_complete(batch, vfms, 0.1)
    q = list(vfms["A"].queue)
    assert q[0].finish_tag - q[0].start_tag == pytest.approx(lb)
    assert q[1].start_tag == pytest.approx(q[0].finish_tag)


def test_work_conserving():
    sched, vfms = make()
    assert sched.next_batch(vfms, 0.0) is None
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    assert sched.next_batch(vfms, 0.0).size == 1


def test_tag_order_prefers_underserved():
    """Heavier-weight task accumulates tags slower -> gets more slots."""
    sched, vfms = make(weight_a=3.0, weight_b=1.0, b_max=1)
    for i in range(12):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
        sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    served = {"A": 0, "B": 0}
    for _ in range(8):
        b = sched.next_batch(vfms, 0.0)
        served[b.requests[0].task_id] += 1
        sched.on_complete(b, vfms, 0.0)
    assert served["A"] == 6 and served["B"] == 2   # 3:1 share


def test_stfq_serves_one():
    prof = FMProfile("fm", alpha=1e-3, beta=1e-3, b_max=8)
    s = STFQ(prof)
    v = VFM("A")
    for i in range(4):
        s.on_arrival(v, Request("A", 0.0), 0.0)
    assert s.next_batch({"A": v}, 0.0).size == 1


def test_fifo_batches_arrival_order():
    prof = FMProfile("fm", alpha=1e-3, beta=1e-3, b_max=3)
    s = FIFOBatch(prof)
    v = VFM("A")
    rs = [Request("A", t * 0.001) for t in range(5)]
    for r in rs:
        s.on_arrival(v, r, r.arrival)
    b = s.next_batch({"A": v}, 0.01)
    assert [r.rid for r in b.requests] == [r.rid for r in rs[:3]]


def test_token_level_accounting():
    """Paper §4.2, token-based FMs: with equal weights, a task sending
    10x-token requests receives ~1/10th the REQUEST rate (equal token rate)."""
    prof = FMProfile("llm", alpha=1e-3, beta=1e-3, b_max=1)
    sched = BFQ(prof)
    va, vb = VFM("A"), VFM("B")
    vfms = {"A": va, "B": vb}
    for i in range(300):
        sched.on_arrival(va, Request("A", 0.0, tokens=10.0), 0.0)
        sched.on_arrival(vb, Request("B", 0.0, tokens=1.0), 0.0)
    served = {"A": 0, "B": 0}
    tokens = {"A": 0.0, "B": 0.0}
    for _ in range(220):
        b = sched.next_batch(vfms, 0.0)
        r = b.requests[0]
        served[r.task_id] += 1
        tokens[r.task_id] += r.tokens
        sched.on_complete(b, vfms, 0.0)
    # token shares ~equal; request shares ~1:10
    assert abs(tokens["A"] - tokens["B"]) / max(tokens.values()) < 0.15
    assert served["B"] > 5 * served["A"]
