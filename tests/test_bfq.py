"""BFQ unit tests: tag math (paper Eqs. 1-3), batch formation, SLO-aware
admission, adapter sub-batching, work conservation, retro-correction,
token-level accounting for the event-loop plane, and pooled-vs-generative
colocation fairness on the real plane."""
import pytest

from repro.core.bfq import BFQ, FIFOBatch, STFQ
from repro.core.profile import FMProfile
from repro.core.request import Request, SLO
from repro.core.vfm import VFM, TaskExtensions


def make(weight_a=1.0, weight_b=1.0, b_max=8, adapter_a=None, adapter_b=None):
    prof = FMProfile("fm", alpha=10e-3, beta=2e-3, b_max=b_max)
    sched = BFQ(prof)
    va = VFM("A", weight=weight_a, extensions=TaskExtensions(adapter_id=adapter_a))
    vb = VFM("B", weight=weight_b, extensions=TaskExtensions(adapter_id=adapter_b))
    return sched, {"A": va, "B": vb}


def test_arrival_tags_eq1_eq2():
    sched, vfms = make(weight_a=2.0)
    l1 = sched.profile.l(1)
    r1 = Request("A", 0.0)
    sched.on_arrival(vfms["A"], r1, 0.0)
    assert r1.start_tag == 0.0
    assert r1.finish_tag == pytest.approx(l1 / 2.0)          # F = S + l/w
    r2 = Request("A", 0.0)
    sched.on_arrival(vfms["A"], r2, 0.0)
    assert r2.start_tag == pytest.approx(r1.finish_tag)      # S = max(F_prev, v)
    # global tag advances only with dispatches
    b = sched.next_batch(vfms, 0.0)
    assert sched.v >= r1.finish_tag


def test_start_tag_jumps_to_v_for_idle_task():
    sched, vfms = make()
    for i in range(5):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.next_batch(vfms, 0.0)
    r = Request("B", 1.0)
    sched.on_arrival(vfms["B"], r, 1.0)
    assert r.start_tag == pytest.approx(sched.v)   # no credit for idling


def test_batch_respects_bmax():
    sched, vfms = make(b_max=4)
    for i in range(10):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 4
    assert len(vfms["A"].queue) == 6


def test_slo_limits_batch_growth():
    """Adding requests extends completion; stop before violating any SLO."""
    prof = FMProfile("fm", alpha=10e-3, beta=10e-3, b_max=16)
    sched = BFQ(prof)
    v = VFM("A", slo=SLO(0.045))
    for i in range(10):
        sched.on_arrival(v, Request("A", 0.0, slo=SLO(0.045)), 0.0)
    batch = sched.next_batch({"A": v}, 0.0)
    # l(b) = 10ms + 10ms*b <= 45ms -> b <= 3
    assert batch.size == 3


def test_adapter_sub_batching():
    sched, vfms = make(adapter_a="la", adapter_b=None)
    sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 2                       # one backbone co-batch
    assert batch.num_adapters == 1               # one adapter sub-batch
    adapters = dict(batch.sub_batches)
    assert len(adapters["la"]) == 1 and len(adapters[None]) == 1


def test_exec_time_charges_adapter_subbatches():
    sched, vfms = make(adapter_a="la", adapter_b="lb")
    sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    t = sched.exec_time(batch)
    p = sched.profile
    assert t == pytest.approx(p.l(2) + 2 * (p.adapter_alpha + p.adapter_beta * 1))


def test_retro_correction_eq3():
    """After a batch, queued requests of participating tasks get l(b)-based tags."""
    sched, vfms = make(b_max=2)
    for i in range(4):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
    batch = sched.next_batch(vfms, 0.0)
    assert batch.size == 2
    lb = sched.profile.effective_per_request(2)
    sched.on_complete(batch, vfms, 0.1)
    q = list(vfms["A"].queue)
    assert q[0].finish_tag - q[0].start_tag == pytest.approx(lb)
    assert q[1].start_tag == pytest.approx(q[0].finish_tag)


def test_work_conserving():
    sched, vfms = make()
    assert sched.next_batch(vfms, 0.0) is None
    sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    assert sched.next_batch(vfms, 0.0).size == 1


def test_tag_order_prefers_underserved():
    """Heavier-weight task accumulates tags slower -> gets more slots."""
    sched, vfms = make(weight_a=3.0, weight_b=1.0, b_max=1)
    for i in range(12):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
        sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    served = {"A": 0, "B": 0}
    for _ in range(8):
        b = sched.next_batch(vfms, 0.0)
        served[b.requests[0].task_id] += 1
        sched.on_complete(b, vfms, 0.0)
    assert served["A"] == 6 and served["B"] == 2   # 3:1 share


def test_stfq_serves_one():
    prof = FMProfile("fm", alpha=1e-3, beta=1e-3, b_max=8)
    s = STFQ(prof)
    v = VFM("A")
    for i in range(4):
        s.on_arrival(v, Request("A", 0.0), 0.0)
    assert s.next_batch({"A": v}, 0.0).size == 1


def test_fifo_batches_arrival_order():
    prof = FMProfile("fm", alpha=1e-3, beta=1e-3, b_max=3)
    s = FIFOBatch(prof)
    v = VFM("A")
    rs = [Request("A", t * 0.001) for t in range(5)]
    for r in rs:
        s.on_arrival(v, r, r.arrival)
    b = s.next_batch({"A": v}, 0.01)
    assert [r.rid for r in b.requests] == [r.rid for r in rs[:3]]


def test_next_batch_pred_and_limit():
    """Event-loop formation: ``pred`` restricts the plane, ``limit`` caps
    below B_max (admission is bounded by free decode slots)."""
    sched, vfms = make(b_max=8)
    for i in range(6):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
        sched.on_arrival(vfms["B"], Request("B", 0.0, max_new_tokens=4), 0.0)
    gen = sched.next_batch(vfms, 0.0, pred=lambda r: r.max_new_tokens > 0,
                           limit=2)
    assert gen.size == 2 and all(r.max_new_tokens > 0 for r in gen.requests)
    pooled = sched.next_batch(vfms, 0.0,
                              pred=lambda r: r.max_new_tokens <= 0)
    assert pooled.size == 6 and all(r.max_new_tokens <= 0
                                    for r in pooled.requests)
    assert len(vfms["B"].queue) == 4              # the rest stayed queued


def test_defer_charge_dispatch_uses_start_tag():
    """Event-loop admission: the dispatched stream's virtual time advances
    only to its START tag; per-token charges bill the actual work (a full
    finish-tag advance would double-price the stream: estimate + charges)."""
    sched, vfms = make()
    r = Request("A", 0.0, tokens=20.0, max_new_tokens=16)
    sched.on_arrival(vfms["A"], r, 0.0)
    sched.next_batch(vfms, 0.0, defer_charge=True)
    assert sched.task_vtime("A") == pytest.approx(r.start_tag)
    sched.charge_tokens(vfms, {"A": 4.0}, 0.0)
    assert sched.task_vtime("A") == pytest.approx(
        r.start_tag + sched.profile.l(1) * 4.0)


def test_charge_tokens_advances_vtime_and_rechains():
    """Token-level plane: a decode chunk charge advances the task's virtual
    finish by l(1)·tokens/w and re-chains its queued requests behind it."""
    sched, vfms = make(weight_a=2.0)
    l1 = sched.profile.l(1)
    r = Request("A", 0.0, tokens=4.0)
    sched.on_arrival(vfms["A"], r, 0.0)
    sched.charge_tokens(vfms, {"A": 10.0}, 0.0)
    assert sched.task_vtime("A") == pytest.approx(l1 * 10.0 / 2.0)
    assert sched.v >= sched.task_vtime("A")
    # the queued request was re-chained behind the charged work
    assert r.start_tag == pytest.approx(sched.task_vtime("A"))
    assert r.finish_tag == pytest.approx(r.start_tag + l1 * 4.0 / 2.0)
    # baselines: no virtual time, charge is a no-op
    from repro.core.bfq import FIFOBatch
    fifo = FIFOBatch(sched.profile)
    fifo.charge_tokens(vfms, {"A": 100.0}, 0.0)
    assert fifo.task_vtime("A") == 0.0


def test_on_cancel_refunds_tags_and_rechains():
    """Cancel/shed refund (Eq. 3 re-chain): removing a still-queued request
    restores the task's tag chain to what it would have been had the request
    never arrived — a shed 100-token request must not leave a permanent
    hole in the task's fair share."""
    sched, vfms = make(weight_a=2.0)
    l1 = sched.profile.l(1)
    r1 = Request("A", 0.0, tokens=4.0)
    r2 = Request("A", 0.0, tokens=100.0)          # the one we cancel
    r3 = Request("A", 0.0, tokens=4.0)
    for r in (r1, r2, r3):
        sched.on_arrival(vfms["A"], r, 0.0)
    assert r3.start_tag == pytest.approx(r2.finish_tag)
    assert sched.on_cancel(vfms, r2)
    # r3 re-chained directly behind r1: the 100-token slice is refunded
    assert r3.start_tag == pytest.approx(r1.finish_tag)
    assert r3.finish_tag == pytest.approx(r1.finish_tag + l1 * 4.0 / 2.0)
    assert sched._tail["A"] == pytest.approx(r3.finish_tag)
    assert list(vfms["A"].queue) == [r1, r3]
    # not queued (already dispatched / unknown): nothing to unwind
    assert not sched.on_cancel(vfms, r2)
    # deferred-charge dispatch + drop: admission into the engine advances
    # virtual time only to the START tag, and the actual prompt/chunk work
    # is charged at real admission — so a join shed while still deferred
    # in the engine's pending queue carried NO charge to refund
    b = sched.next_batch(vfms, 0.0, pred=lambda r: r is r1, limit=1,
                         defer_charge=True)
    assert [r.rid for r in b.requests] == [r1.rid]
    assert sched.task_vtime("A") == pytest.approx(r1.start_tag)
    # ...and r1 is then shed while pending: no charge_tokens ever lands,
    # so the task's virtual time still reflects zero device work
    assert sched.task_vtime("A") == pytest.approx(0.0)


def test_weighted_shares_hold_at_token_granularity():
    """Mixed-plane colocation, scheduler level: task A streams decode chunks
    (charged via charge_tokens), task B holds a pooled backlog. Replaying
    the event loop's pick-min-tag rule must hand A ~weight_A:weight_B of the
    tokens — weighted max-min across planes at token granularity."""
    prof = FMProfile("fm", alpha=1e-3, beta=1e-3, b_max=1)
    sched = BFQ(prof)
    va, vb = VFM("A", weight=3.0), VFM("B", weight=1.0)
    vfms = {"A": va, "B": vb}
    chunk_tokens = 4.0
    for _ in range(400):
        sched.on_arrival(vb, Request("B", 0.0, tokens=chunk_tokens), 0.0)
    # seed A's stream the way admission does: one request dispatched at
    # deferred charge (actual work billed per chunk below)
    sched.on_arrival(va, Request("A", 0.0, tokens=chunk_tokens), 0.0)
    sched.next_batch(vfms, 0.0, pred=lambda r: r.task_id == "A",
                     defer_charge=True)
    tokens = {"A": 0.0, "B": 0.0}
    for _ in range(200):
        decode_tag = sched.task_vtime("A")
        pooled_tag = sched.peek_tag(vfms)
        if decode_tag <= pooled_tag:              # the loop's decision rule
            sched.charge_tokens(vfms, {"A": chunk_tokens}, 0.0)
            tokens["A"] += chunk_tokens
        else:
            b = sched.next_batch(vfms, 0.0)
            tokens["B"] += sum(r.tokens for r in b.requests)
            sched.on_complete(b, vfms, 0.0)
    ratio = tokens["A"] / tokens["B"]
    assert 2.5 < ratio < 3.6, ratio               # ~3:1 by weight


def test_token_level_accounting():
    """Paper §4.2, token-based FMs: with equal weights, a task sending
    10x-token requests receives ~1/10th the REQUEST rate (equal token rate)."""
    prof = FMProfile("llm", alpha=1e-3, beta=1e-3, b_max=1)
    sched = BFQ(prof)
    va, vb = VFM("A"), VFM("B")
    vfms = {"A": va, "B": vb}
    for i in range(300):
        sched.on_arrival(va, Request("A", 0.0, tokens=10.0), 0.0)
        sched.on_arrival(vb, Request("B", 0.0, tokens=1.0), 0.0)
    served = {"A": 0, "B": 0}
    tokens = {"A": 0.0, "B": 0.0}
    for _ in range(220):
        b = sched.next_batch(vfms, 0.0)
        r = b.requests[0]
        served[r.task_id] += 1
        tokens[r.task_id] += r.tokens
        sched.on_complete(b, vfms, 0.0)
    # token shares ~equal; request shares ~1:10
    assert abs(tokens["A"] - tokens["B"]) / max(tokens.values()) < 0.15
    assert served["B"] > 5 * served["A"]


# ---------------- real plane: pooled vs generative colocation ----------------

def test_pooled_latency_bounded_under_decode_colocation():
    """A pooled task co-located with a long (64-step) generative stream on
    one backbone, served by the event loop: pooled batches interleave
    BETWEEN decode chunks, so (a) every pooled request completes while the
    stream is still decoding — the drain-synchronous plane made them wait
    for the whole stream — and (b) pooled p50 stays within ~2x of the
    pooled-only baseline (asserted at 3x for CI-machine headroom)."""
    import time

    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.physical import PhysicalFM
    from repro.core.request import Request
    from repro.core.server import FMplexServer
    from repro.core.vfm import TaskExtensions
    from repro.serving.metrics import percentile

    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    srv.bind_task("pooled", "fm0", weight=2.0, extensions=TaskExtensions())
    srv.bind_task("gen", "fm0", weight=1.0, extensions=TaskExtensions())
    loop = srv.serve_loop("fm0", engine_kwargs=dict(
        num_slots=2, prompt_len=8, max_new=64, chunk=2))
    rng = np.random.RandomState(0)

    def pooled_req():
        return Request("pooled", time.perf_counter(),
                       payload=rng.randn(8, cfg.d_model).astype(np.float32))

    def gen_req(steps):
        return Request("gen", time.perf_counter(),
                       payload=rng.randint(0, cfg.vocab_size, 8).astype("int32"),
                       tokens=float(8 + steps), max_new_tokens=steps)

    def serve(reqs):
        for r in reqs:
            loop.submit(r)
        while any(r.finish_time is None for r in reqs):
            loop.tick()
        loop._flush()
        return reqs

    # warm every executable (pooled bucket, admission, decode chunk)
    serve([pooled_req(), gen_req(2)])

    # baseline: pooled only
    solo = serve([pooled_req() for _ in range(6)])
    p50_solo = percentile([r.latency for r in solo], 50)

    # colocated: admit a 64-step stream, then the same pooled burst
    stream = gen_req(64)
    loop.submit(stream)
    while not srv.engines["fm0"].active_count():
        loop.tick()                                   # admission prefill
    colo = [pooled_req() for _ in range(6)]
    for r in colo:
        loop.submit(r)
    while any(r.finish_time is None for r in colo):
        loop.tick()
        assert loop.ticks is not None
    loop._flush()
    p50_colo = percentile([r.latency for r in colo], 50)
    # (a) interleaving: all pooled done while the stream still decodes
    assert stream.finish_time is None
    while stream.finish_time is None:
        loop.tick()
    assert max(r.finish_time for r in colo) < stream.finish_time
    assert len(stream.result) == 64
    # (b) bounded degradation (~2x, asserted with headroom for CI noise)
    assert p50_colo < 3.0 * max(p50_solo, 1e-3), (p50_colo, p50_solo)


def test_admission_charges_tail_tokens_not_full_prompt():
    """Chunked shared-prefix admission regression: a sharer whose prefill
    computed only the private TAIL is charged tail tokens, not the full
    prompt — billing the full prompt would inflate the sharer task's
    virtual time by compute the prefix registry saved it, handing its fair
    share to competitors. step_batch-owned rids (not loop-admitted) were
    priced at dispatch and must not pay again here."""
    from repro.core.serve_loop import ServeLoop

    sched, vfms = make()
    l1 = sched.profile.l(1)

    class StubEngine:
        def take_admitted(self):
            # (rid, task_id, prompt_tokens, tail_tokens): rid 1 is a
            # prefix-hit sharer (112-token prompt, 16-token tail), rid 2 a
            # miss (full prefill), rid 3 step_batch-owned (not inflight)
            return [(1, "A", 112, 16), (2, "B", 112, 112), (3, "A", 112, 16)]

    loop = ServeLoop.__new__(ServeLoop)
    loop._inflight = {1: object(), 2: object()}
    loop._prefix_hit_rids = set()
    loop._engine = lambda: StubEngine()
    ServeLoop._charge_admissions(loop, sched, vfms, 0.0)
    assert sched.task_vtime("A") == pytest.approx(l1 * 16.0)     # tail only
    assert sched.task_vtime("B") == pytest.approx(l1 * 112.0)    # full miss
    assert loop._prefix_hit_rids == {1}                          # hit split


def test_decode_charges_committed_tokens_not_chunk_times_slots():
    """Speculative fairness regression: a decode chunk charges each task the
    tokens its streams actually COMMITTED, not chunk x active_slots. Under
    self-speculation a high-accept stream commits several tokens per scan
    step while a zero-accept co-batched stream commits one; the flat split
    would bill both tasks identically, overcharging the slow stream and
    undercharging the fast one. Engines without a charge log (stubs, older
    engines) must still degenerate to the flat split."""
    import types

    from repro.core.serve_loop import ServeLoop

    def make_loop(eng):
        loop = ServeLoop.__new__(ServeLoop)
        loop._flush = lambda: None
        loop._engine = lambda create=False: eng
        loop._inflight = {1: object(), 2: object()}
        loop._prefix_hit_rids = set()
        loop._handle_rejected = lambda *a, **k: None
        loop.failures = {}
        loop.page_samples, loop.shared_samples = [], []
        return loop

    def slot(tid):
        return types.SimpleNamespace(task_id=tid, done=False)

    class SpecEngine:
        """Two live slots; over one chunk of 4 scan steps task A's stream
        accepted ~2 drafts/step (12 committed) while task B's accepted
        none (4 committed)."""
        paged = False
        steps = 0
        slots = [slot("A"), slot("B")]

        def _expire_deadlines(self, now):
            pass

        def step_chunk(self):
            self.steps += 4
            return []

        def take_decode_charges(self):
            return {("A", 1): 12, ("B", 2): 4}

        def take_admitted(self):
            return []

    sched, vfms = make()
    l1 = sched.profile.l(1)
    ServeLoop._tick_decode(make_loop(SpecEngine()), sched, vfms, 0.0)
    assert sched.task_vtime("A") == pytest.approx(l1 * 12.0)
    assert sched.task_vtime("B") == pytest.approx(l1 * 4.0)
    assert sched.task_vtime("A") > sched.task_vtime("B")   # NOT the flat split

    class LegacyEngine:
        """Same shape, but no charge log at all (pre-speculation engine)."""
        paged = False
        steps = 0
        slots = [slot("A"), slot("B")]

        def _expire_deadlines(self, now):
            pass

        def step_chunk(self):
            self.steps += 4
            return []

        def take_admitted(self):
            return []

    sched2, vfms2 = make()
    ServeLoop._tick_decode(make_loop(LegacyEngine()), sched2, vfms2, 0.0)
    # fallback: flat chunk x active_slots split, equal for both tasks
    assert sched2.task_vtime("A") == pytest.approx(l1 * 4.0)
    assert sched2.task_vtime("B") == pytest.approx(l1 * 4.0)
