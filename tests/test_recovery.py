"""Durable serving state: host-RAM spill tier (lossless preemption resume
with PRNG continuity, prefix pages surviving idle gaps, corruption falling
back to recompute), engine snapshot/restore (mid-flight token parity, jit
reuse, digest tamper detection, disk round trip), the serve loop's
checkpoint_restart under load with the device-reset chaos fault, the
deadline-clamp chunk ladder, retry-jitter desynchronization, and BFQ
virtual-time tag persistence."""
import time
import warnings

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.core.bfq import BFQ
from repro.core.decode_engine import DecodeEngine
from repro.core.executor import Executor
from repro.core.physical import PhysicalFM
from repro.core.profile import FMProfile
from repro.core.request import Request
from repro.core.spill import HostSpillArena
from repro.distributed.fault import InjectedFailure
from repro.serving.faults import DeviceResetFault, SpillCorruptionFault
from repro.serving.metrics import failure_counters

_FM = {}


def _fm():
    if "fm" not in _FM:
        cfg = reduced(get_config("stablelm-1.6b"))
        fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4,
                        lora_impl="segmented", seg_block_t=8)
        tree = fm.adapters._mod.init_single_adapter(
            jax.random.PRNGKey(0), fm.cfg, fm.adapters.rank)
        leaves, tdef = jax.tree.flatten(tree)
        ks = jax.random.split(jax.random.PRNGKey(100), len(leaves))
        fm.adapters.add("lora0", jax.tree.unflatten(tdef, [
            jax.random.normal(k, l.shape, l.dtype) * 0.05
            for k, l in zip(ks, leaves)]))
        _FM["fm"] = (cfg, fm)
    return _FM["fm"]


def _prompts(seed=1, n=2, plen=8):
    cfg, _ = _fm()
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
            for _ in range(n)]


def _run_pair(total_pages, *, spill_bytes=0, max_new=24, temperature=0.7):
    """Two long sampled streams on a ``total_pages`` arena; returns the
    engine and {rid: tokens}."""
    _, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=max_new,
                       chunk=4, paged=True, page_size=4,
                       total_pages=total_pages, spill_bytes=spill_bytes,
                       temperature=temperature, top_k=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, p in enumerate(_prompts()):
            eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=max_new,
                     rid=i)
        done = eng.drain()
    return eng, {d.rid: d.tokens for d in done}


# ---------------- host-RAM spill tier ----------------

def test_spill_resume_exact_parity_with_sampling():
    """A preempted SAMPLED stream resumed from its host spill produces the
    exact token sequence of a never-preempted run — pages, int8 scales,
    drift trackers, last token and PRNG key all survive the D2H/H2D round
    trip. The legacy re-prefill resume cannot do this (re-quantization +
    PRNG restart), which is the spill tier's whole claim."""
    ref_eng, ref = _run_pair(40)
    assert ref_eng.preemptions == 0              # reference never preempts
    eng, got = _run_pair(10, spill_bytes=64 << 20)
    assert eng.preemptions > 0 and eng.spill_resumes > 0
    assert eng.spilled_pages > 0 and eng.restored_pages > 0
    assert eng.digest_failures == 0
    for rid, toks in ref.items():
        assert got[rid] == toks
    # every resume went through the spill path, and the arena drained clean
    assert all(kind == "spill" for kind, _ in eng.resume_costs)
    assert eng.free_page_count() == eng.total_pages - 1


def test_spill_budget_eviction_falls_back_to_reprefill():
    """A spill arena too small for any stream entry skips the capture and
    the engine degrades to the legacy lossy-but-correct re-prefill resume —
    budget pressure is a performance event, never an error."""
    eng, got = _run_pair(10, spill_bytes=1, temperature=0.0)
    assert eng.preemptions > 0 and eng.spill_resumes == 0
    assert eng.spill.skips > 0
    assert all(kind == "reprefill" for kind, _ in eng.resume_costs)
    assert all(len(t) == 24 for t in got.values())
    assert eng.free_page_count() == eng.total_pages - 1


def test_spill_corruption_detected_and_recomputed():
    """Bit-flipped stream spill entries fail digest verification at resume:
    the entry is dropped, ``digest_failures`` counts it, and the stream
    completes through the re-prefill fallback — corruption can never
    surface as silently wrong tokens."""
    _, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=24, chunk=4,
                       paged=True, page_size=4, total_pages=10,
                       spill_bytes=64 << 20, temperature=0.0)

    class _Loop:                                 # faults.py's view of a loop
        def _engine(self):
            return eng

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, p in enumerate(_prompts()):
            eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=24,
                     rid=i)
        corrupted = 0
        done = []
        for _ in range(200):
            if len(eng.spill) and not corrupted:
                fault = SpillCorruptionFault(1.0)
                fault.inject(_Loop())
                corrupted = fault.corrupted
            done += eng.step_chunk()
            if len(done) == 2:
                break
    assert corrupted > 0 and eng.preemptions > 0
    assert eng.digest_failures >= 1
    assert sorted(d.rid for d in done) == [0, 1]
    assert all(len(d.tokens) == 24 for d in done)
    assert eng.free_page_count() == eng.total_pages - 1


def test_prefix_spill_survives_idle_gap_and_rededuplicates():
    """A registered prefix whose last sharer retires spills to host RAM;
    a later join whose prompt chains to the same digests restores it
    (bit-exact: same tokens as the first pass) and RE-REGISTERS it, so a
    third join deduplicates against live pages again."""
    cfg, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4, total_pages=20,
                       spill_bytes=64 << 20, prompt_buckets=(8, 16))
    (pfx,) = _prompts(seed=5, n=1)
    eng.join("a", pfx, adapter_id="lora0", max_new_tokens=4, rid=10)
    (d1,) = eng.drain()
    assert len(eng._prefix_registry) == 0        # last sharer gone...
    assert eng.spilled_pages >= 2                # ...but the pages moved D2H
    eng.join("b", pfx, adapter_id="lora0", max_new_tokens=4, rid=11)
    # chunked admission restores the leading spilled pages and re-prefills
    # the prompt's final page privately (the first generated token needs a
    # real last-position forward pass), so >= 1 page — not all — restores
    assert eng.spill_prefix_hits == 1 and eng.restored_pages >= 1
    assert len(eng._prefix_registry) > 0         # re-registered
    # third joiner shares the LIVE restored pages (no further restore)
    eng.join("c", pfx, adapter_id="lora0", max_new_tokens=4, rid=12)
    assert eng.prefix_hits >= 1
    done = {d.rid: d for d in eng.drain()}
    assert done[11].tokens == d1.tokens == done[12].tokens
    assert eng.free_page_count() == eng.total_pages - 1


# ---------------- engine snapshot / restore ----------------

def _midflight(spill_bytes=0, temperature=0.7):
    _, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=12, chunk=2,
                       paged=True, page_size=4, total_pages=20,
                       temperature=temperature, top_k=8,
                       spill_bytes=spill_bytes)
    for i, p in enumerate(_prompts()):
        eng.join(f"t{i}", p, adapter_id="lora0", max_new_tokens=12,
                 rid=100 + i)
    eng.step_chunk()
    eng.step_chunk()
    return eng


def test_snapshot_restore_midflight_parity_and_jit_reuse():
    """snapshot() between chunks + restore() into a fresh engine resumes
    every live stream token-for-token against an uninterrupted run, with
    ZERO digest failures and zero new compiles (the old engine's jit caches
    are reused — executables are code, not device state)."""
    ref = {d.rid: d.tokens for d in _midflight().drain()}
    eng = _midflight()
    snap = eng.snapshot()
    eng2 = DecodeEngine.restore(_fm()[1], snap, reuse_jits_from=eng)
    compiles = eng2.compile_count()
    got = {d.rid: d.tokens for d in eng2.drain()}
    assert got == ref
    assert eng2.digest_failures == 0
    assert eng2.compile_count() == compiles      # nothing recompiled
    assert eng2.free_page_count() == eng2.total_pages - 1


def test_snapshot_digest_detects_tampered_page():
    """A snapshot page whose content no longer matches its digest is never
    served: the mapping stream is requeued through the lossless fold path
    and still completes its full budget."""
    eng = _midflight(spill_bytes=64 << 20, temperature=0.0)
    snap = eng.snapshot()
    snap.pages[0] = dict(snap.pages[0])
    snap.pages[0]["k"] = np.array(snap.pages[0]["k"])
    snap.pages[0]["k"][:, 0] ^= 1                # flip bits in one used page
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng2 = DecodeEngine.restore(_fm()[1], snap)
        assert eng2.digest_failures >= 1
        done = {d.rid: d for d in eng2.drain()}
    assert sorted(done) == [100, 101]
    assert all(len(d.tokens) == 12 for d in done.values())
    assert eng2.free_page_count() == eng2.total_pages - 1


def test_snapshot_disk_round_trip(tmp_path):
    """save_snapshot/load_snapshot round-trips through npz+json: the loaded
    snapshot restores to the same continuation as the in-memory one."""
    ref = {d.rid: d.tokens for d in _midflight().drain()}
    eng = _midflight()
    snap = eng.snapshot()
    out = ckpt.save_snapshot(tmp_path / "snap", snap)
    assert out.exists()
    loaded = ckpt.load_snapshot(tmp_path / "snap")
    assert loaded.page_digests == snap.page_digests
    eng2 = DecodeEngine.restore(_fm()[1], loaded, reuse_jits_from=eng)
    assert {d.rid: d.tokens for d in eng2.drain()} == ref
    assert eng2.digest_failures == 0


# ---------------- serve loop: checkpoint_restart + device reset ----------


@pytest.fixture(scope="module")
def served():
    from repro.core.server import FMplexServer
    from repro.core.vfm import TaskExtensions
    cfg, fm = _fm()
    fm.calibrate(sizes=(1, 2))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(0)
    w = rng.randn(cfg.d_model, 2).astype(np.float32) * 0.1
    srv.bind_task("task0", "fm0", weight=1.0,
                  extensions=TaskExtensions(decoder=lambda f: f @ w,
                                            adapter_id="lora0"))
    loop = srv.serve_loop("fm0", engine_kwargs=dict(
        num_slots=2, prompt_len=8, max_new=16, chunk=2,
        paged=True, page_size=4, spill_bytes=64 << 20))
    loop.warmup(pooled_task="task0", gen_task="task0")
    return srv, cfg, loop


def _gen(cfg, rng, t=0.0, new=8):
    return Request("task0", t,
                   payload=rng.randint(0, cfg.vocab_size, 8).astype("int32"),
                   tokens=float(8 + new), max_new_tokens=new)


def test_loop_checkpoint_restart_under_load(served):
    """checkpoint_restart mid-flight loses nothing: in-flight streams
    complete ok with full budgets and carry ``resets_survived`` stamps;
    the loop's failure counters and metrics surface the reset."""
    srv, cfg, loop = served
    rng = np.random.RandomState(3)
    reqs = [_gen(cfg, rng, new=10) for _ in range(3)]
    for r in reqs:
        loop.submit(r, time.perf_counter())
    while not srv.engines["fm0"].active_count():
        loop.tick()
    r0 = loop.failures["resets_survived"]
    inflight = set(loop._inflight)               # stamped: in flight at reset
    loop.checkpoint_restart()
    while loop._work_left():
        loop.tick()
    assert loop.failures["resets_survived"] == r0 + 1
    assert all(r.ok and len(r.result) == 10 for r in reqs)
    assert inflight and all(
        r.resets_survived == (1 if r.rid in inflight else 0) for r in reqs)
    fc = failure_counters(reqs, loop=loop, engine=srv.engines["fm0"])
    assert fc["resets_survived"] >= 1
    assert fc["digest_failures"] == 0


def test_device_reset_fault_scrambles_then_survives(served):
    """DeviceResetFault scrambles every pool leaf of the OLD engine before
    restore — the restored streams' correctness proves the recovery path
    reads nothing from dead device state. Token parity vs a fault-free run
    is exact."""
    srv, cfg, loop = served
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, 8).astype("int32")
               for _ in range(2)]

    def run(reset: bool):
        reqs = [Request("task0", 0.0, payload=p, tokens=16.0,
                        max_new_tokens=8) for p in prompts]
        for r in reqs:
            loop.submit(r, time.perf_counter())
        while srv.engines["fm0"].active_count() < 2:
            loop.tick()                          # both streams live
        if reset:
            fault = DeviceResetFault()
            fault.inject(loop)
            assert fault.resets == 1
        while loop._work_left():
            loop.tick()
        return reqs

    clean = run(reset=False)
    hit = run(reset=True)
    assert all(r.ok for r in clean + hit)
    for rc, rh in zip(clean, hit):
        # bit-exact token parity across the reset
        assert list(rh.result) == list(rc.result)
        assert rh.resets_survived == 1 and rc.resets_survived == 0
    assert srv.engines["fm0"].digest_failures == 0


# ---------------- deadline clamp ----------------

def test_deadline_clamp_shortens_chunk_from_warm_ladder():
    """A live stream close to its deadline gets a SHORTENED chunk from the
    precompiled ladder — it still makes progress (partial tokens beat zero)
    without paying for steps past the cancel point, and the clamp never
    compiles anything new after ``warm_decode_ladder``."""
    _, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=16, chunk=4,
                       paged=True, page_size=4, total_pages=20)
    assert eng.chunk_ladder() == (4, 2, 1)
    eng.warm_decode_ladder()
    assert eng.active_count() == 0               # ladder warmup left no state
    (p,) = _prompts(seed=9, n=1)
    eng.join("t", p, adapter_id="lora0", max_new_tokens=16, rid=0)
    compiles = eng.compile_count()               # admission compiles done
    eng._step_ema = 1.0                          # pretend decode steps take 1s
    s = next(x for x in eng.slots if x is not None)
    s.deadline = time.perf_counter() + 2.5       # room for ~2 steps, not 4
    n0 = len(s.tokens)
    eng.step_chunk()
    assert len(s.tokens) - n0 == 2               # ladder picked 2, not 4
    assert eng.deadline_clamps == 1
    assert eng.compile_count() == compiles       # ladder was already warm
    s.deadline = float("inf")
    eng.drain()


def test_deadline_clamp_off_dispatches_full_chunk():
    _, fm = _fm()
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8, chunk=4,
                       paged=True, page_size=4, total_pages=20,
                       deadline_clamp=False)
    (p,) = _prompts(seed=9, n=1)
    eng.join("t", p, adapter_id="lora0", max_new_tokens=8, rid=0)
    eng._step_ema = 1.0
    s = next(x for x in eng.slots if x is not None)
    s.deadline = time.perf_counter() + 2.5
    n0 = len(s.tokens)
    eng.step_chunk()
    assert len(s.tokens) - n0 == 4               # full chunk, clamp disabled
    assert eng.deadline_clamps == 0
    s.deadline = float("inf")
    eng.drain()


# ---------------- retry jitter ----------------

def test_retry_jitter_desynchronizes_cofailing_tasks():
    """Two tasks whose heads fail on the same tick back off on DIFFERENT
    schedules: per-task seeded jitter bounds every delay within
    [1-j, 1+j) x base and is reproducible for a given seed."""
    cfg = reduced(get_config("moment-large"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)

    def raising(f):
        raise InjectedFailure("boom")

    for t in ("ta", "tb"):
        fm.attach_head(t, raising)
    ex = Executor(fm, head_retries=2, head_backoff_s=0.001,
                  retry_jitter=0.5, retry_seed=42)
    from repro.core.request import Batch
    rng = np.random.RandomState(0)
    reqs = [Request(t, 0.0, payload=rng.randn(8, cfg.d_model)
                    .astype(np.float32)) for t in ("ta", "tb")]
    ex.execute(Batch(reqs, [(None, reqs)]), {})
    da, db = ex.retry_delays["ta"], ex.retry_delays["tb"]
    assert len(da) == len(db) == ex.head_retries
    assert da != db                              # desynchronized
    for delays in (da, db):
        for i, d in enumerate(delays):
            base = 0.001 * (2 ** i)
            assert 0.5 * base <= d < 1.5 * base  # bounded jitter
    # same seed -> same schedule; different seed -> different schedule
    ex2 = Executor(fm, head_retries=2, head_backoff_s=0.001,
                   retry_jitter=0.5, retry_seed=42)
    assert [ex2._retry_factor("ta") for _ in range(2)] == \
        pytest.approx([d / (0.001 * 2 ** i) for i, d in enumerate(da)])
    ex3 = Executor(fm, retry_jitter=0.5, retry_seed=43)
    assert ex3._retry_factor("ta") != pytest.approx(da[0] / 0.001)


# ---------------- scheduler tag persistence ----------------

def test_bfq_tags_snapshot_round_trip():
    sched = BFQ(FMProfile("fm", alpha=10e-3, beta=2e-3, b_max=8))
    sched.v = 3.5
    sched._tail.update({"a": 4.0, "b": 2.0})
    sched._last_dispatched.update({"a": 3.0})
    tags = sched.snapshot_tags()
    fresh = BFQ(FMProfile("fm", alpha=10e-3, beta=2e-3, b_max=8))
    fresh.restore_tags(tags)
    assert fresh.v == 3.5
    assert fresh._tail == {"a": 4.0, "b": 2.0}
    assert fresh._last_dispatched == {"a": 3.0}
    fresh.restore_tags(None)                     # no-op, never raises
    assert fresh.v == 3.5


def test_spill_arena_lru_accounting():
    """Pure host-side arena semantics: byte budget, LRU eviction order,
    same-key replacement, hit/miss counters."""
    a = HostSpillArena(100)
    blob = lambda n: [{"x": np.zeros(n, np.uint8)}]
    assert a.put("k1", blob(40)) and a.put("k2", blob(40))
    assert a.bytes_in_use == 80 and len(a) == 2
    a.get("k1")                                  # k1 now MRU -> k2 evicts
    assert a.put("k3", blob(40))
    assert "k2" not in a and "k1" in a and a.evictions == 1
    assert not a.put("big", blob(1000))          # over-budget: skipped
    assert a.skips == 1 and "big" not in a
    assert a.put("k1", blob(10))                 # same-key replace
    assert a.bytes_in_use == 50
    assert a.get("missing") is None and a.misses == 1
    e = a.pop("k1")
    assert e is not None and e.verify()
    a.peek("k3")
    assert a.hits == 1                           # peek counted nothing
