"""Per-assigned-architecture smoke tests (deliverable f).

Each arch: instantiate a REDUCED same-family config, run one forward + one
train step on CPU, assert output shapes and finiteness. Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ASSIGNED, get_config, reduced
from repro.models import lm
from repro.optim.adamw import AdamW

ALL = ASSIGNED + ["moment-large"]


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    x, _, aux = lm.forward(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"),
                           pos3=batch.get("pos3"))
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    assert bool(jnp.isfinite(jnp.asarray(aux)))


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg, 2, 16)

    @jax.jit
    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(p, cfg, b)
        p2, o2, _ = opt.update(g, o, p)
        return p2, o2, loss

    p2, o2, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[1]
    l1 = jax.tree.leaves(p2)[1]
    assert not bool(jnp.allclose(l0, l1))


@pytest.mark.parametrize("arch", [a for a in ALL
                                  if get_config(a).has_decode])
def test_prefill_decode_shapes(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    batch = make_batch(cfg, B, S)
    cache = lm.init_cache(cfg, B, S + 4)
    logits, cache = lm.prefill(params, cfg, cache=cache,
                               tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               enc_embeds=batch.get("enc_embeds"),
                               pos3=batch.get("pos3"))
    assert logits.shape[0] == B
    tok = jnp.ones((B,), jnp.int32)
    logits2, cache = lm.decode_step(params, cfg, tokens=tok, cache=cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
