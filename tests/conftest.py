import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def make_batch(cfg, B, S, seed=0):
    """Input batch for any arch family (tokens / stub embeds / enc-dec)."""
    import jax.numpy as jnp
    r = np.random.RandomState(seed)
    b = {}
    if cfg.is_encoder_decoder:
        b["enc_embeds"] = jnp.asarray(r.randn(B, S, cfg.d_model), jnp.float32)
        b["tokens"] = jnp.asarray(r.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.frontend_stub:
        b["embeds"] = jnp.asarray(r.randn(B, S, cfg.d_model), jnp.float32)
        if cfg.vocab_size > 0:
            b["labels"] = jnp.asarray(r.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
        if cfg.mrope_sections:
            b["pos3"] = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None, :, None],
                                 (B, 1, 3))
    else:
        b["tokens"] = jnp.asarray(r.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    return b
