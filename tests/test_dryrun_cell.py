"""Integration: one real dry-run cell lowers + compiles on the production
mesh in a subprocess (device count locks at first jax init)."""
import json
import subprocess
import sys


def test_dryrun_cell_compiles(tmp_path):
    out = tmp_path / "cell.jsonl"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--cell", "xlstm-125m:decode_32k:pod1", "--out", str(out)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok" and rec["chips"] == 256
    assert rec["roofline"]["bottleneck"] is not None
    assert rec["memory"]["temp_size_in_bytes"] < 16e9   # fits a v5e chip
