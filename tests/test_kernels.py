"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp ref oracles,
executed in Pallas interpret mode (kernel body runs on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.segmented_lora import segmented_lora, sort_by_adapter


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,Sq,Sk,hd", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 4, 2, 64, 128, 32),       # GQA, q suffix (prefill w/ prefix)
    (1, 8, 1, 128, 128, 64),      # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32), (False, None)])
def test_flash_attention_sweep(B, H, KV, Sq, Sk, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd", [(2, 8, 2, 256, 64), (3, 4, 4, 128, 32)])
@pytest.mark.parametrize("window", [None, 48])
def test_decode_attention_sweep(B, H, KV, S, hd, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    lens = jnp.asarray(np.random.RandomState(0).randint(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lens, window=window, block_s=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,r,NA,bt", [(256, 128, 16, 5, 32),
                                         (128, 256, 8, 2, 64),
                                         (64, 64, 4, 1, 64)])
def test_segmented_lora_sweep(T, d, r, NA, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (T, d), dtype)
    a = (jax.random.normal(ks[1], (NA, d, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[2], (NA, r, d)) * 0.05).astype(dtype)
    blocks = jnp.asarray(np.random.RandomState(0).randint(0, NA + 1, T // bt),
                         jnp.int32)
    out = segmented_lora(x, blocks, a, b, block_t=bt, interpret=True)
    want = ref.segmented_lora_ref(x, blocks, a, b, bt)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_sort_by_adapter_blocks_are_pure():
    ids = np.random.RandomState(1).randint(0, 6, 173)
    perm, blocks, total = sort_by_adapter(ids, 6, block_t=16, max_tokens=304)
    assert total == 304 and len(blocks) == 304 // 16
    for i, aid in enumerate(blocks):
        rows = perm[i * 16:(i + 1) * 16]
        real = {ids[j] for j in rows if j >= 0}
        assert len(real) <= 1
        if real:
            assert real.pop() == aid
    # every original row appears exactly once
    seen = sorted(j for j in perm if j >= 0)
    assert seen == list(range(173))


@pytest.mark.parametrize("B,H,KV,S,hd", [(2, 8, 2, 256, 64), (1, 4, 4, 128, 32)])
def test_decode_attention_int8_kernel(B, H, KV, S, hd):
    """int8-KV flash-decode: exact vs dequantized oracle; bounded vs f32."""
    from repro.kernels.decode_attention_int8 import (decode_attention_int8,
                                                     quantize_kv)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    lens = jnp.asarray(np.random.RandomState(0).randint(1, S + 1, B), jnp.int32)
    kq, vq, kss, vs = quantize_kv(k, v)
    out = decode_attention_int8(q, kq, vq, kss, vs, lens, block_s=64,
                                interpret=True)
    kd = kq.astype(jnp.float32) * kss[:, :, None, None]
    vd = vq.astype(jnp.float32) * vs[:, :, None, None]
    exact = ref.decode_attention_ref(q, kd, vd, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact), atol=1e-5)
    f32 = ref.decode_attention_ref(q, k, v, lens)
    assert float(jnp.max(jnp.abs(out - f32))) < 0.08   # quantization bound
