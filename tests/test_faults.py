"""Fault-tolerant serving plane: per-stream numeric quarantine with exact
co-batch token parity, deadline enforcement (mid-flight cancel + pending
shed), client cancellation across every request state, per-task head-failure
isolation with recovery, the loop watchdog under an injected stall,
stranded-sharer wedge recovery, and the chaos-injection scheduler itself."""
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM
from repro.core.request import FAILURE_STATUSES, Request
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions
from repro.serving.faults import (ChaosEvent, ChaosInjector, Fault,
                                  NaNAdapterFault, PagePressureFault,
                                  RaisingHeadFault, StallFault)


@pytest.fixture(scope="module")
def served():
    """One warmed server + PAGED loop shared by the module (the paged pool
    exposes the full failure surface: pending queue, stranding, pages)."""
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(0)
    for i in range(3):
        w = rng.randn(cfg.d_model, 2).astype(np.float32) * 0.1
        head = (lambda ww: (lambda f: f @ ww))(w)
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(f"task{i}", "fm0", weight=float(i + 1),
                      extensions=TaskExtensions(decoder=head,
                                                adapter_id=f"lora{i}"))
    loop = srv.serve_loop("fm0", engine_kwargs=dict(
        num_slots=2, prompt_len=8, max_new=16, chunk=2,
        paged=True, page_size=4))
    loop.warmup(pooled_task="task0", gen_task="task1")
    return srv, cfg, loop, rng


def _pooled(cfg, rng, tid="task0", t=0.0):
    return Request(tid, t, payload=rng.randn(8, cfg.d_model).astype(np.float32))


def _gen(cfg, rng, tid="task1", t=0.0, new=6, plen=8):
    return Request(tid, t,
                   payload=rng.randint(0, cfg.vocab_size, plen).astype("int32"),
                   tokens=float(plen + new), max_new_tokens=new)


def _run_stream(eng, rid):
    """Step the engine until stream ``rid`` retires; return its slot."""
    for _ in range(64):
        for s in eng.step_chunk():
            if s.rid == rid:
                return s
    raise AssertionError(f"stream {rid} never retired")


# ---------------- numeric-fault quarantine ----------------

def test_quarantine_isolates_poisoned_stream_with_exact_parity(served):
    """A NaN'd adapter quarantines ONLY its own stream — at admission,
    before any page allocation or prefix registration — while a co-batched
    clean stream's tokens match a fault-free solo run bit for bit, with
    zero new compiles."""
    srv, cfg, loop, rng = served
    eng = srv.engines["fm0"]
    rng = np.random.RandomState(7)
    clean_prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    bad_prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)

    # fault-free baseline: the clean stream alone
    eng.join("task1", clean_prompt, adapter_id="lora1", max_new_tokens=6,
             rid=9001)
    solo = _run_stream(eng, 9001).tokens
    assert not eng.active_count()

    q0, compiles, free0 = eng.quarantines, eng.compile_count(), \
        eng.free_page_count()
    fault = NaNAdapterFault("lora0")
    fault.inject(loop)
    try:
        eng.join("task1", clean_prompt, adapter_id="lora1", max_new_tokens=6,
                 rid=9002)
        eng.join("task0", bad_prompt, adapter_id="lora0", max_new_tokens=6,
                 rid=9003)
        retired = {s.rid: s for s in eng.step_chunk()}
        for _ in range(32):
            if 9002 in retired and 9003 in retired:
                break
            retired.update({s.rid: s for s in eng.step_chunk()})
    finally:
        fault.restore(loop)
    assert retired[9003].status == "quarantined"
    assert eng.quarantines == q0 + 1
    # quarantined at ADMISSION: one garbage prefill token, nothing decoded
    assert len(retired[9003].tokens) == 1
    # the poisoned prompt never entered the COW prefix registry
    assert eng._match_prefix("lora0", bad_prompt) == []
    # exact parity for the clean co-batched stream, no recompiles
    assert retired[9002].status == "ok"
    assert retired[9002].tokens == solo
    assert eng.compile_count() == compiles
    assert not eng.active_count() and eng.free_page_count() == free0
    eng.take_admitted()

    # restored adapter serves cleanly again (loop-level status plumbing)
    r = _gen(cfg, rng, tid="task0", new=4)
    loop.run([r], max_wall=60)
    assert r.ok and len(r.result) == 4


def test_loop_stamps_quarantined_status(served):
    srv, cfg, loop, rng = served
    fail0 = loop.failures["quarantined"]
    fault = NaNAdapterFault("lora2")
    fault.inject(loop)
    try:
        r = _gen(cfg, np.random.RandomState(11), tid="task2", new=4)
        loop.run([r], max_wall=60)
    finally:
        fault.restore(loop)
    assert r.status == "quarantined" and not r.ok
    assert r.error and not r.met_deadline()
    assert loop.failures["quarantined"] == fail0 + 1


# ---------------- deadline enforcement ----------------

def test_deadline_cancels_live_and_sheds_pending(served):
    srv, cfg, loop, _ = served
    eng = srv.engines["fm0"]
    rng = np.random.RandomState(13)
    c0, s0 = eng.deadline_cancels, eng.deadline_sheds

    # live slot past its deadline: retired with its partial tokens
    p = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    eng.join("task1", p, adapter_id="lora1", max_new_tokens=8, rid=9101,
             deadline=time.perf_counter() - 1.0)
    s = _run_stream(eng, 9101)
    assert s.status == "deadline_cancelled"
    assert 1 <= len(s.tokens) < 8                # partial output preserved
    assert eng.deadline_cancels == c0 + 1

    # expired PENDING entry: terminally shed, never admitted, never charged
    for rid in (9102, 9103):                     # fill both slots
        eng.join("task1", rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                 adapter_id="lora1", max_new_tokens=16, rid=rid)
    admitted_rids = {rid for rid, *_ in eng.take_admitted()}
    eng.join("task2", rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
             adapter_id="lora2", max_new_tokens=16, rid=9104,
             deadline=time.perf_counter() - 1.0)
    assert eng.pending_count() == 1              # no free slot: deferred
    eng.step_chunk()
    rej = eng.take_rejected()
    assert [p.rid for p in rej] == [9104]
    assert rej[0].status == "deadline_shed"
    assert eng.deadline_sheds == s0 + 1
    # charged at ACTUAL admission: the shed rid never hit the admitted log
    admitted_rids |= {rid for rid, *_ in eng.take_admitted()}
    assert 9104 not in admitted_rids
    for rid in (9102, 9103):                     # cleanup
        assert eng.cancel(rid) is not None
    assert not eng.active_count() and not eng.pending_count()
    eng.take_admitted()


def test_loop_sheds_infeasible_deadline_before_prefill(served):
    """Queued requests whose predicted TTFT (l(1)·prompt_len) already busts
    the deadline are shed pre-admission with a BFQ tag refund."""
    srv, cfg, loop, _ = served
    from repro.core.request import SLO
    rng = np.random.RandomState(17)
    r = _gen(cfg, rng, tid="task1", new=8)
    r.slo = SLO(1e-6)                            # infeasible by construction
    shed0 = loop.failures["deadline_shed"]
    loop.run([r], max_wall=60)
    assert r.status == "deadline_shed" and r.result is None
    assert loop.failures["deadline_shed"] == shed0 + 1
    # the refund re-chained the task's tail: a follow-up request is priced
    # as if the shed one never arrived, and still serves normally
    r2 = _gen(cfg, rng, tid="task1", new=4)
    loop.run([r2], max_wall=60)
    assert r2.ok and len(r2.result) == 4


# ---------------- client cancellation ----------------

def test_loop_cancel_unwinds_queued_and_live(served):
    srv, cfg, loop, _ = served
    eng = srv.engines["fm0"]
    sched = loop.sched
    rng = np.random.RandomState(19)

    # queued (never dispatched): tag refund, terminal status, no result
    r = _gen(cfg, rng, tid="task2", new=8)
    loop.submit(r, time.perf_counter())
    assert loop.cancel(r.rid)
    assert r.status == "cancelled" and r.finish_time is not None
    assert not any(v.queue for v in srv.vfms_on("fm0").values())
    # the queue tail re-chained to the last DISPATCHED finish (Eq. 3 refund)
    assert sched._tail.get("task2", 0.0) == pytest.approx(
        sched._last_dispatched.get("task2", 0.0))
    assert not loop.cancel(r.rid)                # already terminal

    # live slot: partial tokens preserved, pages released, slot freed
    free0 = eng.free_page_count()
    r2 = _gen(cfg, rng, tid="task1", new=16)
    loop.submit(r2, time.perf_counter())
    while not eng.active_count():
        loop.tick()
    assert loop.cancel(r2.rid)
    assert r2.status == "cancelled"
    assert r2.result is not None and len(r2.result) >= 1
    assert r2.first_token_time is not None
    assert not eng.active_count() and eng.free_page_count() == free0
    assert not loop.cancel(10 ** 9)              # unknown rid
    while loop._work_left():
        loop.tick()


# ---------------- per-task head-failure isolation ----------------

def test_head_failure_isolates_task_and_recovers(served):
    """A raising decoder head fails ONLY its own task's requests (bounded
    retries, then HeadFailure → status "head_failed"); co-batched tasks
    resolve normally, and the restored head re-probes from scratch."""
    srv, cfg, loop, _ = served
    ex = srv.executors["fm0"]
    rng = np.random.RandomState(23)
    hf0, retries0 = ex.head_failures["task2"], ex.retries
    fault = RaisingHeadFault("task2")
    fault.inject(loop)
    try:
        r_ok = _pooled(cfg, rng, tid="task0")
        r_bad = _pooled(cfg, rng, tid="task2")
        loop.run([r_ok, r_bad], max_wall=60)
    finally:
        fault.restore(loop)
    assert r_bad.status == "head_failed" and r_bad.result is None
    assert r_bad.error and "InjectedFailure" in r_bad.error
    assert r_ok.ok and np.all(np.isfinite(np.asarray(r_ok.result)))
    assert ex.head_failures["task2"] == hf0 + 1
    assert ex.retries == retries0 + ex.head_retries
    # recovery: the restored head re-probes and serves again
    r_again = _pooled(cfg, rng, tid="task2")
    loop.run([r_again], max_wall=60)
    assert r_again.ok and np.all(np.isfinite(np.asarray(r_again.result)))


# ---------------- watchdog + stall ----------------

def test_watchdog_trips_on_stall_then_stream_recovers(served):
    """A stalled engine (step_chunk no-op) with live work trips the loop
    watchdog — no crash, no hang — and the stream finishes exactly once the
    stall lifts."""
    srv, cfg, loop, _ = served
    eng = srv.engines["fm0"]
    rng = np.random.RandomState(29)
    old = loop.watchdog_stall_s
    loop.watchdog_stall_s = 0.05
    trips0 = loop.failures["watchdog_trips"]
    stream = _gen(cfg, rng, tid="task1", new=12)
    loop.submit(stream, time.perf_counter())
    while not eng.active_count():
        loop.tick()
    fault = StallFault()
    fault.inject(loop)
    t0 = time.perf_counter()
    try:
        while loop.failures["watchdog_trips"] == trips0:
            loop.tick()
            assert time.perf_counter() - t0 < 10.0, "watchdog never tripped"
    finally:
        fault.restore(loop)
        loop.watchdog_stall_s = old
    while stream.finish_time is None:
        loop.tick()
    assert stream.ok and len(stream.result) == 12
    while loop._work_left():
        loop.tick()


def test_page_pressure_fault_steals_and_returns(served):
    srv, cfg, loop, _ = served
    eng = srv.engines["fm0"]
    free0 = eng.free_page_count()
    assert free0 > 0
    fault = PagePressureFault(1.0)
    fault.inject(loop)
    try:
        assert eng.free_page_count() == 0
        assert not eng.can_admit(8)              # memory gate closed
    finally:
        fault.restore(loop)
    assert eng.free_page_count() == free0
    assert eng.can_admit(8)


# ---------------- stranded-sharer wedge recovery ----------------

def test_stranded_sharer_wedge_sheds_terminally(served):
    """A deferred join admitted on the strength of a prefix discount whose
    sharer retires becomes stranded; with nothing live the engine raises the
    wedge error for direct users, and ``shed_stranded`` converts the entry
    to a terminal ``rejected_stranded`` (the serve loop's recovery path)."""
    srv, cfg, loop, _ = served
    from repro.core.decode_engine import DecodeEngine
    fm = srv.fms["fm0"]
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=4, chunk=2,
                       paged=True, page_size=4, total_pages=5,
                       prompt_buckets=(8, 16))
    rng = np.random.RandomState(31)
    prefix = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    # A registers 2 full prefix pages (bucket 8: 2 pages + 1 chunk headroom
    # fits the 4-page arena)
    assert eng.join("t", prefix, adapter_id="lora0", max_new_tokens=4,
                    rid=1) >= 0
    # B (bucket 16: 4 pages) only fits BECAUSE the discount covers 2 of
    # them — with A holding pages it defers instead of admitting
    sfx = rng.randint(0, cfg.vocab_size, 4).astype(np.int32)
    assert eng.join("t", np.concatenate([prefix, sfx]), adapter_id="lora0",
                    max_new_tokens=4, rid=2) == -1
    assert eng.pending_count() == 1
    # the sharer cancels: registry entry released, B can never fit again
    assert eng.cancel(1) is not None
    with pytest.raises(ValueError, match="no longer fit"):
        eng.step_chunk()                         # wedged: loud for direct use
    assert eng.shed_stranded() == 1
    rej = eng.take_rejected()
    assert [p.rid for p in rej] == [2]
    assert rej[0].status == "rejected_stranded"
    assert rej[0].status in FAILURE_STATUSES
    assert eng.step_chunk() == []                # unwedged, serving again
    assert eng.free_page_count() == eng.total_pages - 1


# ---------------- chaos-injection scheduler ----------------

def test_chaos_injector_schedule_is_deterministic():
    class Rec(Fault):
        def __init__(self, name):
            self.name, self.state = name, "idle"

        def inject(self, loop):
            self.state = "armed"

        def restore(self, loop):
            self.state = "restored"

    f1, f2 = Rec("f1"), Rec("f2")
    inj = ChaosInjector([ChaosEvent(0.5, f2, duration=1.0),
                         ChaosEvent(0.0, f1)])
    inj.on_tick(None, 0.0)
    assert f1.state == "armed" and f2.state == "idle"
    inj.on_tick(None, 0.6)
    assert f2.state == "armed"
    inj.on_tick(None, 1.4)
    assert f2.state == "armed"                   # duration not elapsed
    inj.on_tick(None, 1.6)
    assert f2.state == "restored"
    inj.restore_all(None)                        # cleans up f1, not f2 twice
    assert f1.state == "restored"
    assert [(n, a) for _, n, a in inj.log] == [
        ("f1", "inject"), ("f2", "inject"), ("f2", "restore"),
        ("f1", "restore_all")]
