"""End-to-end system behaviour on the REAL execution plane: a live
FMplexServer with a JAX backbone, multiple vFMs (heads + LoRA adapters),
BFQ-scheduled execution, isolation, and vFM rebinding."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM
from repro.core.request import Request, SLO
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions


@pytest.fixture(scope="module")
def server():
    cfg = reduced(get_config("moment-large"))
    fm = PhysicalFM(cfg, seed=0, input_len=16, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(0)
    for i in range(3):
        w = rng.randn(cfg.d_model, 2).astype(np.float32) * 0.1
        head = (lambda ww: (lambda f: f @ ww))(w)
        fm.adapters.new(f"lora{i}", seed=i)
        # generous SLO: profile calibration under a loaded CPU can inflate
        # l(b); SLO-bounded batching itself is covered by test_bfq
        srv.bind_task(f"task{i}", "fm0", weight=float(i + 1), slo=SLO(60.0),
                      extensions=TaskExtensions(decoder=head,
                                                adapter_id=f"lora{i}"))
    return srv, cfg


def _req(srv, cfg, tid, t=None):
    import time
    t = time.perf_counter() if t is None else t   # real plane uses wall clock
    x = np.random.RandomState(1).randn(16, cfg.d_model).astype(np.float32)
    r = Request(tid, t, payload=x)
    srv.on_arrival(r, t)
    return r


def test_shared_backbone_single_instance(server):
    srv, cfg = server
    assert len(srv.fms) == 1 and len(srv.vfms) == 3   # 3 tasks, 1 backbone


def test_cross_task_cobatching_and_heads(server):
    srv, cfg = server
    rs = [_req(srv, cfg, f"task{i}") for i in range(3)]
    batch = srv.step("fm0")
    assert batch is not None and batch.size == 3      # inter-task co-batch
    assert batch.num_adapters == 3                    # adapter sub-batches
    for r in rs:
        assert r.result.shape == (2,)                 # per-task head applied
        assert np.all(np.isfinite(r.result))


def test_task_outputs_differ_by_adapter(server):
    """Same input through different vFMs -> different outputs (customization
    is task-private even on a shared backbone)."""
    srv, cfg = server
    r0 = _req(srv, cfg, "task0")
    r1 = _req(srv, cfg, "task1")
    srv.step("fm0")
    assert not np.allclose(r0.result, r1.result)


def test_accounting_tracked_per_vfm(server):
    srv, cfg = server
    before = srv.vfms["task2"].acct.completed
    _req(srv, cfg, "task2")
    srv.step("fm0")
    acct = srv.vfms["task2"].acct
    assert acct.completed == before + 1
    assert acct.service_time > 0


def test_rebind_moves_task_state_only(server):
    """Elastic adaptation: unbind -> snapshot -> rebind preserves identity,
    queue and extensions without touching the backbone."""
    srv, cfg = server
    _req(srv, cfg, "task1")          # leave one request queued
    snap = srv.unbind_task("task1")
    assert snap is not None and len(snap["queue"]) >= 1
    assert "task1" not in srv.vfms
    vfm = srv.rebind_snapshot(snap, "fm0")
    assert vfm.acct.completed >= 1           # accounting identity preserved
    assert len(vfm.queue) >= 1               # queued work moved with the task
    batch = srv.step("fm0")                  # and is servable after rebind
    assert batch is not None


def test_independent_lifecycle_add_remove(server):
    """Tasks attach/detach without redeploying the backbone."""
    srv, cfg = server
    fm = srv.fms["fm0"]
    n_adapters = len(fm.adapters.ids)
    fm.adapters.new("lora_tmp", seed=9)
    srv.bind_task("task_tmp", "fm0", weight=1.0,
                  extensions=TaskExtensions(decoder=lambda f: f[:1],
                                            adapter_id="lora_tmp"))
    r = _req(srv, cfg, "task_tmp")
    srv.step("fm0")
    assert r.result is not None
    srv.unbind_task("task_tmp")
    fm.adapters.remove("lora_tmp")
    assert len(fm.adapters.ids) == n_adapters
    assert "task_tmp" not in srv.vfms
    # surviving tasks still serve
    r2 = _req(srv, cfg, "task0")
    srv.step("fm0")
    assert r2.result is not None
