"""Distributed utilities: compressed collectives (multi-device via subprocess),
LoRA multi-adapter routing, HLO roofline parser."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_compressed_psum_multidevice():
    """int8 compressed all-reduce vs exact psum on a 4-device host mesh.
    Runs in a subprocess because device count locks at first jax init."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_pmean
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("data",))
x = jnp.asarray(np.random.RandomState(0).randn(4, 64).astype(np.float32))
def f(xs):
    return compressed_pmean(xs, "data")
def g(xs):
    return jax.lax.pmean(xs, "data")
fc = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
fe = jax.jit(shard_map(g, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
got, want = fc(x), fe(x)
err = float(jnp.max(jnp.abs(got - want)))
scale = float(jnp.max(jnp.abs(want))) + 1e-9
assert err / scale < 2e-2, (err, scale)
print("OK", err)
"""
    # JAX_PLATFORMS=cpu: without it jax may probe TPU/GCP metadata endpoints
    # from the stripped env, stalling the subprocess past its timeout
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=240, env={"PYTHONPATH": "src",
                                                    "PATH": "/usr/bin:/bin",
                                                    "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_lora_zero_init_is_identity():
    from repro.configs import get_config, reduced
    from repro.models import lm, lora
    cfg = reduced(get_config("stablelm-1.6b"))
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    ad = lora.init_single_adapter(jax.random.PRNGKey(2), cfg, rank=4)
    x0, _, _ = lm.forward(params, cfg, tokens=toks)
    x1, _, _ = lm.forward(params, cfg, tokens=toks, lora=ad,
                          adapter_idx=jnp.zeros((2,), jnp.int32))
    assert float(jnp.max(jnp.abs(x1 - x0))) == 0.0   # b-matrices zero-init


def test_lora_routing_is_task_private():
    from repro.configs import get_config, reduced
    from repro.models import lm, lora
    cfg = reduced(get_config("qwen2-7b"))
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
    ads = [lora.init_single_adapter(jax.random.PRNGKey(i), cfg, 4)
           for i in (3, 4)]
    stack = lora.stack_adapters(ads)
    stack[0]["q"]["b"] = stack[0]["q"]["b"].at[:, 1].add(0.3)  # adapter 1 only
    aidx = jnp.array([0, 1, 2, 1], jnp.int32)                  # 2 = base
    x0, _, _ = lm.forward(params, cfg, tokens=toks)
    x1, _, _ = lm.forward(params, cfg, tokens=toks, lora=stack, adapter_idx=aidx)
    d = np.asarray(jnp.abs(x1 - x0).max(axis=(1, 2)))
    assert d[1] > 0 and d[3] > 0 and d[0] == 0 and d[2] == 0


def test_hlo_analyze_matches_cost_analysis_loop_free():
    """On a loop-free program the parser must agree with XLA cost analysis."""
    from repro.launch.hlo import analyze
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = jax.jit(lambda x, y: (x @ y).sum()).lower(a, b).compile()
    got = analyze(c.as_text())["dot_flops"]
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert got == pytest.approx(float(ca["flops"]), rel=0.01)


def test_hlo_analyze_multiplies_loop_bodies():
    """Scanned matmul: parser must count the body x trip-count (XLA doesn't)."""
    from repro.launch.hlo import analyze

    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=9)[0]

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(a, a).compile()
    got = analyze(c.as_text())["dot_flops"]
    assert got == pytest.approx(9 * 2 * 64 ** 3, rel=0.01)
