"""Sharding rules + TP padding exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.sharding.padding import pad_for_tp, pad_params
from repro.sharding.rules import ACT_RULES, FSDP_RULES, TP_RULES, spec_for


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_spec_for_basic():
    s = spec_for(TP_RULES, ("embed", "heads", "head_dim"), FakeMesh(),
                 (1024, 32, 128))
    assert tuple(s) == (None, "model", None)


def test_spec_for_divisibility_fallback():
    # kv_heads=4 < 16 shards -> replicate
    s = spec_for(TP_RULES, ("embed", "kv_heads", None), FakeMesh(), (512, 4, 64))
    assert tuple(s) == (None, None, None)


def test_spec_for_uneven_allowed_when_fits():
    # 28 heads over 16: uneven is allowed at constraint level (dim >= size)
    s = spec_for(ACT_RULES, ("batch", None, "heads", None), FakeMesh(),
                 (32, 1, 28, 128))
    assert s[2] == "model"


def test_spec_for_axis_used_once():
    # mlp takes 'model'; heads cannot reuse it
    s = spec_for(TP_RULES, ("mlp", "heads"), FakeMesh(), (1024, 32))
    assert tuple(s) == ("model", None)


def test_spec_for_tuple_prefix():
    class M3:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    # batch 8 divides pod(2) but not pod*data(32): only 'pod' taken... 8>=2 and
    # 8 >= 32? no -> prefix stops at pod
    s = spec_for(FSDP_RULES, ("batch",), M3(), (8,))
    assert s[0] == ("pod", "data") or s[0] == "pod"


@pytest.mark.parametrize("arch", ["qwen2-7b", "whisper-base", "grok-1-314b"])
def test_pad_for_tp_shapes(arch):
    cfg = get_config(arch)
    p = pad_for_tp(cfg, 16)
    assert p.num_heads % 16 == 0 or p.num_kv_heads % 16 == 0
    assert p.num_kv_heads % 16 == 0
    assert p.num_heads % p.num_kv_heads == 0
    if cfg.vocab_size % 16:
        assert p.vocab_size % 16 == 0 and p.true_vocab == cfg.vocab_size


def test_pad_params_exactness():
    """Padded model (zero pad q-heads, replicated kv) == base model, exactly."""
    base = dataclasses.replace(
        reduced(get_config("qwen2-7b")),
        num_heads=6, num_kv_heads=2, head_dim=16, d_model=64)
    padded_cfg = pad_for_tp(base, 4)       # kv 2->4 (r=2), G 3->4, H 6->16? -> per math
    assert padded_cfg.num_kv_heads % 4 == 0
    params = lm.init_model(jax.random.PRNGKey(0), base)
    pp = pad_params(params, base, padded_cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, base.vocab_size)
    x0, _, _ = lm.forward(params, base, tokens=toks)
    x1, _, _ = lm.forward(pp, padded_cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(x0, np.float32),
                               np.asarray(x1, np.float32), atol=2e-2, rtol=2e-2)


def test_padded_vocab_loss_masked():
    base = reduced(get_config("qwen2-7b"), vocab_size=250)   # 250 % 4 != 0
    padded_cfg = pad_for_tp(base, 4)
    assert padded_cfg.vocab_size > 250 and padded_cfg.true_vocab == 250
    params = lm.init_model(jax.random.PRNGKey(0), base)
    pp = pad_params(params, base, padded_cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 250)
    l0, _ = lm.loss_fn(params, base, {"tokens": toks}, remat=False)
    l1, _ = lm.loss_fn(pp, padded_cfg, {"tokens": toks}, remat=False)
    assert abs(float(l0) - float(l1)) < 5e-2   # pad logits masked to -inf
