"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bfq import BFQ
from repro.core.profile import FMProfile
from repro.core.request import Request
from repro.core.vfm import VFM
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.kernels.segmented_lora import sort_by_adapter
from repro.serving.metrics import jain_fairness

# ---------------- BFQ invariants ----------------

weights_st = st.lists(st.floats(0.5, 8.0), min_size=2, max_size=5)
arrivals_st = st.lists(st.tuples(st.integers(0, 4),
                                 st.floats(0, 1.0)), min_size=5, max_size=60)


@settings(max_examples=60, deadline=None)
@given(weights=weights_st, arrivals=arrivals_st, b_max=st.integers(1, 16))
def test_bfq_completes_everything_and_bounds_batches(weights, arrivals, b_max):
    """Work conservation: every request is eventually dispatched; batches never
    exceed B_max; per-task start tags are non-decreasing in dispatch order."""
    prof = FMProfile("fm", alpha=5e-3, beta=1e-3, b_max=b_max)
    sched = BFQ(prof)
    vfms = {f"t{i}": VFM(f"t{i}", weight=w) for i, w in enumerate(weights)}
    reqs = []
    for ti, at in sorted(arrivals, key=lambda x: x[1]):
        tid = f"t{ti % len(weights)}"
        r = Request(tid, at)
        sched.on_arrival(vfms[tid], r, at)
        reqs.append(r)
    now, dispatched = 1.0, []
    last_start = {}
    while True:
        b = sched.next_batch(vfms, now)
        if b is None:
            break
        assert b.size <= b_max
        for r in b.requests:
            prev = last_start.get(r.task_id, -1e18)
            assert r.start_tag >= prev - 1e-9
            last_start[r.task_id] = r.start_tag
        now += sched.exec_time(b)
        sched.on_complete(b, vfms, now)
        dispatched += b.requests
    assert len(dispatched) == len(reqs)
    assert not any(len(v.queue) for v in vfms.values())


@settings(max_examples=30, deadline=None)
@given(wa=st.floats(1.0, 4.0), wb=st.floats(1.0, 4.0))
def test_bfq_saturated_shares_track_weights(wa, wb):
    """Under permanent backlog, service shares converge to the weight ratio."""
    prof = FMProfile("fm", alpha=5e-3, beta=1e-3, b_max=1)  # b=1 isolates tags
    sched = BFQ(prof)
    vfms = {"A": VFM("A", weight=wa), "B": VFM("B", weight=wb)}
    for i in range(400):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
        sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    served = {"A": 0, "B": 0}
    for _ in range(200):
        b = sched.next_batch(vfms, 0.0)
        served[b.requests[0].task_id] += 1
        sched.on_complete(b, vfms, 0.0)
    got = served["A"] / max(served["B"], 1)
    want = wa / wb
    assert abs(got - want) / want < 0.15
    f = jain_fairness(served, {"A": wa, "B": wb})
    assert f > 0.97


# ---------------- sharding rules ----------------

@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       data=st.data())
def test_spec_for_never_reuses_axes_and_respects_fit(dims, data):
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.sharding.rules import ACT_RULES, spec_for

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 4}

    names = data.draw(st.lists(
        st.sampled_from(list(ACT_RULES) + [None]),
        min_size=len(dims), max_size=len(dims)))
    spec = spec_for(ACT_RULES, tuple(names), FakeMesh(), tuple(dims))
    used = []
    for part, dim in zip(spec, dims):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes:
            assert a not in used
            used.append(a)
            prod *= FakeMesh.shape[a]
        assert dim >= prod       # every shard nonempty


# ---------------- kernels / compression ----------------

@settings(max_examples=40, deadline=None)
@given(ids=st.lists(st.integers(0, 7), min_size=1, max_size=200),
       bt=st.sampled_from([8, 16, 32]))
def test_sort_by_adapter_properties(ids, bt):
    ids = np.array(ids)
    perm, blocks, total = sort_by_adapter(ids, 8, block_t=bt)
    assert total % bt == 0 and len(blocks) == total // bt
    seen = sorted(j for j in perm if j >= 0)
    assert seen == list(range(len(ids)))            # permutation, no loss
    for i, aid in enumerate(blocks):
        real = {ids[j] for j in perm[i * bt:(i + 1) * bt] if j >= 0}
        assert len(real) <= 1 and (not real or real.pop() == aid)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=64))
def test_int8_quantization_error_bound(xs):
    import jax.numpy as jnp
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 0.5 + 1e-6
    assert float(jnp.max(jnp.abs(back - x))) <= bound + 1e-5
