"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bfq import BFQ
from repro.core.profile import FMProfile
from repro.core.request import Request
from repro.core.vfm import VFM
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.kernels.segmented_lora import sort_by_adapter
from repro.serving.metrics import jain_fairness

# ---------------- BFQ invariants ----------------

weights_st = st.lists(st.floats(0.5, 8.0), min_size=2, max_size=5)
arrivals_st = st.lists(st.tuples(st.integers(0, 4),
                                 st.floats(0, 1.0)), min_size=5, max_size=60)


@settings(max_examples=60, deadline=None)
@given(weights=weights_st, arrivals=arrivals_st, b_max=st.integers(1, 16))
def test_bfq_completes_everything_and_bounds_batches(weights, arrivals, b_max):
    """Work conservation: every request is eventually dispatched; batches never
    exceed B_max; per-task start tags are non-decreasing in dispatch order."""
    prof = FMProfile("fm", alpha=5e-3, beta=1e-3, b_max=b_max)
    sched = BFQ(prof)
    vfms = {f"t{i}": VFM(f"t{i}", weight=w) for i, w in enumerate(weights)}
    reqs = []
    for ti, at in sorted(arrivals, key=lambda x: x[1]):
        tid = f"t{ti % len(weights)}"
        r = Request(tid, at)
        sched.on_arrival(vfms[tid], r, at)
        reqs.append(r)
    now, dispatched = 1.0, []
    last_start = {}
    while True:
        b = sched.next_batch(vfms, now)
        if b is None:
            break
        assert b.size <= b_max
        for r in b.requests:
            prev = last_start.get(r.task_id, -1e18)
            assert r.start_tag >= prev - 1e-9
            last_start[r.task_id] = r.start_tag
        now += sched.exec_time(b)
        sched.on_complete(b, vfms, now)
        dispatched += b.requests
    assert len(dispatched) == len(reqs)
    assert not any(len(v.queue) for v in vfms.values())


@settings(max_examples=30, deadline=None)
@given(wa=st.floats(1.0, 4.0), wb=st.floats(1.0, 4.0))
def test_bfq_saturated_shares_track_weights(wa, wb):
    """Under permanent backlog, service shares converge to the weight ratio."""
    prof = FMProfile("fm", alpha=5e-3, beta=1e-3, b_max=1)  # b=1 isolates tags
    sched = BFQ(prof)
    vfms = {"A": VFM("A", weight=wa), "B": VFM("B", weight=wb)}
    for i in range(400):
        sched.on_arrival(vfms["A"], Request("A", 0.0), 0.0)
        sched.on_arrival(vfms["B"], Request("B", 0.0), 0.0)
    served = {"A": 0, "B": 0}
    for _ in range(200):
        b = sched.next_batch(vfms, 0.0)
        served[b.requests[0].task_id] += 1
        sched.on_complete(b, vfms, 0.0)
    got = served["A"] / max(served["B"], 1)
    want = wa / wb
    assert abs(got - want) / want < 0.15
    f = jain_fairness(served, {"A": wa, "B": wb})
    assert f > 0.97


# ---------------- sharding rules ----------------

@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       data=st.data())
def test_spec_for_never_reuses_axes_and_respects_fit(dims, data):
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.sharding.rules import ACT_RULES, spec_for

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 4}

    names = data.draw(st.lists(
        st.sampled_from(list(ACT_RULES) + [None]),
        min_size=len(dims), max_size=len(dims)))
    spec = spec_for(ACT_RULES, tuple(names), FakeMesh(), tuple(dims))
    used = []
    for part, dim in zip(spec, dims):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes:
            assert a not in used
            used.append(a)
            prod *= FakeMesh.shape[a]
        assert dim >= prod       # every shard nonempty


# ---------------- kernels / compression ----------------

@settings(max_examples=40, deadline=None)
@given(ids=st.lists(st.integers(0, 7), min_size=1, max_size=200),
       bt=st.sampled_from([8, 16, 32]))
def test_sort_by_adapter_properties(ids, bt):
    ids = np.array(ids)
    perm, blocks, total = sort_by_adapter(ids, 8, block_t=bt)
    assert total % bt == 0 and len(blocks) == total // bt
    seen = sorted(j for j in perm if j >= 0)
    assert seen == list(range(len(ids)))            # permutation, no loss
    for i, aid in enumerate(blocks):
        real = {ids[j] for j in perm[i * bt:(i + 1) * bt] if j >= 0}
        assert len(real) <= 1 and (not real or real.pop() == aid)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=64))
def test_int8_quantization_error_bound(xs):
    import jax.numpy as jnp
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 0.5 + 1e-6
    assert float(jnp.max(jnp.abs(back - x))) <= bound + 1e-5


# ---------------- refcounted paged KV pool (COW prefix sharing) ----------------

_PAGED_FM = []          # built once, lazily (a PhysicalFM is expensive)


def _paged_fm():
    if not _PAGED_FM:
        from repro.configs import get_config, reduced
        from repro.core.physical import PhysicalFM
        fm = PhysicalFM(reduced(get_config("stablelm-1.6b")), seed=0,
                        input_len=8, lora_rank=4, lora_impl="segmented",
                        seg_block_t=8)
        fm.adapters.new("lora0", seed=0)
        _PAGED_FM.append(fm)
    return _PAGED_FM[0]


def _check_page_invariants(eng):
    """The refcounted free-list contract: every usable page's refcount equals
    the number of live page-table mappings of it; a page sits on the free
    list exactly when its refcount is zero (and exactly once); the prefix
    registry only references live pages; live slots hold enough pages for
    their tokens; the trash page is never mapped."""
    import collections
    from repro.core.decode_engine import TRASH_PAGE
    held = [int(p) for s in range(eng.num_slots)
            for p in eng._ptab[s, :eng._held[s]]]
    c = collections.Counter(held)
    assert TRASH_PAGE not in c
    free = eng._free_pages
    free_set = set(free)
    assert len(free) == len(free_set), "duplicate free-list entry"
    for p in range(1, eng.total_pages):
        assert eng._page_refs[p] == c.get(p, 0), \
            f"page {p}: refcount {eng._page_refs[p]} != {c.get(p, 0)} mappings"
        assert (eng._page_refs[p] == 0) == (p in free_set), \
            f"page {p}: free-list membership disagrees with refcount"
    for key, p in eng._prefix_registry.items():
        assert eng._page_refs[p] > 0 and eng._page_key.get(p) == key
    # chunked-prefill float sidecars shadow REGISTERED live pages only:
    # releases pop them (or move them into the spill blob), so a sidecar
    # for a free or unregistered page would be a leak feeding stale floats
    # to future sharers
    for p in getattr(eng, "_page_float", {}):
        assert eng._page_refs[p] > 0, f"sidecar for free page {p}"
        assert eng._prefix_registry.get(eng._page_key.get(p)) == p, \
            f"sidecar for unregistered page {p}"
    # memoized assembled-prefix operands must reference live pages only —
    # a key containing a freed id could serve stale floats after the id
    # is recycled for different content
    for fpkey in getattr(eng, "_prefix_fp_cache", {}):
        for p in fpkey:
            assert eng._page_refs[p] > 0, \
                f"assembled-prefix cache holds freed page {p}"
    for s in range(eng.num_slots):
        slot = eng.slots[s]
        # done-but-unretired slots stop being topped up (their residual
        # writes land in the trash page), so only LIVE slots must hold
        # pages covering their token count
        if slot is not None and not slot.done:
            need = -(-max(int(eng._lens[s]), 1) // eng.page_size)
            assert eng._held[s] >= need
    # speculative-rollback contract: after every dispatch the device-side
    # slot lengths equal the host allocator's view for LIVE streams — a
    # speculative KV write surviving past its reject point would leave the
    # device length ahead of host ``_lens``
    for sub in eng.pool:
        if isinstance(sub, dict) and "page_table" in sub:
            dev = np.asarray(sub["len"])
            for s in range(eng.num_slots):
                slot = eng.slots[s]
                if slot is not None and not slot.done:
                    assert (dev[:, s] == int(eng._lens[s])).all(), \
                        f"slot {s}: device len {dev[:, s]} != host " \
                        f"{int(eng._lens[s])}"


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 8), st.integers(0, 7)),
                    min_size=4, max_size=18),
       spec=st.booleans())
def test_paged_refcounts_never_leak_or_double_free(ops, spec):
    """Randomized join/decode/preempt/retire sequences over shared-prefix
    prompts (joins take the CHUNKED tail-admission path whenever the prefix
    is live or spilled), interleaved with the FAULT plane (client cancel by
    rid, mid-flight deadline expiry) and the DURABILITY plane (host spill
    on every preemption, snapshot/restore with a scrambled old arena — a
    simulated device reset — spill-entry corruption, and a mass-retire that
    pushes the prefix to the spill tier right before a late sharer pulls it
    back): the refcounted free list never double-frees or leaks a page,
    unwinding a sharer through ANY exit path never touches another
    stream's mapped pages, the prefix registry only ever references live
    pages, float sidecars shadow exactly the registered pages, restored
    engines uphold all of it, terminally rejected entries always carry a
    failure status, and a final drain returns the arena to fully free."""
    import time

    import jax.numpy as jnp

    from repro.core.decode_engine import DecodeEngine
    fm = _paged_fm()
    cfg = fm.cfg
    # spec=True runs the identical churn through the SPECULATIVE decode
    # plane (multi-token steps, in-scan rollback) — every allocator,
    # sharing, durability and rollback invariant must hold there too
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4, total_pages=21,
                       prompt_buckets=(4, 16), spill_bytes=32 << 20,
                       spec_k=2 if spec else 0, spec_disable_below=1.0)
    rng = np.random.RandomState(0)
    prefixes = [rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
                for _ in range(2)]
    rid = 0
    rejected = []
    for op, a in ops:
        live = [i for i, s in enumerate(eng.slots) if s is not None]
        if op == 0:                                  # join (shared prefix)
            sfx = np.random.RandomState(a).randint(
                0, cfg.vocab_size, 1 + a % 5).astype(np.int32)
            eng.join(f"t{rid}", np.concatenate([prefixes[a % 2], sfx]),
                     adapter_id="lora0", max_new_tokens=1 + a % 6, rid=rid)
            rid += 1
        elif op == 1:
            eng.step_chunk()
        elif op == 2 and live:                       # preempt (spills D2H)
            eng._preempt(live[a % len(live)])
        elif op == 3 and live:                       # retire a stream
            eng.leave(live[a % len(live)])
        elif op == 4:                                # client cancel by rid
            rids = [s.rid for s in eng.slots if s is not None] \
                + eng.pending_rids()
            if rids:
                assert eng.cancel(rids[a % len(rids)]) is not None
        elif op == 5 and live:                       # deadline expiry
            eng.slots[live[a % len(live)]].deadline = 0.0
            eng._expire_deadlines(time.perf_counter())
        elif op == 6:                                # device reset mid-churn
            snap = eng.snapshot()
            old, eng = eng, None
            for sub in old.pool:                     # scramble dead arena
                if isinstance(sub, dict) and "page_table" in sub:
                    sub["k"] = jnp.full_like(sub["k"], 77)
                    sub["k_scale"] = jnp.zeros_like(sub["k_scale"])
            eng = DecodeEngine.restore(fm, snap, reuse_jits_from=old)
        elif op == 7 and len(eng.spill):             # corrupt a spill entry
            key = list(eng.spill._entries)[a % len(eng.spill)]
            d = eng.spill._entries[key].blob[0]
            name = next(iter(d))
            arr = np.ascontiguousarray(d[name])
            arr.view(np.uint8).reshape(-1)[::3] ^= 0xFF
            d[name] = arr
        elif op == 8:                                # mass retire (prefix
            for s in live:                           # spills), late sharer
                eng.leave(s)                         # restores + tail-admits
            sfx = np.random.RandomState(99 + a).randint(
                0, cfg.vocab_size, 1 + a % 5).astype(np.int32)
            eng.join(f"late{rid}", np.concatenate([prefixes[a % 2], sfx]),
                     adapter_id="lora0", max_new_tokens=1 + a % 4, rid=rid)
            rid += 1
        rejected += eng.take_rejected()
        _check_page_invariants(eng)
    for _ in range(200):
        if not (eng.active_count() or eng.pending_count()):
            break
        eng.step_chunk()
        _check_page_invariants(eng)
    assert not (eng.active_count() or eng.pending_count())
    assert eng.free_page_count() == eng.total_pages - 1
    assert (eng._page_refs[1:] == 0).all()
    assert not eng._prefix_registry and not eng._page_key
    rejected += eng.take_rejected()
    assert all(p.status != "ok" for p in rejected)


# ---------------- hybrid state-slot pool (cache-manager plane) ----------------

_HYB_FM = []            # built once, lazily (a PhysicalFM is expensive)


def _hybrid_fm():
    if not _HYB_FM:
        from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
        from repro.core.physical import PhysicalFM
        cfg = ModelConfig(name="hyb-prop", family="hybrid", num_layers=4,
                          d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=128,
                          block_pattern=(MAMBA, ATTN, MLSTM, SLSTM))
        fm = PhysicalFM(cfg, seed=0, input_len=16, lora_rank=4,
                        lora_impl="segmented", seg_block_t=8)
        fm.adapters.new("lora0", seed=0)
        _HYB_FM.append(fm)
    return _HYB_FM[0]


def _check_state_slot_invariants(eng):
    """The fixed-size state-slot contract on a hybrid pool: a state slot is
    allocated exactly when its decode slot holds a live stream (done-but-
    unretired included — the dense state is freed at retirement, with the
    pages), alloc/free counters balance against occupancy, and occupancy
    never exceeds the pool."""
    sp = eng.state_pool
    assert sp is not None
    live = {i for i, s in enumerate(eng.slots) if s is not None}
    assert sp.slots_in_use() == live, \
        f"state slots {sp.slots_in_use()} != live decode slots {live}"
    assert sp.allocs - sp.frees == sp.in_use_count()
    assert sp.in_use_count() <= sp.num_slots
    assert sp.peak_in_use <= sp.num_slots


@settings(max_examples=6, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)),
                    min_size=4, max_size=16))
def test_hybrid_state_slots_never_leak_or_double_alloc(ops):
    """The paged-churn property on a HYBRID stack (mamba + attention +
    xLSTM): randomized join/decode/preempt/retire/cancel/deadline/restore
    sequences keep the state-slot pool 1:1 with live streams on every exit
    path, the page invariants hold for the attention sublayer's arena, and
    a final drain leaves both pools fully free. The spill-corruption op is
    absent by construction — the spill tier demotes on hybrid stacks (its
    capture has no dense-state side), which the engine enforces."""
    import time

    import jax.numpy as jnp

    from repro.core.decode_engine import DecodeEngine
    fm = _hybrid_fm()
    cfg = fm.cfg
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       paged=True, page_size=4, total_pages=25,
                       prompt_buckets=(4, 16))
    assert eng.spill is None and not eng.prefix_sharing     # demoted planes
    rng = np.random.RandomState(0)
    rid = 0
    rejected = []
    for op, a in ops:
        live = [i for i, s in enumerate(eng.slots) if s is not None]
        if op == 0:                                  # join, variable length
            p = np.random.RandomState(a).randint(
                0, cfg.vocab_size, 1 + a * 2).astype(np.int32)
            eng.join(f"t{rid}", p, adapter_id="lora0" if a % 2 else None,
                     max_new_tokens=1 + a % 6, rid=rid)
            rid += 1
        elif op == 1:
            eng.step_chunk()
        elif op == 2 and live:                       # preempt: fold +
            eng._preempt(live[a % len(live)])        # re-prefill recomputes
        elif op == 3 and live:                       # retire a stream
            eng.leave(live[a % len(live)])
        elif op == 4:                                # client cancel by rid
            rids = [s.rid for s in eng.slots if s is not None] \
                + eng.pending_rids()
            if rids:
                assert eng.cancel(rids[a % len(rids)]) is not None
        elif op == 5 and live:                       # deadline expiry
            eng.slots[live[a % len(live)]].deadline = 0.0
            eng._expire_deadlines(time.perf_counter())
        elif op == 6:                                # device reset mid-churn
            snap = eng.snapshot()
            old, eng = eng, None
            for sub in old.pool:                     # scramble dead arena
                if isinstance(sub, dict) and "page_table" in sub:
                    sub["k"] = jnp.full_like(sub["k"], 77)
                    sub["k_scale"] = jnp.zeros_like(sub["k_scale"])
            eng = DecodeEngine.restore(fm, snap, reuse_jits_from=old)
        elif op == 7:                                # mass retire, late join
            for s in live:
                eng.leave(s)
            p = np.random.RandomState(99 + a).randint(
                0, cfg.vocab_size, 1 + a % 9).astype(np.int32)
            eng.join(f"late{rid}", p, adapter_id="lora0",
                     max_new_tokens=1 + a % 4, rid=rid)
            rid += 1
        rejected += eng.take_rejected()
        _check_page_invariants(eng)
        _check_state_slot_invariants(eng)
    for _ in range(200):
        if not (eng.active_count() or eng.pending_count()):
            break
        eng.step_chunk()
        _check_page_invariants(eng)
        _check_state_slot_invariants(eng)
    assert not (eng.active_count() or eng.pending_count())
    assert eng.free_page_count() == eng.total_pages - 1
    assert eng.state_pool.in_use_count() == 0
    assert eng.state_pool.allocs == eng.state_pool.frees
    rejected += eng.take_rejected()
    assert all(p.status != "ok" for p in rejected)
