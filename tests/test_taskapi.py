"""Task-API: pipeline composition, fine-tuning, artifacts."""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.taskapi import (Adapter, LinearChannelCombiner, MLPDecoder,
                           Pipeline, vFM)
from repro.taskapi.artifacts import deserialize, serialize, task_spec


@pytest.fixture(scope="module")
def pipeline():
    cfg = reduced(get_config("moment-large"))
    P = Pipeline(vFM(cfg), task_id="hr")
    P.add_encoder(LinearChannelCombiner(3, 1, 8, cfg.d_model))
    P.add_decoder(MLPDecoder(cfg.d_model, 16, 1))
    P.attach_adapter(Adapter(rank=4, adapter_id="hr_lora"))
    return P


def test_run_shapes(pipeline):
    y = pipeline.run(np.random.RandomState(0).randn(3, 64, 3).astype(np.float32))
    assert y.shape == (3, 1)


def test_train_improves_loss(pipeline):
    rng = np.random.RandomState(0)

    def data():
        while True:
            x = rng.randn(16, 64, 3).astype(np.float32)
            y = (x[:, :, 0].mean(axis=1) * 5.0 + 1.0)[:, None]
            yield x, y

    losses = pipeline.train(data(), steps=80, lr=5e-3, loss="mse")
    assert min(losses[-10:]) < losses[0] * 0.5


def test_backbone_frozen_during_train(pipeline):
    import jax
    before = jax.tree.leaves(pipeline.vfm.params)[0].copy()
    rng = np.random.RandomState(1)

    def data():
        while True:
            x = rng.randn(4, 64, 3).astype(np.float32)
            yield x, x[:, :1, 0]

    pipeline.train(data(), steps=3, lr=1e-2)
    after = jax.tree.leaves(pipeline.vfm.params)[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


def test_adapter_changes_output(pipeline):
    x = np.random.RandomState(2).randn(2, 64, 3).astype(np.float32)
    y_with = pipeline.run(x)
    state = pipeline.state["adapter"]
    pipeline.state["adapter"] = None
    y_without = pipeline.run(x)
    pipeline.state["adapter"] = state
    # adapter was trained above -> must affect outputs
    assert not np.allclose(np.asarray(y_with), np.asarray(y_without))


def test_artifact_roundtrip(pipeline):
    art = pipeline.package(weight=2.0, slo_s=0.5, demand_rps=3.0)
    blob = serialize(art)
    art2 = deserialize(blob)
    assert art2["meta"]["task_id"] == "hr"
    assert art2["meta"]["backbone"] == pipeline.vfm.cfg.name
    spec = task_spec(art)
    assert spec["weight"] == 2.0 and spec["demand_rps"] == 3.0
    # weights survive the wire format
    k = sorted(art["decoder_weights"])[0]
    np.testing.assert_allclose(art["decoder_weights"][k],
                               art2["decoder_weights"][k])
