"""Model-substrate correctness: decode-vs-full oracles, MoE dispatch, mamba &
xLSTM recurrence continuity, attention chunking invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import get_config, reduced
from repro.models import lm
from repro.models import moe as M
from repro.models.attention import flash_attention
from repro.models.common import init_params


def _decode_vs_full(cfg, tol):
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(jax.random.PRNGKey(1),
                                             (B, S, cfg.d_model))
    cache = lm.init_cache(cfg, B, S + 4)
    _, cache = lm.prefill(params, cfg, tokens=toks[:, :S], cache=cache, **kw)
    logits2, _ = lm.decode_step(params, cfg, tokens=toks[:, S], cache=cache)
    x, _, _ = lm.forward(params, cfg, tokens=toks[:, : S + 1], **kw)
    full = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                      params["head"].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(full - logits2))) < tol


@pytest.mark.parametrize("arch", ["qwen2-7b", "h2o-danube-1.8b", "stablelm-1.6b",
                                  "minitron-8b", "xlstm-125m", "whisper-base"])
def test_decode_matches_full_forward(arch):
    _decode_vs_full(reduced(get_config(arch)), tol=2e-1)  # bf16 activations


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "olmoe-1b-7b", "grok-1-314b"])
def test_decode_matches_full_forward_moe(arch, monkeypatch):
    # disable capacity drops so prefill/decode group sizes can't change routing
    monkeypatch.setattr(M, "capacity", lambda g, k, e, factor=1.25: g * k)
    _decode_vs_full(reduced(get_config(arch)), tol=2e-1)


@pytest.mark.parametrize("dispatch", ["gshard", "scatter"])
def test_moe_matches_dense_oracle(dispatch, monkeypatch):
    monkeypatch.setattr(M, "capacity", lambda g, k, e, factor=1.25: g * k)
    cfg = reduced(get_config("olmoe-1b-7b"))
    p = init_params(jax.random.PRNGKey(0), M.moe_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    out, aux = M.moe_ffn(p, x, k=cfg.experts_per_token, dispatch=dispatch)
    ref = M.moe_ref(p, x, k=cfg.experts_per_token)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
    assert float(aux) > 0


def test_moe_subgroup_invariance(monkeypatch):
    """Scanned subgroups must agree with one big group when nothing drops."""
    monkeypatch.setattr(M, "capacity", lambda g, k, e, factor=1.25: 4096)
    cfg = reduced(get_config("olmoe-1b-7b"))
    p = init_params(jax.random.PRNGKey(0), M.moe_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    a, _ = M.moe_ffn(p, x, k=2, subgroup=16)
    b, _ = M.moe_ffn(p, x, k=2, subgroup=4)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_flash_attention_chunk_invariance():
    B, Sq, Sk, H, KV, hd = 2, 32, 64, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, KV, hd))
    a = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    b = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=64)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_sliding_window_masks_past():
    """With window=w, keys older than w positions must not influence output."""
    B, S, H, hd = 1, 32, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    out1 = flash_attention(q, k, v, causal=True, window=4, q_chunk=8, kv_chunk=8)
    # perturb keys/values far in the past of the last query
    k2 = k.at[:, :16].set(jax.random.normal(jax.random.PRNGKey(3), (B, 16, H, hd)))
    v2 = v.at[:, :16].set(0.0)
    out2 = flash_attention(q, k2, v2, causal=True, window=4, q_chunk=8, kv_chunk=8)
    assert float(jnp.max(jnp.abs(out1[:, -1] - out2[:, -1]))) < 1e-6


def test_mamba_decode_continuity():
    """Prefill state then step-by-step decode == one long forward (exact)."""
    cfg = dataclasses.replace(reduced(get_config("jamba-v0.1-52b")),
                              num_experts=0, experts_per_token=0)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    cache = lm.init_cache(cfg, B, S)
    _, cache = lm.prefill(params, cfg, tokens=toks[:, :8], cache=cache)
    for t in range(8, S - 1):
        _, cache = lm.decode_step(params, cfg, tokens=toks[:, t], cache=cache)
    logits, _ = lm.decode_step(params, cfg, tokens=toks[:, S - 1], cache=cache)
    x, _, _ = lm.forward(params, cfg, tokens=toks)
    full = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                      params["head"].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(full - logits))) < 2e-1


def test_xlstm_long_decode_constant_state():
    """xLSTM decode state size is O(1) in sequence length (long_500k basis)."""
    cfg = reduced(get_config("xlstm-125m"))
    c1 = lm.cache_spec(cfg, batch=1, s_max=100)
    c2 = lm.cache_spec(cfg, batch=1, s_max=100000)
    sz = lambda c: sum(int(jnp.prod(jnp.asarray(s.shape))) for s in jax.tree.leaves(
        c, is_leaf=lambda x: hasattr(x, "shape")))
    from repro.models.common import param_count
    assert param_count(c1) == param_count(c2)
