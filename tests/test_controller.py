"""FMplex-Controller: Max-Share (Algorithm 1), elastic adaptation, failures."""
import pytest

from repro.controller import (ClusterState, ElasticAdapter, MaxShare, Server,
                              TaskSpec, get_profile)
from repro.core.profile import FMProfile


def cluster(n=4, mem=16e9, profiles=None):
    profiles = profiles or {"moment-large": get_profile("moment-large")}
    return ClusterState([Server(f"s{i}", mem_bytes=mem) for i in range(n)],
                        profiles)


def test_prefers_existing_backbone():
    c = cluster()
    ms = MaxShare(c)
    p1 = ms.place(TaskSpec("t0", "moment-large", demand_rps=5))
    p2 = ms.place(TaskSpec("t1", "moment-large", demand_rps=5))
    assert p1.new_deployments and not p2.new_deployments
    assert list(p2.assignment) == list(p1.assignment)   # same deployment reused


def test_provisions_when_capacity_exhausted():
    c = cluster()
    ms = MaxShare(c)
    cap = get_profile("moment-large")
    cap_rps = 0.8 * cap.b_max / cap.l(cap.b_max)
    ms.place(TaskSpec("big", "moment-large", demand_rps=cap_rps * 0.9))
    plan = ms.place(TaskSpec("t1", "moment-large", demand_rps=cap_rps * 0.5))
    assert plan is not None and plan.new_deployments   # had to provision


def test_replication_splits_demand():
    c = cluster()
    ms = MaxShare(c)
    cap = get_profile("moment-large")
    cap_rps = 0.8 * cap.b_max / cap.l(cap.b_max)
    plan = ms.place(TaskSpec("huge", "moment-large", demand_rps=cap_rps * 2.5))
    assert plan is not None and len(plan.assignment) >= 3
    assert sum(plan.assignment.values()) == pytest.approx(1.0)


def test_infeasible_returns_none_and_rolls_back():
    prof = FMProfile("big-fm", memory_bytes=int(20e9))   # > server memory
    c = cluster(profiles={"big-fm": prof})
    ms = MaxShare(c)
    assert ms.place(TaskSpec("t", "big-fm")) is None
    assert not c.deployments


def test_memory_admission_limits_instance_per_task():
    """Instance-per-task (no sharing) OOMs where sharing admits ~6x more."""
    prof = get_profile("moment-large")
    c = cluster(n=1)
    per_gpu_replicas = int(16e9 // prof.memory_bytes)
    # sharing: one deployment hosts many tasks
    ms = MaxShare(c)
    admitted = 0
    for i in range(60):
        if ms.place(TaskSpec(f"t{i}", "moment-large", demand_rps=1.0)):
            admitted += 1
    assert admitted >= 6 * per_gpu_replicas


def test_adaptation_rebind_is_fast_path():
    c = cluster()
    ms = MaxShare(c)
    for i in range(3):
        ms.place(TaskSpec(f"t{i}", "moment-large", demand_rps=5))
    ea = ElasticAdapter(c)
    res = ea.on_surge(TaskSpec("t0", "moment-large", demand_rps=5), 10.0)
    assert res.path == "rebind"
    assert res.ready_s < 0.1                       # task-state timescale
    res2 = ea.on_surge(TaskSpec("t1", "moment-large", demand_rps=5), 500.0)
    assert res2.path in ("provision", "infeasible")
    if res2.path == "provision":
        assert res2.ready_s > 1.0                  # backbone-load timescale


def test_failure_rebinds_all_tasks():
    c = cluster()
    ms = MaxShare(c)
    for i in range(6):
        ms.place(TaskSpec(f"t{i}", "moment-large", demand_rps=5))
    ea = ElasticAdapter(c)
    dead = [d.server_id for d in c.deployments.values()][0]
    results = ea.on_server_failure(dead)
    assert results and all(r.path in ("rebind", "provision") for r in results)
    for t in [f"t{i}" for i in range(6)]:
        assert t in c.task_bindings
        for dep_id in c.task_bindings[t]:
            assert c.deployments[dep_id].server_id != dead
