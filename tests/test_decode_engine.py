"""Continuous-batching decode serving: segmented-vs-gather decode parity,
int8-KV pool tolerance, zero-recompile (and zero-host-sort) steady state
across request join/leave churn, variable-length bucketed admission,
temperature/top-k sampling, int8 scale-drift bounds, vectorized SGMV host
prep, and on-device per-task head application."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.executor import Executor
from repro.core.physical import PhysicalFM
from repro.core.request import Batch, Request
from repro.kernels import ops
from repro.kernels.segmented_lora import padded_tokens, segment_metadata
from repro.models import lm

BT = 8


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("stablelm-1.6b"))


def _randomized_adapter(fm, i):
    tree = fm.adapters._mod.init_single_adapter(
        jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
    leaves, tdef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
    return jax.tree.unflatten(tdef, [
        jax.random.normal(k, l.shape, l.dtype) * 0.05
        for k, l in zip(ks, leaves)])


def _fm(cfg, impl="segmented", na=3):
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4, lora_impl=impl,
                    seg_block_t=BT)
    for i in range(na):
        fm.adapters.add(f"lora{i}", _randomized_adapter(fm, i))
    return fm


# ---------------- decode-path parity (lm level, teacher-forced) ----------------

def test_decode_segmented_matches_gather_over_steps(cfg):
    """≥ 8 decode steps, mixed adapters + base-model sentinel row; the S=1
    segment metadata is built ONCE and reused every step (the engine's
    steady-state contract) and must match the gather path step for step."""
    fm = _fm(cfg)
    params, stack = fm.params, fm.adapters.stacked()
    cap = fm.adapters.capacity()
    B, S, steps = 5, 8, 9
    aidx = np.array([0, 2, cap, 1, 0], np.int32)        # cap == no adapter
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S + steps), 0,
                              cfg.vocab_size)
    caches = {}
    for impl in ("gather", "segmented"):
        seg = None
        if impl == "segmented":
            perm, inv, blocks = fm.segment_meta(aidx, cap, 1)
            seg = {"perm": jnp.asarray(perm), "inv": jnp.asarray(inv),
                   "block_adapter": jnp.asarray(blocks), "block_t": BT}
        cache = lm.init_cache(cfg, B, S + steps + 1)
        _, cache = lm.prefill(params, cfg, tokens=toks[:, :S], cache=cache,
                              lora=stack, adapter_idx=jnp.asarray(aidx),
                              lora_impl="gather")
        caches[impl] = (cache, seg)
    for t in range(steps):                              # teacher-forced
        outs = {}
        for impl in ("gather", "segmented"):
            cache, seg = caches[impl]
            logits, cache = lm.decode_step(
                params, cfg, tokens=toks[:, S + t], cache=cache, lora=stack,
                adapter_idx=jnp.asarray(aidx), lora_impl=impl, lora_seg=seg)
            caches[impl] = (cache, seg)
            outs[impl] = np.asarray(logits)
        np.testing.assert_allclose(outs["segmented"], outs["gather"],
                                   atol=2e-2)


# ---------------- int8 KV pool ----------------

def test_quantize_kv_roundtrip_error_bound():
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 3, 8)) * 2.0
    v = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 3, 8))
    kq, vq, ks, vs = ops.quantize_kv(k, v)
    assert kq.dtype == jnp.int8 and vq.dtype == jnp.int8
    for x, xq, s in ((k, kq, ks), (v, vq, vs)):
        deq = np.asarray(xq, np.float32) * np.asarray(s)[:, None, :, None]
        # symmetric int8: per-element error bounded by scale/2
        err = np.abs(deq - np.asarray(x, np.float32))
        bound = np.asarray(s)[:, None, :, None] / 2 + 1e-6
        assert (err <= bound).all()


def test_int8_kv_decode_close_to_fp(cfg):
    """Prefill + several decode steps on an int8-quantized KV pool stay
    within quantization tolerance of the bf16-cache decode path."""
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S, steps = 3, 8, 5
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + steps), 0,
                              cfg.vocab_size)
    c_fp = lm.init_cache(cfg, B, S + steps + 1)
    c_q8 = lm.init_cache(cfg, B, S + steps + 1, kv_quant=True)
    assert jax.tree.leaves(c_q8)[0].dtype != jax.tree.leaves(c_fp)[0].dtype
    lg_fp, c_fp = lm.prefill(params, cfg, tokens=toks[:, :S], cache=c_fp)
    lg_q8, c_q8 = lm.prefill(params, cfg, tokens=toks[:, :S], cache=c_q8)
    # prefill logits come from the forward pass, before the cache is read
    np.testing.assert_allclose(np.asarray(lg_q8), np.asarray(lg_fp), atol=1e-5)
    for t in range(steps):
        lg_fp, c_fp = lm.decode_step(params, cfg, tokens=toks[:, S + t],
                                     cache=c_fp)
        lg_q8, c_q8 = lm.decode_step(params, cfg, tokens=toks[:, S + t],
                                     cache=c_q8)
        d, ref = np.asarray(lg_q8 - lg_fp), np.asarray(lg_fp)
        assert np.abs(d).max() < 1.0                    # absolute ceiling
        assert np.linalg.norm(d) / np.linalg.norm(ref) < 0.25


# ---------------- the engine ----------------

def test_engine_segmented_matches_gather_tokens(cfg):
    """Greedy token streams agree between the segmented and gather decode
    engines (both on the int8 pool — isolates the LoRA impl), with mixed
    adapters and a base-model request."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 8) for _ in range(4)]
    adapters = ["lora0", "lora2", None, "lora1"]
    outs = {}
    for impl in ("segmented", "gather"):
        eng = DecodeEngine(_fm(cfg, impl), num_slots=4, prompt_len=8,
                           max_new=8, chunk=2)
        for i, p in enumerate(prompts):
            eng.join(f"t{i}", p, adapter_id=adapters[i], max_new_tokens=8,
                     rid=i)
        done = sorted(eng.drain(), key=lambda s: s.rid)
        assert all(len(d.tokens) == 8 for d in done)
        outs[impl] = [d.tokens for d in done]
    assert outs["segmented"] == outs["gather"]


def test_engine_zero_recompiles_and_sorts_across_churn(cfg):
    """Requests joining/leaving slots between chunks (with changing adapter
    assignments and variable lengths) must add ZERO jitted executables, and
    a previously-seen batch composition must trigger ZERO host-side sorts."""
    fm = _fm(cfg)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=8, max_new=8, chunk=2)
    rng = np.random.RandomState(1)
    prompts = rng.randint(0, cfg.vocab_size, (8, 8)).astype(np.int32)
    names = ["lora0", "lora1", "lora2", None]
    eng.join("warm", prompts[0], adapter_id="lora0", max_new_tokens=2, rid=-1)
    eng.drain()                                     # compile all executables
    compiles = eng.compile_count()
    for i in range(4):          # variable lengths -> staggered retirement
        eng.join(f"t{i}", prompts[i], adapter_id=names[i],
                 max_new_tokens=3 + i, rid=i)
    finished = []
    while eng.active_count():
        finished += eng.step_chunk()
        # continuous batching: refill freed slots mid-flight
        while eng.free_slots() and len(finished) + eng.active_count() < 6:
            j = len(finished) + eng.active_count()
            eng.join(f"t{j}", prompts[j], adapter_id=names[j % 4],
                     max_new_tokens=4, rid=j)
    assert len(finished) == 6
    assert all(len(s.tokens) == s.max_new for s in finished)
    assert eng.compile_count() == compiles          # zero recompiles in churn
    # identical passes: uniform lengths so both traverse the same
    # compositions; the second pass must trigger ZERO host-side sorts
    for r in range(2):
        if r == 1:
            builds = fm.seg_meta_cache.builds
        for i in range(4):
            eng.join(f"p{r}-{i}", prompts[i], adapter_id=names[i],
                     max_new_tokens=4, rid=100 + i)
        eng.drain()
    assert fm.seg_meta_cache.builds == builds       # zero host sorts
    assert eng.compile_count() == compiles


def test_engine_first_token_and_slot_reuse(cfg):
    fm = _fm(cfg)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=4, chunk=2)
    p = np.arange(8) % cfg.vocab_size
    s0 = eng.join("a", p, adapter_id="lora0", max_new_tokens=1, rid=0)
    assert eng.slots[s0].done                       # budget met at prefill
    done = eng.step_chunk()                         # retires without decoding
    assert [d.rid for d in done] == [0] and len(done[0].tokens) == 1
    assert eng.free_slots() == [0, 1]
    s1 = eng.join("b", p, adapter_id="lora1", max_new_tokens=4, rid=1)
    assert s1 == 0                                  # slot recycled
    (d,) = eng.drain()
    assert len(d.tokens) == 4 and d.t_first <= d.t_join + 10


# ---------------- variable-length bucketed admission ----------------

def _greedy_reference(fm, prompt, steps, s_max):
    """Exact-length (unpadded) prefill + greedy decode on an int8 cache —
    the oracle a bucketed right-padded admission must match token-for-token."""
    cfg = fm.cfg
    cap = fm.adapters.capacity()
    ai = jnp.full((1,), cap, jnp.int32)
    cache = lm.init_cache(cfg, 1, s_max, kv_quant=True)
    lg, cache = lm.prefill(fm.params, cfg, tokens=jnp.asarray(prompt[None]),
                           cache=cache, lora=fm.adapters.stacked(),
                           adapter_idx=ai, lora_impl="gather")
    toks = [int(jnp.argmax(lg, -1)[0])]
    for _ in range(steps - 1):
        lg, cache = lm.decode_step(
            fm.params, cfg, tokens=jnp.asarray([toks[-1]], jnp.int32),
            cache=cache, lora=fm.adapters.stacked(), adapter_idx=ai,
            lora_impl="gather")
        toks.append(int(jnp.argmax(lg, -1)[0]))
    return toks


def test_variable_length_admission_matches_exact_prefill(cfg):
    """A short prompt admitted into a larger bucket (right-padded, true
    length masked) must produce the SAME token stream as an exact-length
    unpadded prefill: pads are invisible to attention, the cache len, the
    rope positions, and the int8 admission scales."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=16, max_new=8, chunk=2)
    assert eng.prompt_buckets == (4, 8, 16)
    rng = np.random.RandomState(7)
    for plen in (3, 5, 11):                     # buckets 4, 8, 16
        p = rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
        eng.join("t", p, max_new_tokens=6, rid=0)
        (d,) = eng.drain()
        assert d.tokens == _greedy_reference(fm, p, 6, eng.s_max)


def test_prompt_buckets_zero_recompiles_across_lengths(cfg):
    """After one warm join per bucket, admission of ANY prompt length within
    the largest bucket — across join/leave churn — adds zero executables:
    the true length is a traced operand, only the bucket is a jit key."""
    fm = _fm(cfg)
    eng = DecodeEngine(fm, num_slots=4, prompt_len=16, max_new=6, chunk=2,
                       prompt_buckets=(4, 16))
    rng = np.random.RandomState(3)
    for plen in (4, 16):                        # warm each bucket once
        eng.join("w", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
    eng.drain()
    compiles = eng.compile_count()
    names = ["lora0", "lora1", None, "lora2"]
    for i, plen in enumerate((1, 3, 7, 9, 13, 16, 2, 11)):
        eng.join(f"t{i}", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id=names[i % 4], max_new_tokens=2 + i % 3, rid=i)
        if not eng.free_slots():
            eng.step_chunk()
    done = eng.drain()
    assert eng.compile_count() == compiles
    assert len(eng._jit_prefill) == 2           # one executable per bucket


def test_join_warns_on_truncation(cfg):
    """Prompts longer than the largest admission bucket lose context;
    that must be loud (satellite: fix the silent left-truncation)."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=4, chunk=2)
    rng = np.random.RandomState(0)
    long = rng.randint(0, cfg.vocab_size, 23).astype(np.int32)
    with pytest.warns(RuntimeWarning, match="left-truncating"):
        eng.join("t", long, max_new_tokens=3, rid=0)
    (d,) = eng.drain()
    # suffix semantics: same stream as admitting the last prompt_len tokens
    eng.join("t", long[-8:], max_new_tokens=3, rid=1)
    (d2,) = eng.drain()
    assert d.tokens == d2.tokens


# ---------------- temperature / top-k sampling ----------------

def test_sampling_topk1_is_greedy_and_seed_reproducible(cfg):
    """top_k=1 at any temperature must reproduce the greedy stream (the
    categorical collapses to the argmax); equal seeds reproduce, different
    seeds explore."""
    fm = _fm(cfg, na=1)
    rng = np.random.RandomState(11)
    p = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)

    def stream(**kw):
        eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=8, chunk=4,
                           **kw)
        eng.join("t", p, adapter_id="lora0", max_new_tokens=8, rid=0)
        (d,) = eng.drain()
        return d.tokens

    greedy = stream()
    assert stream(temperature=0.7, top_k=1) == greedy
    s1 = stream(temperature=1.5, top_k=8, sample_seed=1)
    s2 = stream(temperature=1.5, top_k=8, sample_seed=1)
    s3 = stream(temperature=1.5, top_k=8, sample_seed=2)
    assert s1 == s2                             # per-slot PRNG state is exact
    assert s1 != greedy or s3 != greedy         # temperature actually samples


def test_sampling_streams_independent_across_slots(cfg):
    """Co-batched sampled streams use per-slot keys: the same prompt in two
    slots of one chunked scan must not produce correlated tokens."""
    fm = _fm(cfg, na=1)
    eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=12, chunk=4,
                       temperature=2.0, top_k=16, sample_seed=5)
    p = np.arange(8).astype(np.int32) % cfg.vocab_size
    eng.join("a", p, max_new_tokens=12, rid=0)
    eng.join("b", p, max_new_tokens=12, rid=1)
    a, b = sorted(eng.drain(), key=lambda s: s.rid)
    assert a.tokens != b.tokens


# ---------------- int8 KV scale drift ----------------

def test_int8_scale_drift_bounded():
    """Dense-pool scales are FIXED at prefill admission; decode-era K/V
    outside the prompt-era range get clipped. Drive the decode tail to 3x
    the admission magnitude and assert the attention output's divergence
    from the fp path stays bounded (the limit documented in
    ``core.decode_engine``) — then show the layout the PAGED pool's
    proactive refresh CONVERGES to (drifted tail pages stamped at the
    refreshed per-(page, kv-head) range as they are written) holds the
    no-drift tolerance where the clipped path degrades ~10x. This is the
    steady-state bound: tokens clipped BEFORE the drift first crosses the
    refresh threshold stay clipped (int8 codes cannot be un-clipped), so a
    live stream lands between the two curves during the transient."""
    from repro.kernels import ops, ref
    from repro.models.attention import decode_attention
    rng = np.random.RandomState(0)
    B, S_p, S_d, KV, hd = 2, 16, 48, 2, 8
    S = S_p + S_d
    k_p = rng.randn(B, S_p, KV, hd).astype(np.float32)
    v_p = rng.randn(B, S_p, KV, hd).astype(np.float32)
    kq, vq, ks, vs = ops.quantize_kv(jnp.asarray(k_p), jnp.asarray(v_p))
    ks, vs = np.asarray(ks), np.asarray(vs)
    rels = {}
    for drift, bound in ((1.0, 0.06), (3.0, 0.85)):
        # decode-era tail at drift× the prompt magnitude, quantized with the
        # ADMISSION-ERA scales exactly as self_attention_decode does
        k_d = rng.randn(B, S_d, KV, hd).astype(np.float32) * drift
        v_d = rng.randn(B, S_d, KV, hd).astype(np.float32) * drift
        kq_d = np.clip(np.round(k_d / ks[:, None, :, None]), -127, 127)
        vq_d = np.clip(np.round(v_d / vs[:, None, :, None]), -127, 127)
        k_all = np.concatenate([np.asarray(kq), kq_d], 1).astype(np.int8)
        v_all = np.concatenate([np.asarray(vq), vq_d], 1).astype(np.int8)
        q = rng.randn(B, 4, hd).astype(np.float32)
        lens = np.full((B,), S, np.int32)
        o_q8 = np.asarray(ops.decode_attention_int8(
            jnp.asarray(q), jnp.asarray(k_all), jnp.asarray(v_all),
            jnp.asarray(ks), jnp.asarray(vs), jnp.asarray(lens)))
        o_fp = np.asarray(decode_attention(
            jnp.asarray(q), jnp.asarray(np.concatenate([k_p, k_d], 1)),
            jnp.asarray(np.concatenate([v_p, v_d], 1)), jnp.asarray(lens)))
        rel = np.linalg.norm(o_q8 - o_fp) / np.linalg.norm(o_fp)
        assert rel < bound, (drift, rel)
        rels[drift] = rel

        # REFRESHED path: lay the same stream out as pages (the paged pool
        # layout) with prompt pages at the admission scale and tail pages
        # re-quantized at the drifted range — what the engine's proactive
        # refresh stamps via the per-(page, kv-head) scale storage
        ps = 16
        P = B * (S // ps) + 1
        kp_pages = np.zeros((P, KV, ps, hd), np.int8)
        vp_pages = np.zeros((P, KV, ps, hd), np.int8)
        pks = np.zeros((P, KV), np.float32)
        pvs = np.zeros((P, KV), np.float32)
        ptab = np.zeros((B, S // ps), np.int32)
        nxt = 1
        for b in range(B):
            k_row = np.concatenate([k_p[b], k_d[b]], 0)     # (S, KV, hd)
            v_row = np.concatenate([v_p[b], v_d[b]], 0)
            for j in range(S // ps):
                kpg = k_row[j * ps:(j + 1) * ps].transpose(1, 0, 2)
                vpg = v_row[j * ps:(j + 1) * ps].transpose(1, 0, 2)
                if j * ps < S_p:                # prompt page: admission scale
                    ksc, vsc = ks[b], vs[b]
                else:                           # tail page: refreshed scale
                    ksc = np.abs(kpg).max(axis=(1, 2)) / 127.0
                    vsc = np.abs(vpg).max(axis=(1, 2)) / 127.0
                kp_pages[nxt] = np.clip(np.round(
                    kpg / np.maximum(ksc, 1e-8)[:, None, None]), -127, 127)
                vp_pages[nxt] = np.clip(np.round(
                    vpg / np.maximum(vsc, 1e-8)[:, None, None]), -127, 127)
                pks[nxt], pvs[nxt] = ksc, vsc
                ptab[b, j] = nxt
                nxt += 1
        o_rf = np.asarray(ref.paged_decode_attention_ref(
            jnp.asarray(q), jnp.asarray(kp_pages), jnp.asarray(vp_pages),
            jnp.asarray(pks), jnp.asarray(pvs), jnp.asarray(ptab),
            jnp.asarray(lens)))
        rel_rf = np.linalg.norm(o_rf - o_fp) / np.linalg.norm(o_fp)
        assert rel_rf < 0.1, (drift, rel_rf)    # no-drift tolerance, always
    assert rels[3.0] > 5 * rels[1.0]            # the gap refresh closes


def test_int8_long_decode_divergence_bounded(cfg):
    """Model-level guard: a decode 4x longer than the prompt on the int8
    pool stays within bounded relative divergence of the fp-cache path
    (scales never refresh — the engine's documented limit)."""
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    B, S, steps = 2, 8, 32
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    c_fp = lm.init_cache(cfg, B, S + steps + 1)
    c_q8 = lm.init_cache(cfg, B, S + steps + 1, kv_quant=True)
    lg_fp, c_fp = lm.prefill(params, cfg, tokens=toks, cache=c_fp)
    lg_q8, c_q8 = lm.prefill(params, cfg, tokens=toks, cache=c_q8)
    t_fp = t_q8 = jnp.argmax(lg_fp, -1).astype(jnp.int32)
    worst = 0.0
    for _ in range(steps):                      # teacher-force on the fp path
        lg_fp, c_fp = lm.decode_step(params, cfg, tokens=t_fp, cache=c_fp)
        lg_q8, c_q8 = lm.decode_step(params, cfg, tokens=t_fp, cache=c_q8)
        t_fp = jnp.argmax(lg_fp, -1).astype(jnp.int32)
        d = np.asarray(lg_q8 - lg_fp)
        worst = max(worst, float(np.linalg.norm(d) /
                                 np.linalg.norm(np.asarray(lg_fp))))
    assert worst < 0.5, worst                   # documented drift ceiling


# ---------------- vectorized host prep ----------------

def test_sort_by_adapter_vectorized_matches_loop_reference():
    from repro.kernels.segmented_lora import sort_by_adapter

    def loop_reference(ids, num_adapters, block_t, max_tokens):
        ids = np.asarray(ids)
        order = np.argsort(ids, kind="stable")
        segs, blocks = [], []
        for aid in np.unique(ids):
            idx = order[ids[order] == aid]
            pad = (-len(idx)) % block_t
            segs.append((idx, pad))
            blocks += [int(aid)] * ((len(idx) + pad) // block_t)
        perm = []
        for idx, pad in segs:
            perm += list(idx) + [-1] * pad
        total = len(perm)
        if max_tokens is not None:
            blocks += [num_adapters] * ((max_tokens - total) // block_t)
            perm += [-1] * (max_tokens - total)
            total = max_tokens
        return np.array(perm, np.int32), np.array(blocks, np.int32), total

    rng = np.random.RandomState(0)
    for trial in range(20):
        n = rng.randint(1, 200)
        na = rng.randint(1, 9)
        bt = int(rng.choice([4, 8, 16]))
        ids = rng.randint(0, na + 1, n)             # includes the sentinel
        tp = padded_tokens(n, min(n, na + 2), bt)
        for mt in (None, tp):
            got = sort_by_adapter(ids, na, block_t=bt, max_tokens=mt)
            want = loop_reference(ids, na, bt, mt)
            np.testing.assert_array_equal(got[0], want[0])
            np.testing.assert_array_equal(got[1], want[1])
            assert got[2] == want[2]


def test_segment_meta_cache_memoizes():
    fm_cache = __import__("repro.kernels.segmented_lora",
                          fromlist=["SegmentMetaCache"]).SegmentMetaCache()
    ids = np.array([0, 1, 0, 2], np.int32)
    a = fm_cache.get(ids, 3, 8, 64)
    b = fm_cache.get(ids.copy(), 3, 8, 64)
    assert fm_cache.builds == 1 and a is b
    fm_cache.get(np.array([1, 1, 0, 2], np.int32), 3, 8, 64)
    assert fm_cache.builds == 2


# ---------------- on-device per-task heads ----------------

def _pooled_batch(fm, n, task_id="t0"):
    rng = np.random.RandomState(3)
    reqs = [Request(task_id, 0.0,
                    payload=rng.randn(fm.input_len,
                                      fm.cfg.d_model).astype(np.float32))
            for _ in range(n)]
    return Batch(reqs, [(None, reqs)])


def test_executor_runs_traceable_head_on_device():
    cfg = reduced(get_config("moment-large"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    w = np.random.RandomState(0).randn(cfg.d_model, 3).astype(np.float32) * 0.1
    fm.attach_head("t0", lambda f: f @ w)
    ex = Executor(fm)
    batch = _pooled_batch(fm, 3)
    out = ex.execute(batch, {})
    assert ex._head_mode["t0"][1] == "device" and "t0" in ex._head_jit
    feats = fm.run_batch(np.stack([r.payload for r in batch.requests]),
                         np.full(3, fm.adapters.capacity(), np.int32))
    for i, r in enumerate(batch.requests):
        np.testing.assert_allclose(np.asarray(out[r.rid]), feats[i] @ w,
                                   atol=1e-4)


def test_executor_untraceable_head_falls_back():
    cfg = reduced(get_config("moment-large"))
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4)
    w = np.random.RandomState(0).randn(cfg.d_model, 2).astype(np.float32)

    def head(f):                # jit-hostile: forces concrete numpy values
        return np.ascontiguousarray(f) @ w

    fm.attach_head("t0", head)
    ex = Executor(fm)
    out = ex.execute(_pooled_batch(fm, 3), {})
    assert ex._head_mode["t0"][1] in ("batched", "row")
    assert "t0" not in ex._head_jit
    assert all(np.asarray(v).shape == (2,) for v in out.values())
