"""Self-speculative decoding: verify-window kernel parity, bit-exact greedy
parity vs the plain engine over ragged churn (join/leave/preempt/spill-
resume), zero-accept == plain-step equivalence, device-length rollback
invariant, zero steady-state recompiles across accept swings (adaptive
demotion included), committed-token charge accounting, and sampled-mode
sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM
from repro.kernels import ops, ref

BT = 8


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("stablelm-1.6b"))


def _randomized_adapter(fm, i):
    tree = fm.adapters._mod.init_single_adapter(
        jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
    leaves, tdef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(100 + i), len(leaves))
    return jax.tree.unflatten(tdef, [
        jax.random.normal(k, l.shape, l.dtype) * 0.05
        for k, l in zip(ks, leaves)])


def _fm(cfg, impl="segmented", na=2):
    fm = PhysicalFM(cfg, seed=0, input_len=8, lora_rank=4, lora_impl=impl,
                    seg_block_t=BT)
    for i in range(na):
        fm.adapters.add(f"lora{i}", _randomized_adapter(fm, i))
    return fm


def _copy_inclined(fm):
    """Zero every attention out-projection: logits then depend only on the
    current token, the greedy chain becomes a deterministic bigram machine
    that cycles (pigeonhole over a finite vocab), and the prompt-lookup
    drafter's bigram matches start accepting. Random-weight reduced models
    never self-overlap, so this is the accept-heavy regime's test double."""
    fm.params = jax.tree_util.tree_map_with_path(
        lambda path, l: l * 0.0
        if any(getattr(k, "key", None) == "wo" for k in path) else l,
        fm.params)
    return fm


def _engine(fm, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("prompt_len", 16)
    kw.setdefault("max_new", 24)
    kw.setdefault("chunk", 4)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    kw.setdefault("total_pages", 48)
    kw.setdefault("prompt_buckets", (8, 16))
    return DecodeEngine(fm, **kw)


def _streams(eng_or_done):
    done = eng_or_done.drain() if isinstance(eng_or_done, DecodeEngine) \
        else eng_or_done
    return {d.rid: list(d.tokens) for d in done}


# ---------------- verify-window kernel parity ----------------

def _verify_case(seed=0, B=3, T=5, H=8, KV=2, hd=16, ps=8, P=11, MP=5,
                 lens=(9, 23, 1)):
    """Head-major arena + page tables sized so every row holds its
    base_len + T window positions (speculative writes land above len)."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32))
    kp = jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8))
    vp = jnp.asarray(rng.randint(-127, 128, (P, KV, ps, hd)).astype(np.int8))
    ks = jnp.asarray(rng.rand(P, KV).astype(np.float32) * 0.05 + 1e-3)
    vs = jnp.asarray(rng.rand(P, KV).astype(np.float32) * 0.05 + 1e-3)
    pt = np.zeros((B, MP), np.int32)
    free = list(range(1, P))
    rng.shuffle(free)
    for b in range(B):
        for j in range(-(-(int(lens[b]) + T) // ps)):
            pt[b, j] = free.pop()
    return q, kp, vp, ks, vs, jnp.asarray(pt), jnp.asarray(
        np.asarray(lens, np.int32))


@pytest.mark.parametrize("window", [None, 6])
def test_verify_attention_matches_unrolled_ref(window):
    """The fused one-gather XLA verify path must match T independent
    single-token paged decode reads at successive lengths (the oracle) —
    only matmul batching may separate them."""
    q, kp, vp, ks, vs, pt, base = _verify_case()
    want = ref.paged_verify_attention_ref(q, kp, vp, ks, vs, pt, base,
                                          window=window)
    got = ops.paged_verify_attention(
        q, kp.transpose(0, 2, 1, 3), vp.transpose(0, 2, 1, 3), ks, vs, pt,
        base, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_verify_attention_pallas_fallback_matches_ref():
    """The Pallas backend (unrolled per-position kernel calls, interpret
    mode on CPU) agrees with the oracle too."""
    q, kp, vp, ks, vs, pt, base = _verify_case(seed=3, T=3)
    want = ref.paged_verify_attention_ref(q, kp, vp, ks, vs, pt, base)
    got = ops.paged_verify_attention(
        q, kp.transpose(0, 2, 1, 3), vp.transpose(0, 2, 1, 3), ks, vs, pt,
        base, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------- greedy parity vs the plain engine ----------------

def test_spec_greedy_parity_zero_accept(cfg):
    """Random weights never self-overlap, so every draft misses and every
    speculative step commits exactly one token — the streams must be
    bit-identical to a plain engine's, and the counters must show real
    proposals with zero accepts."""
    fm = _fm(cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 16, 12, 5)]
    plain = _engine(fm, spec_k=0)
    for i, p in enumerate(prompts):
        plain.join(f"t{i}", p, adapter_id=["lora0", None][i % 2],
                   max_new_tokens=10 + i, rid=i)
    want = _streams(plain)

    spec = _engine(fm, spec_k=4)
    for i, p in enumerate(prompts):
        spec.join(f"t{i}", p, adapter_id=["lora0", None][i % 2],
                  max_new_tokens=10 + i, rid=i)
    got = _streams(spec)
    assert got == want
    assert spec.spec_dispatches >= 1
    assert spec.draft_proposed >= 0 and spec.draft_accepted == 0


def test_spec_force_fill_equals_plain(cfg):
    """``spec_force_fill`` replaces every draft with the out-of-vocab
    sentinel, so acceptance is structurally impossible — the zero-accept
    knob. Output must equal the plain engine's exactly even on an
    accept-heavy (copy-inclined) model."""
    fm = _copy_inclined(_fm(cfg))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, 11).astype(np.int32)
               for _ in range(3)]
    plain = _engine(fm, spec_k=0)
    for i, p in enumerate(prompts):
        plain.join(f"t{i}", p, max_new_tokens=12, rid=i)
    want = _streams(plain)

    spec = _engine(fm, spec_k=3, spec_force_fill=True,
                   spec_disable_below=1.0)      # never demote: all spec steps
    for i, p in enumerate(prompts):
        spec.join(f"t{i}", p, max_new_tokens=12, rid=i)
    got = _streams(spec)
    assert got == want
    assert spec.draft_accepted == 0 and spec.spec_dispatches >= 1


def _dev_lens_match(eng):
    """KV rollback invariant: after every chunk the device length tracker of
    each live slot equals the host's committed length — a partial accept
    rolled ``len`` (and the int8 scale trackers) back rather than leaving
    speculatively-written positions visible."""
    for sub in eng.pool:
        if isinstance(sub, dict) and "page_table" in sub:
            dev = np.asarray(sub["len"])
            for s, st in enumerate(eng.slots):
                if st is not None and not st.done:
                    assert (dev[:, s] == int(eng._lens[s])).all(), \
                        (s, dev[:, s], eng._lens[s])


def test_spec_greedy_parity_accept_heavy_churn(cfg):
    """The load-bearing parity claim: on a copy-inclined model (accepts
    actually fire, rollback actually runs) a speculative engine driven
    through ragged churn — staggered budgets, mid-flight joins, a
    preemption that spills D2H mid-speculation and resumes — produces
    BIT-IDENTICAL greedy streams to the plain engine, while the device
    length tracker never drifts from the host's committed view."""
    fm = _copy_inclined(_fm(cfg))
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 16, 6, 13, 8, 15)]
    budgets = [20, 9, 16, 24, 11, 18]

    def drive(spec_k):
        eng = _engine(fm, spec_k=spec_k, spec_disable_below=1.0,
                      spill_bytes=32 << 20)
        for i in (0, 1):
            eng.join(f"t{i}", prompts[i], adapter_id="lora0",
                     max_new_tokens=budgets[i], rid=i)
        done, nxt, steps = [], 2, 0
        while eng.active_count() or eng.pending or nxt < len(prompts):
            done += eng.step_chunk()
            steps += 1
            if spec_k:
                _dev_lens_match(eng)
            if steps == 2:          # preempt mid-speculation (spills D2H)
                live = [i for i, s in enumerate(eng.slots)
                        if s is not None and not s.done]
                if live:
                    eng._preempt(live[0])
            while nxt < len(prompts) and eng.free_slots() \
                    and not eng.pending:
                eng.join(f"t{nxt}", prompts[nxt],
                         adapter_id=[None, "lora1"][nxt % 2],
                         max_new_tokens=budgets[nxt], rid=nxt)
                nxt += 1
        return _streams(done), eng

    want, _ = drive(0)
    got, spec = drive(4)
    assert set(got) == set(range(len(prompts)))
    assert all(len(got[i]) == budgets[i] for i in got)
    assert got == want
    # accepts really fired (the whole point of the copy-inclined double)
    assert spec.draft_accepted > 0
    rates = spec.spec_task_accept_rates()
    assert rates and max(rates.values()) > 0.5


# ---------------- steady state: zero recompiles across accept swings ------

def test_spec_zero_recompiles_across_accept_swings(cfg):
    """After warming the plain ladder AND the speculative ladder, serving
    must add ZERO executables no matter how the accept rate swings — here
    a random-weight model drives the rate to zero, the EMA demotes to plain
    dispatches and periodically probes speculation again, so both executable
    families (and the demotion boundary between them) are exercised."""
    fm = _fm(cfg)
    eng = _engine(fm, spec_k=4, spec_probe_every=4)
    rng = np.random.RandomState(3)
    # compile both prefill buckets, then both decode ladders
    eng.join("w", rng.randint(0, cfg.vocab_size, 6), max_new_tokens=2, rid=-1)
    eng.join("w", rng.randint(0, cfg.vocab_size, 14), adapter_id="lora0",
             max_new_tokens=2, rid=-1)
    eng.drain()
    eng.warm_decode_ladder()
    eng.warm_speculative()
    compiles = eng.compile_count()

    done, nxt = [], 0
    prompts = [rng.randint(0, cfg.vocab_size, 5 + (i * 3) % 11)
               for i in range(8)]
    while len(done) < len(prompts):
        while nxt < len(prompts) and eng.free_slots() and not eng.pending:
            eng.join(f"t{nxt}", prompts[nxt],
                     adapter_id=[None, "lora1"][nxt % 2],
                     max_new_tokens=6 + nxt % 5, rid=nxt)
            nxt += 1
        done += eng.step_chunk()
    assert eng.compile_count() == compiles
    # both regimes ran: speculative dispatches AND demoted plain dispatches
    assert eng.spec_dispatches >= 1 and eng.spec_fallbacks >= 1


# ---------------- accounting + sampled mode ----------------

def test_spec_decode_charges_follow_committed_tokens(cfg):
    """The per-(task, rid) charge log prices the work each stream's chunks
    actually committed: the totals drain once, are keyed by rid, and cover
    at least every token the engine kept."""
    fm = _fm(cfg)
    eng = _engine(fm, spec_k=2)
    rng = np.random.RandomState(4)
    eng.join("A", rng.randint(0, cfg.vocab_size, 9), max_new_tokens=8, rid=1)
    eng.join("B", rng.randint(0, cfg.vocab_size, 12), max_new_tokens=14,
             rid=2)
    done = _streams(eng)
    charges = eng.take_decode_charges()
    assert eng.take_decode_charges() == {}            # drained
    assert set(charges) == {("A", 1), ("B", 2)}
    # decode commits everything after the prefill's first token; charges
    # may exceed kept tokens (committed-then-truncated tail work) but
    # never undercount them
    assert charges[("A", 1)] >= len(done[1]) - 1
    assert charges[("B", 2)] >= len(done[2]) - 1


def test_spec_sampled_mode_smoke(cfg):
    """Sampled speculation is documented APPROXIMATE (the PRNG stream
    advances per verify position, not per committed token) — but it must
    complete, stay inside the vocabulary, and never leak the out-of-vocab
    draft FILL sentinel into a stream."""
    fm = _copy_inclined(_fm(cfg))
    eng = _engine(fm, spec_k=3, temperature=0.8, top_k=8,
                  spec_disable_below=1.0)
    rng = np.random.RandomState(5)
    for i in range(3):
        eng.join(f"t{i}", rng.randint(0, cfg.vocab_size, 10),
                 max_new_tokens=12, rid=i)
    out = _streams(eng)
    assert len(out) == 3
    for toks in out.values():
        assert len(toks) == 12
        assert all(0 <= t < cfg.vocab_size for t in toks)
