"""Discrete-event simulator: conservation, deployment modes, fairness
separation at saturation, noisy-neighbor isolation."""
import pytest

from repro.controller.profiles import get_profile
from repro.core.profile import FMProfile
from repro.serving.loadgen import burst_trace, merge, poisson_trace
from repro.serving.metrics import jain_fairness, latency_stats
from repro.serving.simulator import build_single_gpu

PROF = FMProfile("fm", alpha=16.8e-3, beta=9.5e-3, b_max=16,
                 memory_bytes=int(1.5e9), task_memory_bytes=int(1e6),
                 adapter_alpha=2e-3, adapter_beta=4e-4)


def run(mode, tasks, arrivals, horizon):
    sim, ok = build_single_gpu(mode, tasks, PROF)
    assert ok
    fin = sim.run(arrivals, horizon)
    return fin


def test_underload_everything_completes():
    tasks = [{"task_id": "A"}, {"task_id": "B"}]
    arr = merge([poisson_trace("A", 5, 10, seed=1),
                 poisson_trace("B", 5, 10, seed=2)])
    fin = run("fmplex", tasks, arr, 60.0)
    assert len(fin) == len(arr)
    assert all(r.finish_time is not None for r in fin)


def test_batching_beats_serial_at_load():
    tasks = [{"task_id": "A"}, {"task_id": "B"}]
    arr = merge([poisson_trace("A", 40, 10, seed=1),
                 poisson_trace("B", 40, 10, seed=2)])
    lat_fmplex = latency_stats(run("fmplex", tasks, list(arr), 200.0))
    lat_stfq = latency_stats(run("s-stfq", tasks, list(arr), 200.0))
    assert lat_fmplex["mean_ms"] < lat_stfq["mean_ms"] / 3


def test_sp_partition_inflates_latency_at_low_load():
    tasks = [{"task_id": "A"}, {"task_id": "B"}]
    arr = merge([poisson_trace("A", 1, 10, seed=1),
                 poisson_trace("B", 1, 10, seed=2)])
    m_fmplex = latency_stats(run("fmplex", tasks, list(arr), 60.0))["mean_ms"]
    m_sp = latency_stats(run("sp", tasks, list(arr), 60.0))["mean_ms"]
    assert m_sp > m_fmplex * 1.1      # paper: +13.7% at 1 RPS


def test_be_processor_sharing_slows_under_contention():
    tasks = [{"task_id": "A"}, {"task_id": "B"}]
    arr = merge([poisson_trace("A", 20, 10, seed=1),
                 poisson_trace("B", 20, 10, seed=2)])
    m_fmplex = latency_stats(run("fmplex", tasks, list(arr), 120.0))["mean_ms"]
    m_be = latency_stats(run("be", tasks, list(arr), 120.0))["mean_ms"]
    assert m_be > m_fmplex


def test_fairness_separates_at_saturation():
    """Paper Fig. 12: weighted shares enforced by BFQ, ignored by S-BE."""
    tasks = [{"task_id": "A", "weight": 3.0}, {"task_id": "B", "weight": 1.0}]
    arr = merge([poisson_trace("A", 100, 20, seed=1),     # deep saturation:
                 poisson_trace("B", 100, 20, seed=2)])    # both backlogged
    w = {"A": 3.0, "B": 1.0}

    def shares(mode):
        fin = run(mode, tasks, list(arr), 21.0)   # judge within the busy window
        done = [r for r in fin if r.finish_time is not None and r.finish_time < 20]
        return {t: sum(1 for r in done if r.task_id == t) for t in w}

    f_bfq = jain_fairness(shares("fmplex"), w)
    f_sbe = jain_fairness(shares("s-be"), w)
    assert f_bfq > 0.95
    assert f_bfq > f_sbe + 0.05


def test_noisy_neighbor_isolation():
    """Paper Fig. 13: B's service protected during A's 500-RPS burst."""
    tasks = [{"task_id": "A", "weight": 3.0}, {"task_id": "B", "weight": 1.0}]
    arr = merge([burst_trace("A", 5, 500, burst_start=10, burst_len=10,
                             horizon=30, seed=1),
                 poisson_trace("B", 60, 30, seed=2)])

    def b_thr_during_burst(mode):
        fin = run(mode, tasks, list(arr), 60.0)
        return sum(1 for r in fin if r.task_id == "B" and r.finish_time
                   and 10 <= r.finish_time < 20) / 10.0

    thr_bfq = b_thr_during_burst("fmplex")
    thr_sbe = b_thr_during_burst("s-be")
    # BFQ guarantees B >= w_B/(w_A+w_B) of capacity ~ 0.25 * ~90rps > 20
    assert thr_bfq > 20
    assert thr_bfq > thr_sbe * 1.5


def test_memory_admission_matches_paper_oom():
    """BE (replica per task) OOMs at N where sharing still fits (Fig. 9)."""
    prof = get_profile("moment-large")
    tasks = [{"task_id": f"t{i}"} for i in range(10)]
    _, ok_shared = build_single_gpu("fmplex", tasks, prof)
    _, ok_be = build_single_gpu("be", tasks, prof)
    assert ok_shared and not ok_be
