"""Recovery benchmark: durable serving state under a device reset + the
host-RAM KV spill tier vs re-prefill resume.

Two experiments, both against the same built server:

  * ``reset`` — one generative trace runs twice: fault-free, and with a
    ``DeviceResetFault`` injected mid-trace (snapshot -> scramble the old
    arena -> digest-verified restore). The arena is sized so NEITHER run
    preempts, making every token divergence attributable to the restore
    path alone. Hard asserts:
      - zero request loss: every trace request reaches a terminal state in
        both runs, and the reset run completes them all ``ok``;
      - bit-exact token parity for EVERY stream vs the fault-free run
        (greedy decoding: restore must reproduce the exact KV state);
      - ``resets_survived`` lands on the loop and every in-flight request,
        with zero ``digest_failures``;
      - zero steady-state recompiles across snapshot/restore after a
        one-time priming restart (restored engines reuse the old engine's
        jit caches — executables are code, not device state).

  * ``spill_resume`` — two sampled long streams on an arena that holds only
    one, forcing preemption, run three ways: big-arena reference (never
    preempts), small arena with the spill tier, small arena without. Hard
    asserts:
      - the spill run's tokens match the never-preempted reference EXACTLY
        (lossless preemption: pages + scales + PRNG key round-trip D2H/H2D);
      - every spill-run resume went through the spill path, and its mean
        resume cost beats the re-prefill resume's mean (restoring pages by
        DMA must be cheaper than recomputing them through the model).

Results land under the "recovery" section of ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import time
import warnings

import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM
from repro.core.request import SLO, Request
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions
from repro.serving.faults import ChaosEvent, ChaosInjector, DeviceResetFault
from repro.serving.loadgen import token_trace
from repro.serving.metrics import failure_counters

PROMPT_LEN = 16
MAX_NEW = 16
HORIZON = 1.5
GEN_RPS = 6.0


def build(seed: int = 0):
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=seed, input_len=PROMPT_LEN, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    for i, tid in enumerate(("gen0", "gen1")):
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(tid, "fm0", weight=1.0,
                      extensions=TaskExtensions(adapter_id=f"lora{i}"))
    # arena sized so the reset trace never preempts: parity must be
    # attributable to the restore path, not to preemption/resume noise
    srv.decode_engine("fm0", num_slots=4, prompt_len=PROMPT_LEN,
                      max_new=MAX_NEW, chunk=4, paged=True, page_size=8,
                      total_pages=96, spill_bytes=64 << 20)
    loop = srv.serve_loop("fm0")
    return srv, cfg, loop


def build_trace(cfg):
    return [r for r in token_trace(
        "gen0", GEN_RPS, HORIZON, prompt_len=PROMPT_LEN,
        vocab=cfg.vocab_size, max_new=MAX_NEW, seed=1, min_prompt_len=4,
    )] + [r for r in token_trace(
        "gen1", GEN_RPS, HORIZON, prompt_len=PROMPT_LEN,
        vocab=cfg.vocab_size, max_new=MAX_NEW, seed=2, min_prompt_len=4,
    )]


def _clone(r: Request) -> Request:
    return Request(r.task_id, r.arrival, payload=r.payload, tokens=r.tokens,
                   max_new_tokens=r.max_new_tokens,
                   slo=SLO(r.slo.deadline_s))


def run_once(loop, trace, max_wall, injector=None):
    clones = [_clone(r) for r in trace]
    keymap = {c.rid: i for i, c in enumerate(clones)}
    served = loop.run(clones, max_wall=max_wall,
                      on_tick=injector.on_tick if injector else None)
    if injector is not None:
        injector.restore_all(loop)
    return {keymap[r.rid]: r for r in served if r.rid in keymap}


def bench_reset(srv, cfg, loop, max_wall):
    fm = srv.fms["fm0"]
    trace = build_trace(cfg)

    # priming: warmup compiled the spill gather/restore scatters; one
    # checkpoint_restart exercises the snapshot/restore round trip itself.
    # Everything after must reuse jit caches — a device reset re-uploads
    # state, it does not re-derive executables.
    loop.checkpoint_restart()
    eng = srv.decode_engine("fm0")
    compiles = eng.compile_count() + fm.compile_count()

    base = run_once(loop, trace, max_wall)
    p_base = srv.decode_engine("fm0").preemptions

    loop.failures.clear()
    fault = DeviceResetFault()
    injector = ChaosInjector([ChaosEvent(at=HORIZON * 0.4, fault=fault)])
    chaos_tick = injector.on_tick

    def on_tick(lp, rel):
        # hold the reset until streams are actually in flight, so the
        # "survivors rode the reset" claim can't go vacuous on a fast tick
        if lp._inflight:
            chaos_tick(lp, rel)

    injector.on_tick = on_tick
    hit = run_once(loop, trace, max_wall, injector=injector)
    eng = srv.decode_engine("fm0")             # identity changed at restore
    recompiles = eng.compile_count() + fm.compile_count() - compiles
    fails = failure_counters(hit.values(), loop=loop, engine=eng)

    # zero request loss, everything terminal and ok in BOTH runs
    assert len(base) == len(trace) and len(hit) == len(trace), \
        f"dropped requests: base={len(base)} reset={len(hit)}/{len(trace)}"
    for i, r in hit.items():
        assert r.finish_time is not None, f"non-terminal request {i}"
        assert r.ok, f"request {i} lost to the reset: {r.status}"
    assert fault.resets == 1 and fails["resets_survived"] >= 1
    assert fails["digest_failures"] == 0
    survivors = sum(1 for r in hit.values() if r.resets_survived > 0)
    assert survivors >= 1, "no in-flight stream actually rode the reset"
    # parity is attributable to restore only if neither run preempted
    p_hit = eng.preemptions
    assert p_base == 0 and p_hit == 0, (p_base, p_hit)

    mismatched = 0
    for i in base:
        if not np.array_equal(np.asarray(base[i].result),
                              np.asarray(hit[i].result)):
            mismatched += 1
    assert mismatched == 0, \
        f"{mismatched}/{len(base)} streams lost token parity over the reset"
    assert recompiles == 0, \
        f"snapshot/restore added {recompiles} jit keys after priming"

    print(f"reset: {len(hit)}/{len(trace)} served ok, "
          f"{survivors} streams rode the reset, parity exact, "
          f"recompiles={recompiles}")
    return {
        "trace_len": len(trace),
        "served_ok": len(hit),
        "resets_survived": fails["resets_survived"],
        "streams_riding_reset": survivors,
        "digest_failures": fails["digest_failures"],
        "parity_mismatched": mismatched,
        "steady_state_recompiles": recompiles,
        "spilled_pages": fails["spilled_pages"],
        "restored_pages": fails["restored_pages"],
    }


def bench_spill_resume(srv, cfg, max_new):
    fm = srv.fms["fm0"]
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]

    def run(total_pages, spill_bytes):
        eng = DecodeEngine(fm, num_slots=2, prompt_len=8, max_new=max_new,
                           chunk=4, paged=True, page_size=4,
                           total_pages=total_pages, spill_bytes=spill_bytes,
                           temperature=0.7, top_k=8)
        if spill_bytes:
            # resume cost must time the H2D copy, not the one-time compile
            eng.warm_spill()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            for i, p in enumerate(prompts):
                eng.join(f"g{i}", p, adapter_id="lora0",
                         max_new_tokens=max_new, rid=i)
            done = eng.drain()
        return eng, {d.rid: d.tokens for d in done}

    ref_eng, ref = run(48, 0)                    # big arena: never preempts
    assert ref_eng.preemptions == 0
    spill_eng, spill = run(10, 64 << 20)         # starved arena, spill tier
    plain_eng, plain = run(10, 0)                # starved arena, re-prefill
    assert spill_eng.preemptions > 0 and plain_eng.preemptions > 0
    assert spill_eng.spill_resumes > 0
    assert all(kind == "spill" for kind, _ in spill_eng.resume_costs)
    assert spill_eng.digest_failures == 0

    # lossless preemption: the spill run IS the never-preempted run
    for rid, toks in ref.items():
        assert spill[rid] == toks, f"stream {rid} lost parity through spill"

    spill_costs = [c for _, c in spill_eng.resume_costs]
    plain_costs = [c for _, c in plain_eng.resume_costs]
    assert plain_costs, "re-prefill run recorded no resume costs"
    m_spill = float(np.mean(spill_costs))
    m_plain = float(np.mean(plain_costs))
    # restored-stream TTFT: a spill resume restores pages by DMA instead of
    # recomputing the whole context through the model
    assert m_spill < m_plain, \
        f"spill resume ({m_spill:.4f}s) not faster than re-prefill " \
        f"({m_plain:.4f}s)"

    print(f"spill_resume: parity exact over {spill_eng.preemptions} "
          f"preemptions; resume cost spill={m_spill * 1e3:.1f}ms "
          f"vs re-prefill={m_plain * 1e3:.1f}ms "
          f"(x{m_plain / max(m_spill, 1e-9):.2f})")
    return {
        "preemptions_spill": spill_eng.preemptions,
        "preemptions_plain": plain_eng.preemptions,
        "spill_resumes": spill_eng.spill_resumes,
        "spilled_pages": spill_eng.spilled_pages,
        "restored_pages": spill_eng.restored_pages,
        "parity_exact": True,
        "resume_cost_spill_ms": round(m_spill * 1e3, 3),
        "resume_cost_reprefill_ms": round(m_plain * 1e3, 3),
        "resume_speedup": round(m_plain / max(m_spill, 1e-9), 3),
    }


def run_all(out_path: str = None, smoke: bool = False):
    global HORIZON, GEN_RPS
    if smoke:
        HORIZON, GEN_RPS = 0.8, 4.0
    srv, cfg, loop = build()
    max_wall = 60.0 if smoke else 300.0
    loop.warmup(gen_task="gen0")

    reset = bench_reset(srv, cfg, loop, max_wall)
    spill = bench_spill_resume(srv, cfg, max_new=16 if smoke else 24)

    out = {
        "config": cfg.name,
        "horizon_s": HORIZON,
        "reset": reset,
        "spill_resume": spill,
    }
    write_serving_section("recovery", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short horizon, lighter rates")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
