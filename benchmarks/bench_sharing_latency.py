"""Paper Fig. 7 (+ Fig. 8 modality generalization): two tasks sharing one
backbone — mean/p99 latency across deployment modes and request rates."""
from benchmarks.common import emit, run_mode
from repro.serving.metrics import latency_stats

MODES = ("st", "be", "sp", "fmplex")
RATES = (1, 5, 10, 20)


def run(profile="moment-large", label="fig7"):
    rows = []
    for rps in RATES:
        for mode in MODES:
            fin, ok, _ = run_mode(mode, 2, rps, horizon=20.0,
                                  profile_name=profile)
            if not ok:
                rows.append((f"{label}.{mode}.rps{rps}.mean", "OOM", 0))
                continue
            s = latency_stats(fin)
            rows.append((f"{label}.{mode}.rps{rps}.mean_ms",
                         round(s["mean_ms"] * 1e3), round(s["mean_ms"], 2)))
            rows.append((f"{label}.{mode}.rps{rps}.p99_ms",
                         round(s["p99_ms"] * 1e3), round(s["p99_ms"], 2)))
    return emit(rows)


def run_all():
    rows = run("moment-large", "fig7.moment-large")
    rows += run("dinov2-base", "fig8a.dinov2-base")
    rows += run("swin-large", "fig8b.swin-large")
    # headline claims (paper: up to 80% vs SP, 33.3% vs BE at high load)
    import collections
    by = collections.defaultdict(dict)
    for name, us, derived in rows:
        parts = name.split(".")          # label.prof, mode, rpsN, metric
        by[(parts[0] + "." + parts[1], parts[3], parts[4])][parts[2]] = derived
    best_sp, best_be = 0.0, 0.0
    for (prof, rps, metric), d in sorted(by.items()):
        if metric != "mean_ms" or "sp" not in d or "fmplex" not in d:
            continue
        red_sp = 100 * (1 - d["fmplex"] / d["sp"])
        red_be = 100 * (1 - d["fmplex"] / d["be"]) if "be" in d else 0
        best_sp, best_be = max(best_sp, red_sp), max(best_be, red_be)
        print(f"{prof}.{rps}.reduction_vs_sp_pct,{red_sp:.1f},vs_be={red_be:.1f}")
    print(f"fig7_8.headline.max_reduction_vs_sp_pct,{best_sp:.1f},"
          f"paper=80; vs_be={best_be:.1f} paper=33.3")
    return rows


if __name__ == "__main__":
    run_all()
