"""Paper Fig. 9/10: mean latency vs number of co-located tasks N."""
from benchmarks.common import emit, run_mode
from repro.serving.metrics import latency_stats


def run_all():
    rows = []
    for profile, rates, label in (("moment-large", (5, 7), "fig9"),
                                  ("dinov2-base", (5,), "fig10a"),
                                  ("swin-large", (5,), "fig10b")):
        for rps in rates:
            for n in (2, 4, 6, 8, 10):
                for mode in ("fmplex", "be", "sp"):
                    fin, ok, _ = run_mode(mode, n, rps, horizon=15.0,
                                          profile_name=profile)
                    if not ok:
                        rows.append((f"{label}.{mode}.rps{rps}.n{n}.mean_ms",
                                     "OOM", 0))
                        continue
                    s = latency_stats(fin)
                    rows.append((f"{label}.{mode}.rps{rps}.n{n}.mean_ms",
                                 round(s["mean_ms"] * 1e3),
                                 round(s["mean_ms"], 1)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
