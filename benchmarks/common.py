"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import json
import pathlib
import time

from repro.controller.profiles import get_profile
from repro.serving.loadgen import merge, poisson_trace
from repro.serving.metrics import jain_fairness, latency_stats
from repro.serving.simulator import build_single_gpu

BENCH_SERVING = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"


def write_serving_section(section: str, payload: dict, out_path=None) -> dict:
    """Merge one benchmark's results into BENCH_serving.json under its own
    top-level key ("pooled" / "decode"), stamping backend + jax version +
    timestamp so numbers from different environments can't be conflated."""
    import jax

    path = pathlib.Path(out_path) if out_path else BENCH_SERVING
    data = {}
    if path.exists():
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError:
            data = {}
    if "grid" in data:           # legacy flat layout (PR 1): rehome as pooled
        data = {"pooled": data}
    payload = dict(payload)
    payload["backend"] = jax.default_backend()
    payload["jax_version"] = jax.__version__
    payload["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    data[section] = payload
    path.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote section '{section}' to {path}")
    return data


def run_mode(mode: str, n_tasks: int, rps_per_task: float, horizon: float,
             profile_name: str = "moment-large", weights=None, seed: int = 0,
             adapters: bool = False, drain: float = 40.0):
    """One single-GPU scenario -> (finished requests, ok, tasks)."""
    prof = get_profile(profile_name)
    tasks = []
    for i in range(n_tasks):
        t = {"task_id": f"t{i}", "weight": (weights[i] if weights else 1.0)}
        if adapters:
            t["adapter_id"] = f"lora{i}"
        tasks.append(t)
    sim, ok = build_single_gpu(mode, tasks, prof)
    if not ok:
        return None, False, tasks
    arr = merge([poisson_trace(f"t{i}", rps_per_task, horizon, seed=seed + i)
                 for i in range(n_tasks)])
    fin = sim.run(arr, horizon + drain)
    return fin, True, tasks


def emit(rows: list[tuple]):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    return rows
