"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

from repro.controller.profiles import get_profile
from repro.serving.loadgen import merge, poisson_trace
from repro.serving.metrics import jain_fairness, latency_stats
from repro.serving.simulator import build_single_gpu


def run_mode(mode: str, n_tasks: int, rps_per_task: float, horizon: float,
             profile_name: str = "moment-large", weights=None, seed: int = 0,
             adapters: bool = False, drain: float = 40.0):
    """One single-GPU scenario -> (finished requests, ok, tasks)."""
    prof = get_profile(profile_name)
    tasks = []
    for i in range(n_tasks):
        t = {"task_id": f"t{i}", "weight": (weights[i] if weights else 1.0)}
        if adapters:
            t["adapter_id"] = f"lora{i}"
        tasks.append(t)
    sim, ok = build_single_gpu(mode, tasks, prof)
    if not ok:
        return None, False, tasks
    arr = merge([poisson_trace(f"t{i}", rps_per_task, horizon, seed=seed + i)
                 for i in range(n_tasks)])
    fin = sim.run(arr, horizon + drain)
    return fin, True, tasks


def emit(rows: list[tuple]):
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    return rows
