"""Gather-einsum vs segmented (SGMV) LoRA serve-forward timings.

Times ``PhysicalFM.run_batch`` — the full serve forward including the
per-batch host-side segment-metadata build — across a
(batch, num_adapters) grid for both ``lora_impl`` paths, and verifies the
de-recompiled steady state: after the grid warm-up, binding one more
adapter within slot-bucket capacity and serving again must add ZERO jitted
executables.

Each cell runs ``WARMUP`` untimed iterations then reports the MEDIAN of
``REPEATS`` individually-timed runs (CPU wall times are noisy; means of a
single hot loop produced non-monotonic grids). Results land under the
"pooled" section of ``BENCH_serving.json`` (repo root), stamped with
backend + jax version + timestamp by ``common.write_serving_section``.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM, slot_bucket_for

BATCHES = (1, 2, 4, 8, 16, 32)
ADAPTERS = (1, 2, 4, 8, 16)
INPUT_LEN = 16
WARMUP = 2
REPEATS = 5


def _randomized_adapter(fm: PhysicalFM, i: int):
    """Nonzero A AND B (B is zero-init) so the delta path does real work."""
    tree = fm.adapters._mod.init_single_adapter(
        jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
    leaves, tdef = jax.tree.flatten(tree)
    ks = jax.random.split(jax.random.PRNGKey(1000 + i), len(leaves))
    return jax.tree.unflatten(tdef, [
        jax.random.normal(k, l.shape, l.dtype) * 0.05
        for k, l in zip(ks, leaves)])


def _fm(cfg, impl: str, num_adapters: int) -> PhysicalFM:
    fm = PhysicalFM(cfg, seed=0, input_len=INPUT_LEN, lora_rank=8,
                    lora_impl=impl, seg_block_t=16)
    for i in range(num_adapters):
        fm.adapters.add(f"lora{i}", _randomized_adapter(fm, i))
    return fm


def _time_batch(fm: PhysicalFM, batch: int, num_adapters: int,
                repeats: int = REPEATS) -> float:
    rng = np.random.RandomState(batch * 100 + num_adapters)
    x = rng.randn(batch, INPUT_LEN, fm.cfg.d_model).astype(np.float32)
    aidx = (np.arange(batch) % num_adapters).astype(np.int32)
    for _ in range(1 + WARMUP):                             # compile + warm
        fm.run_batch(x, aidx)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fm.run_batch(x, aidx)
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e3


def run_all(out_path: str = None, smoke: bool = False):
    global BATCHES, ADAPTERS
    if smoke:                                 # CI: tiny grid, one repeat
        BATCHES, ADAPTERS = (1, 4), (1, 2)
    repeats = 1 if smoke else REPEATS
    cfg = reduced(get_config("moment-large"))
    grid = []
    # one FM per (impl, slot bucket): realistic multi-adapter residency, and
    # the jit cache is shared across the grid cells the way serving shares it
    fms = {(impl, slot_bucket_for(na)): None
           for impl in ("gather", "segmented") for na in ADAPTERS}
    for (impl, cap) in fms:
        fms[(impl, cap)] = _fm(cfg, impl, cap)
    for na in ADAPTERS:
        cap = slot_bucket_for(na)
        for b in BATCHES:
            row = {"batch": b, "num_adapters": na}
            for impl in ("gather", "segmented"):
                row[f"{impl}_ms"] = round(
                    _time_batch(fms[(impl, cap)], b, na, repeats), 3)
            grid.append(row)
            print(f"b={b:3d} na={na:3d} gather={row['gather_ms']:8.2f}ms "
                  f"segmented={row['segmented_ms']:8.2f}ms")

    # steady state: bind one more task within slot capacity -> zero recompiles
    fm = _fm(cfg, "segmented", 2)                 # 2 adapters, slot bucket 4
    cap = fm.adapters.capacity()
    x = np.random.RandomState(7).randn(4, INPUT_LEN,
                                       cfg.d_model).astype(np.float32)
    fm.run_batch(x, np.array([0, 1, 0, cap], np.int32))     # warm
    before = fm.compile_count()
    fm.adapters.add("late-bound", _randomized_adapter(fm, 99))
    assert fm.adapters.capacity() == cap, "bucket crossed; pick smaller NA"
    fm.run_batch(x, np.array([len(fm.adapters) - 1, 0, 0, cap], np.int32))
    steady = {
        "recompiles_after_add_within_capacity": fm.compile_count() - before,
        "jit_entries": len(fm._jit_cache),
        "slot_bucket": cap,
    }
    print("steady state:", steady)

    out = {
        "config": cfg.name,
        "input_len": INPUT_LEN,
        "warmup": WARMUP,
        "repeats": repeats,
        "stat": "median",
        "grid": grid,
        "steady_state": steady,
    }
    write_serving_section("pooled", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny grid, 1 repeat")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
