"""Kernel benchmarks: XLA-path wall time on CPU (what this container can
measure) + analytic TPU-v5e roofline floor per kernel (what the BlockSpec
tiling targets). Pallas correctness is covered by tests/test_kernels.py."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ref

PEAK = 197e12
HBM = 819e9


def timeit(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n


def run_all():
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    # flash attention prefill tile
    B, H, KV, S, hd = 1, 8, 2, 1024, 128
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.bfloat16)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    us = timeit(f, q, k, v) * 1e6
    flops = 4 * B * H * S * S * hd
    tpu_us = flops / PEAK * 1e6
    rows.append(("kernel.flash_attention.1k", round(us, 1),
                 f"tpu_roofline_us={tpu_us:.1f}"))

    # decode attention (bandwidth bound)
    B, H, KV, S, hd = 8, 32, 8, 4096, 128
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (B, KV, S, hd), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, KV, S, hd), jnp.bfloat16)
    lens = jnp.full((B,), S, jnp.int32)
    f = jax.jit(lambda a, b, c, l: ref.decode_attention_ref(a, b, c, l))
    us = timeit(f, q, kc, vc, lens) * 1e6
    bytes_moved = 2 * B * KV * S * hd * 2
    tpu_us = bytes_moved / HBM * 1e6
    rows.append(("kernel.decode_attention.4k", round(us, 1),
                 f"tpu_roofline_us={tpu_us:.1f}"))

    # segmented lora
    T, d, r, NA, bt = 512, 2048, 16, 16, 64
    x = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
    a = jax.random.normal(ks[1], (NA, d, r), jnp.bfloat16) * 0.05
    b = jax.random.normal(ks[2], (NA, r, d), jnp.bfloat16) * 0.05
    blocks = jnp.asarray(np.random.RandomState(0).randint(0, NA, T // bt),
                         jnp.int32)
    f = jax.jit(lambda *aa: ref.segmented_lora_ref(*aa, block_size=bt))
    us = timeit(f, x, blocks, a, b) * 1e6
    flops = 2 * T * d * r * 2
    tpu_us = max(flops / PEAK, (T * d * 2 * 2 + NA * 2 * d * r * 2) / HBM) * 1e6
    rows.append(("kernel.segmented_lora.512x2048", round(us, 1),
                 f"tpu_roofline_us={tpu_us:.1f}"))
    return emit(rows)


if __name__ == "__main__":
    run_all()
