"""Paper Fig. 14/15: 16-server cluster with Azure-like load bands.

FMplex = Controller Max-Share placement (shared backbones, BFQ) vs BE
(replica-per-task, best-effort). Metrics: end-to-end latency on an 85-task
workload and max tasks hosted per load band.
"""
import numpy as np

from benchmarks.common import emit
from repro.controller import ClusterState, MaxShare, Server, TaskSpec
from repro.controller.profiles import get_profile
from repro.serving.loadgen import LOAD_BANDS, merge, poisson_trace
from repro.serving.metrics import latency_stats
from repro.serving.simulator import SimGPU, SimInstance, Simulator

N_SERVERS = 16
# density mix (Fig 15): TS/vision tasks + heavyweight LLM/VLM backbones,
# where memory pressure exposes the sharing advantage
BACKBONES = ("moment-large", "moment-large", "moment-large", "dinov2-base",
             "swin-large", "papagei", "qwen2.5-3b", "mistral-7b")
# latency mix (Fig 14): the paper's 85-task workload is dominated by small
# TS/vision backbones (Table 2) so that BOTH systems can host it
LATENCY_MIX = ("moment-large", "papagei", "papagei", "dinov2-base",
               "swin-large", "moment-large", "dinov2-base", "qwen2-vl-2b")


def _task_specs(n_tasks, band, seed=0, mix=BACKBONES):
    rng = np.random.RandomState(seed)
    lo, hi = LOAD_BANDS[band]
    specs = []
    for i in range(n_tasks):
        backbone = mix[i % len(mix)]
        rpm = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        rps = rpm / 60.0
        if backbone in ("qwen2.5-3b", "mistral-7b", "qwen2-vl-2b"):
            rps = min(rps, 0.5)         # token-based tasks are low-rate
        prof = get_profile(backbone)
        specs.append(TaskSpec(f"task{i}", backbone, demand_rps=rps,
                              slo_s=10 * prof.l(1)))  # SLO bounds batch growth
    return specs


def _traffic(placed, horizon, seed=0):
    """Poisson traffic at each task's PLACED rate with hot/cold modulation
    (x1.5 / x0.7) so bursts exercise BFQ without invalidating placement."""
    rng = np.random.RandomState(seed)
    traces = []
    for t in placed:
        reqs, tt, hot = [], 0.0, rng.rand() < 0.3
        while tt < horizon:
            period = float(rng.exponential(15.0))
            rate = t.demand_rps * (1.5 if hot else 0.7)
            reqs += poisson_trace(t.task_id, max(rate, 1e-3),
                                  min(period, horizon - tt),
                                  seed=rng.randint(1 << 30), start=tt)
            tt += period
            hot = not hot
        traces.append(reqs)
    return merge(traces)


def build_fmplex_cluster(specs):
    profiles = {b: get_profile(b) for b in set(BACKBONES) | set(LATENCY_MIX)}
    cluster = ClusterState([Server(f"s{i}") for i in range(N_SERVERS)], profiles)
    ms = MaxShare(cluster)
    placed = [t for t in specs if ms.place(t)]
    # materialize into the simulator
    gpus = {s: SimGPU(s, sharing="partition") for s in cluster.servers}
    insts = {}
    for dep in cluster.deployments.values():
        inst = SimInstance(dep.dep_id, dep.profile, scheduler="bfq")
        insts[dep.dep_id] = inst
        gpus[dep.server_id].instances.append(inst)
    sim = Simulator(list(gpus.values()))
    from repro.core.request import SLO
    for t in placed:
        for dep_id in cluster.task_bindings[t.task_id]:
            dep = cluster.deployments[dep_id]
            inst = insts[dep_id]
            inst.bind(t.task_id, weight=t.weight, slo=SLO(t.slo_s))
            sim.route(t.task_id, gpus[dep.server_id], inst,
                      frac=dep.routing[t.task_id])
    return sim, placed


def _be_per_req(prof, rps):
    """Per-request GPU seconds for a lone replica: it can only batch its OWN
    queue, so expected batch depth follows its arrival rate."""
    b = max(1, min(prof.b_max, int(rps * prof.l(prof.b_max))))
    return prof.l(b) / b


def build_be_cluster(specs):
    """Replica-per-task, first-fit by memory + compute, best-effort sharing."""
    gpus = [SimGPU(f"s{i}", sharing="ps") for i in range(N_SERVERS)]
    util = {g.gpu_id: 0.0 for g in gpus}
    sim = Simulator(gpus)
    placed = []
    for t in specs:
        prof = get_profile(t.backbone)
        need_mem = (prof.memory_bytes + prof.instance_overhead_bytes
                    + prof.task_memory_bytes)
        need_util = t.demand_rps * _be_per_req(prof, t.demand_rps)
        target = next((g for g in gpus if g.fits(need_mem)
                       and util[g.gpu_id] + need_util <= 0.8), None)
        if target is None:
            continue
        inst = SimInstance(f"{t.backbone}/{t.task_id}", prof, scheduler="s-be")
        target.instances.append(inst)
        util[target.gpu_id] += need_util
        inst.bind(t.task_id, weight=t.weight)
        sim.route(t.task_id, target, inst)
        placed.append(t)
    return sim, placed


def density(band, builder):
    specs = _task_specs(2000, band, seed=1)
    _, placed = builder(specs)
    return len(placed)


def run_all():
    rows = []
    # ---- Fig. 15: task density per band ----
    for band in ("low", "moderate", "high"):
        n_fm = density(band, build_fmplex_cluster)
        n_be = density(band, build_be_cluster)
        rows.append((f"fig15.fmplex.{band}.tasks", n_fm * 1000, n_fm))
        rows.append((f"fig15.be.{band}.tasks", n_be * 1000, n_be))
        rows.append((f"fig15.ratio.{band}", round(1e3 * n_fm / max(n_be, 1)),
                     round(n_fm / max(n_be, 1), 2)))
    # ---- Fig. 14: latency on an 85-task workload ----
    specs = _task_specs(85, "moderate", seed=2, mix=LATENCY_MIX)
    horizon = 60.0
    for mode, builder in (("fmplex", build_fmplex_cluster),
                          ("be", build_be_cluster)):
        sim, placed = builder(specs)
        arr = _traffic(placed, horizon, seed=3)
        fin = sim.run(arr, horizon + 60)
        done = [r for r in fin if r.finish_time]
        s = latency_stats(done)
        rows.append((f"fig14.{mode}.mean_ms", round(s["mean_ms"] * 1e3),
                     round(s["mean_ms"], 1)))
        rows.append((f"fig14.{mode}.p99_ms", round(s["p99_ms"] * 1e3),
                     round(s["p99_ms"], 1)))
        rows.append((f"fig14.{mode}.placed", len(placed) * 1000, len(placed)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
