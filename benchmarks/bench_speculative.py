"""Self-speculative decoding benchmark (the PR's acceptance numbers).

Three claims, measured on the same reduced decoder backbone, speculative
engine vs an engine identical except ``spec_k=0`` (so the only variable is
the speculative plane):

  * **throughput on a self-overlapping workload** — decode tokens/s on a
    high-overlap agentic trace improves >= 1.5x at k=4 (smoke: > 1.0).
    Accept rates need generation that actually repeats itself; random
    reduced-model weights never do, so the high-overlap leg runs on a
    COPY-INCLINED backbone (attention out-projections zeroed: logits
    depend only on the current token, the greedy chain is a bigram machine
    that cycles, and the prompt-lookup drafter's matches accept — the
    deterministic stand-in for a real model continuing agentic context).
  * **exact greedy parity** — every stream's tokens match the plain
    engine's token for token, on BOTH workloads. Speculation is a
    scheduling change, not a numeric one.
  * **bounded adversarial regression** — on a zero-overlap trace (random
    weights, every draft misses) the EMA demotes to plain dispatches with
    periodic speculative probes, holding the regression to <= 10% (full;
    smoke asserts a loose floor against CI noise).

Dispatch walls are compile-dominated until warmed, so every engine warms
its prefill bucket, the plain decode ladder AND the speculative ladder
before timing, and each leg re-drives the same workload several times
taking the fastest pass (CPU CI noise). Results land under the "spec"
section of ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM
from repro.serving.loadgen import adversarial_token_trace, agentic_token_trace
from repro.serving.metrics import speculation_stats

PAGE_SIZE = 16
PROMPT_LEN = 32
MAX_NEW = 384          # long streams: the copy-inclined bigram chain needs
                       # ~a cycle (~20 tokens) before the drafter's matches
                       # start landing, so short streams under-report the
                       # steady-state accept rate
CHUNK = 4
SPEC_K = 4
NUM_SLOTS = 4
N_STREAMS = 4          # == slots: every stream admits up front, so the
                       # timed region is pure decode on both engines
TOTAL_PAGES = 128
REPEATS = 5


def _fm(cfg) -> PhysicalFM:
    return PhysicalFM(cfg, seed=0, input_len=PROMPT_LEN, lora_rank=8,
                      lora_impl="segmented", seg_block_t=16)


def _copy_inclined(fm) -> PhysicalFM:
    """Zero the attention out-projections: next-token logits depend only on
    the current token, so greedy generation is a deterministic bigram walk
    over a finite vocab — it cycles (pigeonhole), the history fills with
    repeats, and the drafter's accept rate climbs to ~1. This is the
    accept-heavy regime a real model reaches on agentic re-fed context,
    made reproducible on a randomly-initialized reduced backbone."""
    fm.params = jax.tree_util.tree_map_with_path(
        lambda path, l: l * 0.0
        if any(getattr(k, "key", None) == "wo" for k in path) else l,
        fm.params)
    return fm


def trace_workload(cfg, *, overlap: float, seed: int = 0):
    """(prompt, budget) pairs lifted off the loadgen traces the serving
    plane uses — high self-overlap agentic loops or the zero-overlap
    adversarial variant. Budgets are pinned to MAX_NEW so both engines hold
    the full co-batch for the whole drive (pure decode measurement)."""
    kw = dict(prompt_len=PROMPT_LEN, vocab=cfg.vocab_size, max_new=MAX_NEW,
              min_new=MAX_NEW, seed=seed)
    reqs = agentic_token_trace("bench", 10.0, 100.0, overlap=overlap, **kw) \
        if overlap > 0.0 else \
        adversarial_token_trace("bench", 10.0, 100.0, **kw)
    return [(np.asarray(r.payload, np.int32), r.max_new_tokens)
            for r in reqs[:N_STREAMS]]


def make_engine(fm, *, spec_k: int, **kw) -> DecodeEngine:
    return DecodeEngine(fm, num_slots=NUM_SLOTS, prompt_len=PROMPT_LEN,
                        max_new=MAX_NEW, chunk=CHUNK, paged=True,
                        page_size=PAGE_SIZE, total_pages=TOTAL_PAGES,
                        prompt_buckets=(PROMPT_LEN,), spec_k=spec_k, **kw)


def warm(eng, cfg, seed: int = 123):
    """Compile everything a drive can touch: the prefill bucket, the
    chunked shared-prefix tail planes (motif prompts hit the prefix
    registry), the plain decode ladder, and (spec engines) the speculative
    ladder."""
    rng = np.random.RandomState(seed)
    eng.join("warm", rng.randint(0, cfg.vocab_size, PROMPT_LEN),
             max_new_tokens=2, rid=-1)
    eng.drain()
    eng.warm_chunked()
    eng.warm_decode_ladder()
    if eng.spec_k:
        eng.warm_speculative()


def drive(eng: DecodeEngine, work, repeats: int) -> dict:
    """Admit the whole co-batch (untimed — identical prefill work on both
    engines), then time the drain. Greedy decoding is deterministic, so
    repeat passes must reproduce the streams exactly; the fastest pass is
    the steady-state number."""
    outs, walls = None, []
    for _ in range(repeats):
        for i, (prompt, new) in enumerate(work):
            eng.join(f"t{i}", prompt, max_new_tokens=new, rid=i)
        t0 = time.perf_counter()
        done = {}
        while eng.active_count() or eng.pending_count():
            for d in eng.step_chunk():
                done[d.rid] = d.tokens
        walls.append(time.perf_counter() - t0)
        assert len(done) == len(work), (len(done), len(work))
        if outs is None:
            outs = done
        else:
            assert outs == done, "greedy drive not deterministic"
    toks = sum(len(t) for t in outs.values())
    wall = min(walls)
    return {"streams": len(outs), "tokens_out": toks,
            "tokens_per_s": round(toks / wall, 1),
            "wall_s": round(wall, 4), "tokens": outs}


def bench_leg(fm, cfg, work, repeats: int, **spec_kw) -> dict:
    """Spec vs plain on one workload: tokens/s ratio, exact stream parity,
    zero recompiles after warm, and the spec engine's acceptance stats."""
    results, compiles, engines = {}, {}, {}
    for name, k in (("plain", 0), ("spec", SPEC_K)):
        eng = make_engine(fm, spec_k=k, **(spec_kw if k else {}))
        warm(eng, cfg)
        before = eng.compile_count()
        results[name] = drive(eng, work, repeats)
        compiles[name] = eng.compile_count() - before
        engines[name] = eng
    parity = results["plain"].pop("tokens") == results["spec"].pop("tokens")
    ratio = results["spec"]["tokens_per_s"] / \
        max(results["plain"]["tokens_per_s"], 1e-9)
    return {
        "plain": results["plain"],
        "spec": results["spec"],
        "speedup": round(ratio, 2),
        "greedy_parity": bool(parity),
        "recompiles_after_warm": compiles,
        "speculation": speculation_stats(engines["spec"]),
    }


def run_all(out_path: str = None, smoke: bool = False):
    global MAX_NEW, REPEATS
    if smoke:
        MAX_NEW, REPEATS = 192, 3
    cfg = reduced(get_config("stablelm-1.6b"))

    # the high-overlap leg pins speculation ON (spec_disable_below=1.0):
    # it measures the speculative plane's throughput in the accept-heavy
    # regime; the adaptive demotion machinery is the ADVERSARIAL leg's
    # subject and runs there at stock settings
    high = bench_leg(_copy_inclined(_fm(cfg)), cfg,
                     trace_workload(cfg, overlap=0.85), REPEATS,
                     spec_disable_below=1.0)
    print(f"high-overlap: plain {high['plain']['tokens_per_s']} tok/s, "
          f"spec {high['spec']['tokens_per_s']} tok/s "
          f"(x{high['speedup']}), accept rate "
          f"{high['speculation']['accept_rate']}, parity "
          f"{high['greedy_parity']}, recompiles "
          f"{high['recompiles_after_warm']}")
    assert high["greedy_parity"], "speculation changed a token stream"
    assert high["recompiles_after_warm"] == {"plain": 0, "spec": 0}
    assert high["speedup"] > (1.0 if smoke else 1.5), high["speedup"]

    adv = bench_leg(_fm(cfg), cfg, trace_workload(cfg, overlap=0.0, seed=7),
                    REPEATS)
    print(f"adversarial: plain {adv['plain']['tokens_per_s']} tok/s, "
          f"spec {adv['spec']['tokens_per_s']} tok/s (x{adv['speedup']}), "
          f"fallbacks {adv['speculation']['spec_fallbacks']}, parity "
          f"{adv['greedy_parity']}")
    assert adv["greedy_parity"], "adversarial leg changed a token stream"
    assert adv["recompiles_after_warm"] == {"plain": 0, "spec": 0}
    assert adv["speedup"] >= (0.5 if smoke else 0.9), adv["speedup"]

    out = {
        "config": cfg.name,
        "spec_k": SPEC_K,
        "chunk": CHUNK,
        "page_size": PAGE_SIZE,
        "prompt_len": PROMPT_LEN,
        "max_new": MAX_NEW,
        "n_streams": N_STREAMS,
        "repeats": REPEATS,
        "high_overlap": high,
        "adversarial": adv,
        "greedy_parity": bool(high["greedy_parity"]
                              and adv["greedy_parity"]),
        "spec_speedup_1p5x": bool(high["speedup"] >= 1.5),
        "adversarial_within_10pct": bool(adv["speedup"] >= 0.9),
    }
    write_serving_section("spec", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: shorter streams, fewer repeats")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
