"""Paper Table 3: backbone vs task-component memory / load time / latency —
REAL measurements on the CPU-scale execution plane (reduced configs; the
asymmetry, not the absolute values, is the reproduced claim)."""
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM


def run_all():
    rows = []
    for arch in ("moment-large", "qwen2-7b", "whisper-base"):
        cfg = reduced(get_config(arch))
        fm = PhysicalFM(cfg, input_len=16, lora_rank=4)
        prof = fm.calibrate(sizes=(1, 2, 4))
        bb_mem = prof.memory_bytes
        # task component: one LoRA adapter + a linear head
        t0 = time.perf_counter()
        tree = fm.adapters.new("t_adapter", seed=1)
        import jax
        task_mem = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
        task_mem += cfg.d_model * 4 * 4   # linear head
        task_load = time.perf_counter() - t0
        x = np.random.RandomState(0).randn(1, 16, cfg.d_model).astype(np.float32)
        # backbone latency (warm)
        fm.run_batch(x, np.array([0], np.int32))
        t0 = time.perf_counter()
        feats = fm.run_batch(x, np.array([0], np.int32))
        bb_lat = time.perf_counter() - t0
        w = np.random.RandomState(1).randn(cfg.d_model, 4).astype(np.float32)
        t0 = time.perf_counter()
        _ = feats @ w
        task_lat = time.perf_counter() - t0
        rows += [
            (f"table3.{arch}.bb_memory_MB", round(bb_mem / 1e6 * 1e3),
             round(bb_mem / 1e6, 2)),
            (f"table3.{arch}.task_memory_MB", round(task_mem / 1e6 * 1e3),
             round(task_mem / 1e6, 4)),
            (f"table3.{arch}.bb_load_ms", round(fm.load_time_s * 1e6),
             round(fm.load_time_s * 1e3, 1)),
            (f"table3.{arch}.task_load_ms", round(task_load * 1e6),
             round(task_load * 1e3, 2)),
            (f"table3.{arch}.bb_latency_ms", round(bb_lat * 1e6),
             round(bb_lat * 1e3, 2)),
            (f"table3.{arch}.task_latency_ms", round(task_lat * 1e6),
             round(task_lat * 1e3, 4)),
            (f"table3.{arch}.bb_over_task_memory_x",
             round(bb_mem / max(task_mem, 1) * 1e3),
             round(bb_mem / max(task_mem, 1), 1)),
        ]
    return emit(rows)


if __name__ == "__main__":
    run_all()
